"""Benchmark driver — one section per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only SECTION]

Sections: tables (I-III), convergence (Fig 2), ablations (Fig 3-4),
kernels, roofline, inference (decentralized-inference cost),
round_engine, participation (adaptive client selection vs uniform).
"""
from __future__ import annotations

import argparse
import time


def run_inference_bench(quick: bool = False) -> None:
    """Decentralized vs server-mediated inference (paper contribution #2)."""
    import jax
    import numpy as np

    from benchmarks.common import ExpConfig, run_blendfl, timeit
    from repro.core.inference import InferenceRequest, predict

    print("\n=== decentralized inference vs VFL serving ===")
    exp = ExpConfig(task="smnist", rounds=4 if quick else 8)
    _, _, (fed, te) = run_blendfl(exp)
    m, ecfg, kind = fed.global_models, fed.ecfg, fed.spec.kind
    req = InferenceRequest(te.x_a[:32], te.x_b[:32])
    vfl_req = InferenceRequest(te.x_a[:32], te.x_b[:32], vfl=True)

    t_local = timeit(lambda: jax.block_until_ready(
        predict(m, req, ecfg, kind).scores), n=10)
    t_server = timeit(lambda: jax.block_until_ready(
        predict(m, vfl_req, ecfg, kind, server_gmv=fed.server_gmv).scores),
        n=10)
    c_local = predict(m, req, ecfg, kind)
    c_server = predict(m, vfl_req, ecfg, kind, server_gmv=fed.server_gmv)
    c_srv_i8 = predict(m, vfl_req, ecfg, kind, server_gmv=fed.server_gmv,
                       codec="int8")
    print(f"{'mode':16s} {'us_per_batch':>12s} {'net_msgs':>9s} {'net_bytes':>10s}")
    print(f"{'decentralized':16s} {t_local:12.0f} {c_local.messages:9d} "
          f"{c_local.bytes:10d}")
    print(f"{'vfl_server':16s} {t_server:12.0f} {c_server.messages:9d} "
          f"{c_server.bytes:10d}")
    print(f"{'vfl_server_int8':16s} {'':>12s} {c_srv_i8.messages:9d} "
          f"{c_srv_i8.bytes:10d}")
    print("--> BlendFL serves locally with zero network traffic; conventional "
          "VFL pays 2 uploads + 1 download per request and needs a live "
          "server — the int8 wire codec shrinks but cannot close that gap")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None,
                    choices=["tables", "convergence", "ablations", "kernels",
                             "roofline", "inference", "round_engine",
                             "participation"])
    args = ap.parse_args()
    t0 = time.time()

    sections = {}
    from benchmarks import (ablations, convergence, kernels_bench,
                            participation_bench, roofline_report,
                            round_engine_bench, tables)
    sections["tables"] = tables.main
    sections["convergence"] = convergence.main
    sections["ablations"] = ablations.main
    sections["kernels"] = kernels_bench.main
    sections["roofline"] = roofline_report.main
    sections["inference"] = run_inference_bench
    sections["round_engine"] = round_engine_bench.main
    sections["participation"] = participation_bench.main

    todo = [args.only] if args.only else list(sections)
    for name in todo:
        sections[name](quick=args.quick)
    print(f"\nall benchmarks done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
