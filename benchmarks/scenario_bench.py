"""§Elastic cohorts: participation policies under churn + poisoning.

A federation that starts at C=6 / K=3 and then LIVES: 4 fresh clients
join at round 3 (crossing the capacity bucket 8 -> 16, which is the one
re-jit the elastic-state design budgets for), client 2 turns
label-flipping adversarial at round 4, and clients 0-1 depart at
round 5. The same ``repro.data.scenario.Scenario`` drives every arm, so
the bench measures exactly what the scenario harness promises:

  - membership is host-side data — the per-capacity jitted rounds
    compile once each and their caches stay at 1 across ALL policies
    and all churn events (growth re-jits per bucket, not per round);
  - joins help: the blended global model keeps converging after the
    cohort grows, because joiners' rows adopt the current globals;
  - adaptive participation (data_volume / omega_ema) routes around the
    churn at least as well as uniform sampling.

For each policy the bench drives the shared per-bucket rounds through a
scenario-aware ``FederatedBatcher`` and records rounds to a target
validation multimodal AUROC (host-side eval, outside the timed region),
per-round wall time, and the event/capacity accounting that
``tools/bench_check.py`` validates (event counts >= 0, AUROCs in
[0, 1], null-or-int rounds_to_target, caches exactly 1).

Emits ``BENCH_scenario.json``. Acceptance: every per-bucket compile
cache is exactly 1, both capacity buckets (8 and 16) were exercised,
and at least one policy reaches the target AUROC despite the churn.

    PYTHONPATH=src python -m benchmarks.scenario_bench [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import write_bench_json

POLICIES = ("uniform", "data_volume", "omega_ema")
N_INITIAL, K = 6, 3
TARGET_AUROC = 0.80


def _scenario():
    from repro.data.scenario import Event, Scenario

    return Scenario((
        Event(round=3, join=4),        # 6 -> 10 clients: bucket 8 -> 16
        Event(round=4, corrupt=(2,)),  # label-flipping adversary
        Event(round=5, leave=(0, 1)),  # two departures (rows retired)
    )).validate(N_INITIAL)


def _roster(task, tr, n_paired: int, n_partial: int):
    """The full 10-client roster (initial cohort + future joiners),
    partitioned up-front so membership stays a pure function of the
    round index."""
    clients, cursor = [], 0

    def take(n):
        nonlocal cursor
        sl = slice(cursor, cursor + n)
        cursor += n
        return tr.x_a[sl], tr.x_b[sl], tr.y[sl]

    for _ in range(N_INITIAL + _scenario().total_joins()):
        pa, pb, py = take(n_paired)
        ua, ub, uy = take(n_partial)
        clients.append({
            "paired_a": pa, "paired_b": pb, "paired_y": py,
            "partial_a": ua, "partial_ya": uy,
            "partial_b": ub, "partial_yb": uy,
        })
    return clients


def _build(quick: bool):
    from repro.core import state as rstate
    from repro.core.federation_sharded import (
        ShardedFedSpec, batch_specs, init_round_state, make_blendfl_round)
    from repro.data.synthetic import make_task, train_val_test
    from repro.launch import shardings as sh
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train_federated import place_state

    task = make_task("smnist")
    n_paired, n_partial = (48, 24) if quick else (96, 48)
    n_total = N_INITIAL + _scenario().total_joins()
    need = n_total * (n_paired + n_partial) + 64
    tr, va, _ = train_val_test(task, need, 512, 64, seed=0)
    clients = _roster(task, tr, n_paired, n_partial)

    cap0 = rstate.capacity_for(N_INITIAL)
    spec = ShardedFedSpec(
        n_clients=cap0, d_hidden=32, n_layers=2, seq_a=task.seq_a,
        feat_a=task.feat_a, seq_b=task.seq_b, feat_b=task.feat_b,
        out_dim=task.out_dim, kind=task.kind, n_partial=n_partial,
        n_frag=8, n_paired=n_paired, n_val=512, lr=2e-2,
        optimizer="adamw", n_sampled=K)
    mesh = make_host_mesh()
    shard = sh.batch_shardings(mesh, batch_specs(spec, ragged=True))
    val = {"val_a": va.x_a, "val_b": va.x_b, "val_y": va.y}

    # one jitted round per capacity bucket, shared across every policy
    # arm (the sampled ids and the active mask are data, not shapes)
    caps = sorted({rstate.capacity_for(_scenario().n_clients_at(r, N_INITIAL))
                   for r in range(64)})
    round_fns = {c: jax.jit(make_blendfl_round(
        dataclasses.replace(spec, n_clients=c))) for c in caps}

    # warm every bucket on throwaway states so no arm's s_per_round
    # carries a compile
    from repro.data.pipeline import FederatedBatcher
    wb = FederatedBatcher(clients[:cap0 - 2] + [{}] * 2, spec, val,
                          seed=0, shardings=shard)
    wstate = place_state(init_round_state(jax.random.PRNGKey(0), spec), mesh)
    for _, batch in wb.rounds(0, 1, prefetch=0):
        jax.block_until_ready(round_fns[caps[0]](wstate, batch)[0])
        for c in caps[1:]:
            grown = place_state(rstate.grow(wstate, c), mesh)
            jax.block_until_ready(round_fns[c](grown, batch)[0])
    return spec, clients, val, va, shard, mesh, round_fns


def _run_policy(policy: str, spec, clients, val, va, shard, mesh, round_fns,
                rounds: int):
    """One policy arm: the scenario loop (grow / retire / corrupt) with
    a host-side AUROC eval per round, eval time subtracted from the
    reported per-round wall time."""
    from repro.core import state as rstate
    from repro.core.federation import eval_multimodal
    from repro.core.federation_sharded import init_round_state
    from repro.core.schedule import telemetry_from_state
    from repro.data.pipeline import FederatedBatcher
    from repro.launch.train_federated import place_state

    scenario = _scenario()
    spec = dataclasses.replace(spec, policy=policy)
    batcher = FederatedBatcher(clients, spec, val, seed=0, shardings=shard,
                               scenario=scenario, n_initial=N_INITIAL)
    state = place_state(init_round_state(jax.random.PRNGKey(0), spec), mesh)

    aurocs, eval_spent, to_target = [], 0.0, None
    t_loop = time.perf_counter()
    for r in range(rounds):
        ev = scenario.events_at(r)
        cap = rstate.capacity_for(scenario.n_clients_at(r, N_INITIAL))
        if cap > spec.n_clients:
            state = place_state(rstate.grow(state, cap), mesh)
            spec = dataclasses.replace(spec, n_clients=cap)
            batcher.set_spec(spec)
        if ev is not None and ev.leave:
            state = place_state(rstate.retire_clients(state, ev.leave), mesh)
        sched = (telemetry_from_state(state)
                 if batcher.policy.needs_state else None)
        batch = batcher.put(batcher.build(r, sched))
        state, _ = round_fns[spec.n_clients](state, batch)
        jax.block_until_ready(state["global_models"])
        t0 = time.perf_counter()
        g = state["global_models"]
        auc = eval_multimodal(g["f_A"], g["f_B"], g["g_M"], va.x_a, va.x_b,
                              va.y, spec.ecfg, spec.kind)
        eval_spent += time.perf_counter() - t0
        aurocs.append(auc)
        if to_target is None and auc >= TARGET_AUROC:
            to_target = r + 1
    loop_spent = time.perf_counter() - t_loop
    return {
        "policy": policy,
        "rounds_to_target": to_target,
        "target_auroc": TARGET_AUROC,
        "final_auroc": round(aurocs[-1], 4),
        "best_auroc": round(max(aurocs), 4),
        "s_per_round": round((loop_spent - eval_spent) / rounds, 4),
    }


def main(quick: bool = False) -> None:
    print(f"\n=== elastic cohorts: C={N_INITIAL} K={K}, join at r3 "
          "(bucket 8->16), corrupt at r4, leave at r5 ===")
    spec, clients, val, va, shard, mesh, round_fns = _build(quick)
    rounds = 10 if quick else 18
    scenario = _scenario()

    print(f"{'policy':>12s} {'to_target':>9s} {'final':>7s} {'best':>7s} "
          f"{'s/round':>8s}")
    records = []
    for p in POLICIES:
        rec = _run_policy(p, spec, clients, val, va, shard, mesh, round_fns,
                          rounds)
        records.append(rec)
        tt = "-" if rec["rounds_to_target"] is None else rec["rounds_to_target"]
        print(f"{p:>12s} {tt!s:>9s} {rec['final_auroc']:7.3f} "
              f"{rec['best_auroc']:7.3f} {rec['s_per_round']:8.3f}",
              flush=True)
    caches = [int(fn._cache_size()) for fn in round_fns.values()]
    print(f"per-bucket compile caches across all policies: "
          f"{dict(zip(round_fns, caches))}")

    # record first, assert after: a failed acceptance still leaves the
    # measurement on disk for the next comparison
    write_bench_json("BENCH_scenario.json",
                     {"bench": "scenario",
                      "backend": jax.default_backend(),
                      "n_initial": N_INITIAL, "k": K, "rounds": rounds,
                      "n_join": scenario.total_joins(),
                      "n_leave": len(scenario.left_ids(rounds)),
                      "n_corrupt": len(scenario.corrupt_ids(rounds)),
                      "capacities": sorted(round_fns),
                      "caches": caches,
                      "records": records})
    assert all(c == 1 for c in caches), \
        f"each capacity bucket must compile exactly once, got {caches}"
    assert len(round_fns) == 2, \
        f"the scenario must cross one capacity bucket (8 -> 16): {round_fns}"
    reached = [r for r in records if r["rounds_to_target"] is not None]
    assert reached, (f"no policy reached AUROC {TARGET_AUROC} under churn "
                     f"in {rounds} rounds")
    best = min(reached, key=lambda r: r["rounds_to_target"])
    print(f"--> {best['policy']} reached AUROC {TARGET_AUROC} in "
          f"{best['rounds_to_target']} rounds despite join/corrupt/leave")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
