"""§Wire codec: bytes/round vs rounds-to-target tradeoff across codecs.

The same straggler cohort as ``benchmarks.participation_bench`` (C=16
ragged clients, K=4 uniform sampling), swept over the wire codecs of
``repro.core.codec`` (``none`` / ``int8`` / ``topk`` / ``int8_topk``).
For each codec the bench drives its own jitted sharded round — the
codec is STATIC round structure (a different program, like a different
optimizer), so the invariant is per-program: each codec's round must
compile exactly once across all its rounds. Measured per codec:

  - analytic wire bytes/round (``repro.core.codec.round_bytes``: K
    candidate uploads + K broadcast downloads of the model-group tree)
    and the compression ratio vs. the dense fp32 baseline;
  - rounds to reach a target validation multimodal AUROC (host-side
    eval of the blended global, outside the timed region) — the cost of
    compression in convergence currency;
  - bytes-to-target: the product, the number that actually matters on
    a metered uplink.

Emits ``BENCH_comm.json``. Acceptance: ``int8_topk`` cuts bytes/round
by >= 3.5x vs ``none`` while reaching the target AUROC within +2
rounds, and every codec's compile cache is exactly 1.

    PYTHONPATH=src python -m benchmarks.comm_bench [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from benchmarks.common import write_bench_json
from benchmarks.participation_bench import (
    K,
    N_CLIENTS,
    TARGET_AUROC,
    _straggler_clients,
)

TOPK_FRAC = 0.25


def _build(quick: bool):
    from repro.core.federation_sharded import ShardedFedSpec, batch_specs
    from repro.data.synthetic import make_task, train_val_test
    from repro.launch import shardings as sh
    from repro.launch.mesh import make_host_mesh

    task = make_task("smnist")
    rich_paired, rich_partial, strag = ((96, 48, 8) if quick
                                        else (160, 64, 8))
    need = (N_CLIENTS // 2) * (rich_paired + rich_partial + 2 * strag) + 64
    tr, va, _ = train_val_test(task, need, 512, 64, seed=0)
    clients, rows = _straggler_clients(task, tr, rich_paired, rich_partial,
                                       strag, seed=1)
    print(f"straggler cohort: per-client rows {sorted(rows)}")
    spec = ShardedFedSpec(
        n_clients=N_CLIENTS, d_hidden=32, n_layers=2, seq_a=task.seq_a,
        feat_a=task.feat_a, seq_b=task.seq_b, feat_b=task.feat_b,
        out_dim=task.out_dim, kind=task.kind, n_partial=rich_partial,
        n_frag=8, n_paired=rich_paired, n_val=512, lr=2e-2,
        optimizer="adamw", n_sampled=K, topk_frac=TOPK_FRAC)
    mesh = make_host_mesh()
    # batch shapes are codec-independent: one sharding set for the sweep
    shard = sh.batch_shardings(mesh, batch_specs(spec, ragged=True))
    val = {"val_a": va.x_a, "val_b": va.x_b, "val_y": va.y}
    return spec, clients, val, va, shard, mesh


def _run_codec(codec: str, spec0, clients, val, va, shard, mesh, rounds: int):
    from repro.core.codec import make_codec, round_bytes
    from repro.core.federation import eval_multimodal
    from repro.core.federation_sharded import (
        init_round_state, make_blendfl_round)
    from repro.data.pipeline import FederatedBatcher
    from repro.launch.train_federated import place_state

    spec = dataclasses.replace(spec0, codec=codec)
    round_fn = jax.jit(make_blendfl_round(spec))
    batcher = FederatedBatcher(clients, spec, val, seed=0, shardings=shard)
    state = place_state(init_round_state(jax.random.PRNGKey(0), spec), mesh)

    aurocs, to_target = [], None
    for r, batch in batcher.rounds(0, rounds):
        state, _ = round_fn(state, batch)
        g = state["global_models"]
        auc = eval_multimodal(g["f_A"], g["f_B"], g["g_M"], va.x_a, va.x_b,
                              va.y, spec.ecfg, spec.kind)
        aurocs.append(auc)
        if to_target is None and auc >= TARGET_AUROC:
            to_target = r + 1
    rb = round_bytes(state["global_models"],
                     make_codec(codec, spec.topk_frac), n_up=K, n_down=K)
    return {
        "codec": codec,
        "topk_frac": spec.topk_frac if codec in ("topk", "int8_topk") else None,
        "rounds_to_target": to_target,
        "target_auroc": TARGET_AUROC,
        "final_auroc": round(aurocs[-1], 4),
        "best_auroc": round(max(aurocs), 4),
        "bytes_per_round": rb["bytes_per_round"],
        "compression_ratio": round(rb["compression_ratio"], 3),
        "bytes_to_target": (None if to_target is None
                            else rb["bytes_per_round"] * to_target),
        "compile_cache": int(round_fn._cache_size()),
    }


def main(quick: bool = False) -> None:
    from repro.core.codec import CODECS

    print(f"\n=== wire codecs: straggler cohort, C={N_CLIENTS} K={K}, "
          f"topk_frac={TOPK_FRAC} ===")
    spec, clients, val, va, shard, mesh = _build(quick)
    rounds = 12 if quick else 24
    codecs = ("none", "int8_topk") if quick else CODECS

    print(f"{'codec':>10s} {'to_target':>9s} {'final':>7s} {'best':>7s} "
          f"{'MB/round':>9s} {'ratio':>6s}")
    records = []
    for c in codecs:
        rec = _run_codec(c, spec, clients, val, va, shard, mesh, rounds)
        records.append(rec)
        tt = "-" if rec["rounds_to_target"] is None else rec["rounds_to_target"]
        print(f"{c:>10s} {tt!s:>9s} {rec['final_auroc']:7.3f} "
              f"{rec['best_auroc']:7.3f} {rec['bytes_per_round']/1e6:9.3f} "
              f"{rec['compression_ratio']:6.2f}", flush=True)

    # record first, assert after: a failed acceptance still leaves the
    # measurement on disk for the next comparison
    write_bench_json("BENCH_comm.json",
                     {"bench": "comm_codec",
                      "backend": jax.default_backend(),
                      "n_clients": N_CLIENTS, "k": K, "rounds": rounds,
                      "topk_frac": TOPK_FRAC, "records": records})

    for rec in records:
        assert rec["compile_cache"] == 1, \
            f"codec {rec['codec']} retraced: cache {rec['compile_cache']}"
    by = {r["codec"]: r for r in records}
    ratio = by["int8_topk"]["compression_ratio"]
    assert ratio >= 3.5, \
        f"int8_topk compression {ratio}x < 3.5x vs none"
    none_rounds = by["none"]["rounds_to_target"] or (rounds + 1)
    it_rounds = by["int8_topk"]["rounds_to_target"]
    assert it_rounds is not None and it_rounds <= none_rounds + 2, \
        f"int8_topk took {it_rounds} rounds to AUROC {TARGET_AUROC} vs " \
        f"none's {none_rounds} (+2 budget)"
    print(f"--> int8_topk: {ratio:.1f}x fewer bytes/round, target AUROC in "
          f"{it_rounds} rounds vs none's "
          f"{by['none']['rounds_to_target'] or 'never'}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
