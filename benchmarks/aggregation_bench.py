"""§Aggregation strategies: drift-robust aggregation under non-IID skew.

Sweeps the ``repro.core.aggregate`` strategy family — blendavg, fedavg,
scaffold, fedprox, fedavg+server-adam — over two non-IID cohorts:

  - the **straggler** cohort from the participation bench (8 data-rich
    clients + 8 label-noise stragglers, C=16 / K=4 sampled rounds);
  - a **high-skew Dirichlet** cohort (``data.synthetic.dirichlet_cohort``
    at alpha=0.1: near-single-class clients with power-law sizes — the
    client-drift regime the SCAFFOLD/FedProx/FedOpt literature targets).

Each strategy drives its own jitted ``make_blendfl_round`` program (a
strategy is static round structure — switching strategies is a new
compiled round, never a retrace: every program's compile cache must end
at exactly 1) through the same ``FederatedBatcher`` stream and measures
rounds to a target validation multimodal AUROC (host-side
``repro.metrics.auroc``, evaluated outside the timed region) plus
per-round wall time.

Emits ``BENCH_aggregation.json``. Acceptance: every compile cache is 1,
and on the high-skew Dirichlet cohort at least one drift-robust strategy
(scaffold / fedprox / fedavg+server-adam / blendavg) reaches the target
in fewer rounds than plain fedavg.

Caveat worth keeping in mind when reading the table: the grid runs the
repo's default **adamw** clients, and SCAFFOLD's control variates
``(anchor - trained) / (steps * lr)`` assume SGD clients — under an
adaptive optimizer the implied-gradient scale is off by orders of
magnitude and the correction swamps the true gradients, so scaffold
*lags* here. With SGD clients (``optimizer="sgd", lr=0.15`` on this
same cohort) scaffold beats fedavg as the theory predicts (~0.71 vs
~0.66 AUROC at 16 rounds); the gating tests in tests/test_aggregate.py
pin the control-variate math itself against a numpy reference.

    PYTHONPATH=src python -m benchmarks.aggregation_bench [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks.common import write_bench_json

N_CLIENTS, K = 16, 4
TARGET_AUROC = 0.80
DIRICHLET_ALPHA = 0.1

# (record name, ShardedFedSpec strategy overrides)
STRATEGY_GRID = (
    ("blendavg", {"strategy": "blendavg"}),
    ("fedavg", {"strategy": "fedavg"}),
    ("scaffold", {"strategy": "scaffold"}),
    ("fedprox", {"strategy": "fedprox", "fedprox_mu": 0.01}),
    ("fedavg+adam", {"strategy": "fedavg", "server_opt": "adam",
                     "server_lr": 0.3}),
)
DRIFT_ROBUST = ("scaffold", "fedprox", "fedavg+adam", "blendavg")


def _straggler_cohort(task, quick: bool):
    from benchmarks.participation_bench import _straggler_clients
    from repro.data.synthetic import train_val_test

    rich_paired, rich_partial, strag = ((96, 48, 8) if quick
                                        else (160, 64, 8))
    need = (N_CLIENTS // 2) * (rich_paired + rich_partial + 2 * strag) + 64
    tr, va, _ = train_val_test(task, need, 512, 64, seed=0)
    clients, rows = _straggler_clients(task, tr, rich_paired, rich_partial,
                                       strag, seed=1)
    return clients, va, {"n_partial": rich_partial, "n_paired": rich_paired}


def _dirichlet_cohort(task, quick: bool):
    from repro.data.synthetic import dirichlet_cohort, train_val_test

    n_train = 1536 if quick else 2560
    tr, va, _ = train_val_test(task, n_train, 512, 64, seed=0)
    clients, sizes = dirichlet_cohort(tr, N_CLIENTS, DIRICHLET_ALPHA, seed=1)
    print(f"dirichlet cohort (alpha={DIRICHLET_ALPHA}): per-client rows "
          f"{sorted(sizes.tolist())}")
    return clients, va, {"n_partial": 48, "n_paired": 64}


def _make_spec(task, caps: dict, overrides: dict):
    from repro.core.federation_sharded import ShardedFedSpec

    return ShardedFedSpec(
        n_clients=N_CLIENTS, d_hidden=32, n_layers=2, seq_a=task.seq_a,
        feat_a=task.feat_a, seq_b=task.seq_b, feat_b=task.feat_b,
        out_dim=task.out_dim, kind=task.kind, n_frag=8, n_val=512,
        lr=2e-2, optimizer="adamw", n_sampled=K,
        n_partial=caps["n_partial"], n_paired=caps["n_paired"], **overrides)


def _run_strategy(name: str, spec, clients, va, mesh, rounds: int) -> dict:
    """One strategy's federation: its own jitted round program (compile
    excluded from the timed loop via a one-round warmup on a throwaway
    state) over the shared cohort's batch stream."""
    from repro.core.federation import eval_multimodal
    from repro.core.federation_sharded import (
        batch_specs, init_round_state, make_blendfl_round)
    from repro.data.pipeline import FederatedBatcher
    from repro.launch import shardings as sh
    from repro.launch.train_federated import place_state

    shard = sh.batch_shardings(mesh, batch_specs(spec, ragged=True))
    val = {"val_a": va.x_a, "val_b": va.x_b, "val_y": va.y}
    round_fn = jax.jit(make_blendfl_round(spec))
    batcher = FederatedBatcher(clients, spec, val, seed=0, shardings=shard)
    state = place_state(init_round_state(jax.random.PRNGKey(0), spec), mesh)
    for _, batch in batcher.rounds(0, 1, prefetch=0):  # warmup: compile
        jax.block_until_ready(round_fn(state, batch)[0])

    state = place_state(init_round_state(jax.random.PRNGKey(0), spec), mesh)
    batcher = FederatedBatcher(clients, spec, val, seed=0, shardings=shard)
    aurocs, eval_spent, to_target = [], 0.0, None
    t_loop = time.perf_counter()
    for r, batch in batcher.rounds(0, rounds):
        state, _ = round_fn(state, batch)
        jax.block_until_ready(state["global_models"])
        t0 = time.perf_counter()
        g = state["global_models"]
        auc = eval_multimodal(g["f_A"], g["f_B"], g["g_M"], va.x_a, va.x_b,
                              va.y, spec.ecfg, spec.kind)
        eval_spent += time.perf_counter() - t0
        aurocs.append(auc)
        if to_target is None and auc >= TARGET_AUROC:
            to_target = r + 1
    loop_spent = time.perf_counter() - t_loop
    return {
        "strategy": name,
        "rounds_to_target": to_target,
        "target_auroc": TARGET_AUROC,
        "final_auroc": round(aurocs[-1], 4),
        "best_auroc": round(max(aurocs), 4),
        "s_per_round": round((loop_spent - eval_spent) / rounds, 4),
        "compile_cache": int(round_fn._cache_size()),
    }


def main(quick: bool = False) -> None:
    from repro.data.synthetic import make_task
    from repro.launch.mesh import make_host_mesh

    task = make_task("smnist")
    mesh = make_host_mesh()
    rounds = 12 if quick else 16
    grid = (STRATEGY_GRID if not quick
            else tuple(g for g in STRATEGY_GRID
                       if g[0] in ("blendavg", "fedavg", "scaffold")))
    cohorts = (("dirichlet", _dirichlet_cohort),) if quick else (
        ("straggler", _straggler_cohort), ("dirichlet", _dirichlet_cohort))

    records = []
    for cohort_name, build in cohorts:
        clients, va, caps = build(task, quick)
        print(f"\n=== aggregation strategies: {cohort_name} cohort, "
              f"C={N_CLIENTS} K={K}, {rounds} rounds ===")
        print(f"{'strategy':>12s} {'to_target':>9s} {'final':>7s} "
              f"{'best':>7s} {'s/round':>8s}")
        for name, overrides in grid:
            spec = _make_spec(task, caps, overrides)
            rec = _run_strategy(name, spec, clients, va, mesh, rounds)
            rec["cohort"] = cohort_name
            records.append(rec)
            tt = ("-" if rec["rounds_to_target"] is None
                  else rec["rounds_to_target"])
            print(f"{name:>12s} {tt!s:>9s} {rec['final_auroc']:7.3f} "
                  f"{rec['best_auroc']:7.3f} {rec['s_per_round']:8.3f}",
                  flush=True)

    # record first, assert after: a failed acceptance still leaves the
    # measurement on disk for the next comparison
    write_bench_json("BENCH_aggregation.json",
                     {"bench": "aggregation",
                      "backend": jax.default_backend(),
                      "n_clients": N_CLIENTS, "k": K, "rounds": rounds,
                      "dirichlet_alpha": DIRICHLET_ALPHA,
                      "compile_cache": max(r["compile_cache"]
                                           for r in records),
                      "records": records})
    for r in records:
        assert r["compile_cache"] == 1, \
            f"{r['strategy']}/{r['cohort']}: round program retraced " \
            f"(cache {r['compile_cache']})"
    sk = [r for r in records if r["cohort"] == "dirichlet"]
    fedavg = next(r for r in sk if r["strategy"] == "fedavg")
    fed_rounds = (fedavg["rounds_to_target"]
                  if fedavg["rounds_to_target"] is not None else rounds + 1)
    robust = [r for r in sk if r["strategy"] in DRIFT_ROBUST
              and r["rounds_to_target"] is not None]
    best = min(robust, key=lambda r: r["rounds_to_target"], default=None)
    assert best is not None and best["rounds_to_target"] < fed_rounds, \
        f"no drift-robust strategy beat fedavg ({fed_rounds} rounds) to " \
        f"AUROC {TARGET_AUROC} on the alpha={DIRICHLET_ALPHA} cohort"
    print(f"\n--> {best['strategy']} reached AUROC {TARGET_AUROC} in "
          f"{best['rounds_to_target']} rounds vs fedavg's "
          f"{fedavg['rounds_to_target'] or 'never'} on the high-skew cohort")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
