"""§Sampled rounds: per-round wall time scales with K, not C.

A K-of-C sampled round gathers the K sampled clients' rows of every
stacked model/opt/batch leaf and runs the same compiled phase programs at
leading axis K — so its per-round cost should track K while full
participation tracks C. Measures, at C = 16 in-host clients:

  - wall-clock per round at full participation (K = C) and at
    K ∈ {8, 4}, same data, same engine config;
  - the compile-cache size of each phase after 3 sampled rounds over
    DIFFERENT subsets (must stay 1 — sampled ids are data, not shape).

Emits ``BENCH_sampled_round.json`` next to the other results. The
acceptance target: K=4 per-round time ≤ ~40% of the full round.

    PYTHONPATH=src python -m benchmarks.sampled_round_bench [--quick]
"""
from __future__ import annotations

import time

import jax


def _make_fed(n_sampled: int, quick: bool):
    from repro.core.encoders import EncoderConfig
    from repro.core.federation import FedConfig, Federation
    from repro.core.partitioner import partition
    from repro.data.synthetic import make_task, train_val_test

    spec = make_task("smnist")
    # enough rows/width that the training phases (the part that scales
    # with K) dominate the fixed per-round aggregation cost, as they do
    # at production scale
    n_train = 3200 if quick else 6400
    tr, va, _ = train_val_test(spec, n_train, 200, 100, seed=0)
    clients = partition(tr, 16, seed=1)
    ecfg = EncoderConfig(d_hidden=64, n_layers=2, enc_type="mlp")
    cfg = FedConfig(n_clients=16, rounds=8, lr=1e-2, batch_size=64, seed=0,
                    n_sampled=n_sampled, async_mode=bool(n_sampled))
    return Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)


def _bench_one(n_sampled: int, quick: bool) -> dict:
    fed = _make_fed(n_sampled, quick)
    reps = 3 if quick else 6
    fed.round()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fed.round()
    dt = (time.perf_counter() - t0) / reps
    return {
        "K": n_sampled or 16,
        "mode": "sampled" if n_sampled else "full",
        "s_per_round": round(dt, 4),
        "caches": [int(fed.engine.unimodal_phase._cache_size()),
                   int(fed.engine.vfl_phase._cache_size()),
                   int(fed.engine.paired_phase._cache_size())],
    }


def main(quick: bool = False) -> None:
    print("\n=== sampled rounds: per-round time scales with K, not C=16 ===")
    records = [_bench_one(k, quick) for k in (0, 8, 4)]
    t_full = records[0]["s_per_round"]
    print(f"{'K':>3s} {'mode':>8s} {'s_per_round':>12s} {'vs_full':>8s} {'caches':>9s}")
    for r in records:
        r["frac_of_full"] = round(r["s_per_round"] / max(t_full, 1e-9), 3)
        print(f"{r['K']:3d} {r['mode']:>8s} {r['s_per_round']:12.3f} "
              f"{r['frac_of_full']:8.2f} {str(r['caches']):>9s}")
    # record first, assert after: a cache regression must still leave
    # the measurement on disk for the next run to compare against
    from benchmarks.common import write_bench_json

    write_bench_json("BENCH_sampled_round.json",
                     {"bench": "sampled_round", "backend": jax.default_backend(),
                      "n_clients": 16, "records": records})
    for r in records:
        assert r["caches"] == [1, 1, 1], \
            "sampled rounds must reuse the one compiled program per phase"
    k4 = records[-1]["frac_of_full"]
    print(f"--> K=4 round at {k4:.0%} of the full-participation round")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
