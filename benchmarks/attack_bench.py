"""§Byzantine attacks × robust defenses: the BlendAvg robustness matrix.

Sweeps every gradient-space scenario attack (``repro.data.scenario``:
none / sign_flip / scale / backdoor, two adversaries among the rich
clients) against every defense in the ``repro.core.aggregate`` strategy
family (blendavg / fedavg / median / trimmed_mean / krum) on the
participation bench's straggler cohort (8 rich + 8 label-noise clients,
C=16 / K=4 sampled rounds).

Per defense there is exactly ONE jitted round program shared by all four
attack arms: the attack membership is scenario data (the ``attack_coef``
batch vector), so switching attacks must never retrace — every
defense's compile cache is asserted to end at 1 across the whole sweep.
The attack is applied before the uplink (where a codec would sit);
defenses aggregate what the server receives.

Per cell the bench reports rounds to a target validation multimodal
AUROC (host-side ``repro.metrics.auroc``, evaluated outside the timed
region) and the **backdoor success rate**: the fraction of triggered
validation inputs (``scenario.apply_trigger`` on both modalities) the
final global model classifies as the attacker's target class, measured
over rows whose true label is NOT the target.

Emits ``BENCH_attack.json``. Acceptance: every compile cache is 1, at
least one attacked cell where a robust defense beats the volume-weighted
fedavg baseline (fewer rounds to target, clearly higher best AUROC, or a
clearly lower backdoor success rate), and at least one attacked cell
where blendavg's Eq. 9-10 performance weighting already suffices on its
own (still reaches the target, or holds its own unattacked AUROC).

A finding this matrix pins rather than assumes: blendavg's improvement
filter (candidates must beat the current global on server validation to
earn any omega; nothing-improves keeps the old global) is itself a
strong Byzantine defense — it zeroes sign-flipped, boosted, AND
accuracy-degrading poisoned candidates, so no robust reducer beats
blendavg in any attacked cell here. The reducers earn their keep
against fedavg (which happily averages whatever volume shows up:
backdoor success collapses from ~0.9 to ~0.5-0.6 under median / trimmed
mean), and blendavg's filter is only as trustworthy as the server's
validation set — the geometric defenses assume nothing about it.

    PYTHONPATH=src python -m benchmarks.attack_bench [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import write_bench_json

N_CLIENTS, K = 16, 4
TARGET_AUROC = 0.80
N_MALICIOUS = 1  # the defenses' assumed per-round attacker budget f
# gradient-space attackers are rich clients (clean data, big updates —
# the most damaging compromise). The backdoor arm compromises twice as
# many: stealthy data poisoning needs sustained participation to
# implant under K-of-C sampling, while a single sign-flipper already
# shows up in gradient space every round it is drawn.
UPLINK_ATTACKER_IDS = (0, 1)
BACKDOOR_ATTACKER_IDS = (0, 1, 2, 3)

# (record name, scenario Event kwargs for the round-1 attack event)
ATTACK_GRID = (
    ("none", {}),
    ("sign_flip", {"sign_flip": UPLINK_ATTACKER_IDS}),
    ("scale", {"scale": UPLINK_ATTACKER_IDS}),
    ("backdoor", {"backdoor": BACKDOOR_ATTACKER_IDS}),
)
# (record name, ShardedFedSpec strategy overrides)
DEFENSE_GRID = (
    ("blendavg", {"strategy": "blendavg"}),
    ("fedavg", {"strategy": "fedavg"}),
    ("median", {"strategy": "median"}),
    ("trimmed_mean", {"strategy": "trimmed_mean"}),
    ("krum", {"strategy": "krum"}),
)
ROBUST = ("median", "trimmed_mean", "krum")


def _straggler_cohort(task, quick: bool):
    from benchmarks.participation_bench import _straggler_clients
    from repro.data.synthetic import train_val_test

    rich_paired, rich_partial, strag = ((96, 48, 8) if quick
                                        else (160, 64, 8))
    need = (N_CLIENTS // 2) * (rich_paired + rich_partial + 2 * strag) + 64
    tr, va, _ = train_val_test(task, need, 512, 64, seed=0)
    clients, _ = _straggler_clients(task, tr, rich_paired, rich_partial,
                                    strag, seed=1)
    return clients, va, {"n_partial": rich_partial, "n_paired": rich_paired}


def _make_spec(task, caps: dict, overrides: dict):
    from repro.core.federation_sharded import ShardedFedSpec

    # attacks=True for EVERY arm (the none arm ships an all-ones
    # attack_coef), so each defense's single compiled round covers the
    # whole attack axis
    return ShardedFedSpec(
        n_clients=N_CLIENTS, d_hidden=32, n_layers=2, seq_a=task.seq_a,
        feat_a=task.feat_a, seq_b=task.seq_b, feat_b=task.feat_b,
        out_dim=task.out_dim, kind=task.kind, n_frag=8, n_val=512,
        lr=2e-2, optimizer="adamw", n_sampled=K, attacks=True,
        n_malicious=N_MALICIOUS, n_partial=caps["n_partial"],
        n_paired=caps["n_paired"], **overrides)


def _attack_scenario(event_kwargs: dict):
    from repro.data.scenario import Event, Scenario

    events = (Event(round=1, **event_kwargs),) if event_kwargs else ()
    return Scenario(events).validate(N_CLIENTS)


def _backdoor_success(g, va, spec) -> float:
    """Fraction of trigger-stamped validation inputs the global model
    maps to the attacker's target class, over rows whose true label is
    a different class (the standard targeted-attack success metric)."""
    from repro.core.encoders import fusion_apply, task_scores
    from repro.core.federation import _client_fwd
    from repro.data.scenario import apply_trigger, backdoor_target

    xa = apply_trigger(np.asarray(va.x_a))
    xb = apply_trigger(np.asarray(va.x_b))
    h_a = _client_fwd(g["f_A"], jnp.asarray(xa), ecfg=spec.ecfg)
    h_b = _client_fwd(g["f_B"], jnp.asarray(xb), ecfg=spec.ecfg)
    scores = np.asarray(task_scores(fusion_apply(g["g_M"], h_a, h_b),
                                    spec.kind))
    target = int(np.argmax(backdoor_target(spec.kind, spec.out_dim)))
    y = np.asarray(va.y)
    rows = y.argmax(-1) != target
    return float(np.mean(scores[rows].argmax(-1) == target))


def _run_cell(attack: str, event_kwargs: dict, spec, round_fn, clients, va,
              mesh, rounds: int) -> dict:
    """One (attack, defense) cell over the shared cohort and seed. The
    scenario batcher is driven round-by-round (attack membership is a
    round-indexed query); the round program arrives pre-compiled and
    shared across the defense's four attack arms."""
    from repro.core.federation import eval_multimodal
    from repro.core.federation_sharded import batch_specs, init_round_state
    from repro.data.pipeline import FederatedBatcher
    from repro.launch import shardings as sh
    from repro.launch.train_federated import place_state

    shard = sh.batch_shardings(mesh, batch_specs(spec, ragged=True))
    val = {"val_a": va.x_a, "val_b": va.x_b, "val_y": va.y}
    batcher = FederatedBatcher(clients, spec, val, seed=0, shardings=shard,
                               scenario=_attack_scenario(event_kwargs),
                               n_initial=N_CLIENTS)
    state = place_state(init_round_state(jax.random.PRNGKey(0), spec), mesh)
    aurocs, eval_spent, to_target = [], 0.0, None
    t_loop = time.perf_counter()
    for r in range(rounds):
        batch = batcher.put(batcher.build(r))
        state, _ = round_fn(state, batch)
        jax.block_until_ready(state["global_models"])
        t0 = time.perf_counter()
        g = state["global_models"]
        auc = eval_multimodal(g["f_A"], g["f_B"], g["g_M"], va.x_a, va.x_b,
                              va.y, spec.ecfg, spec.kind)
        eval_spent += time.perf_counter() - t0
        aurocs.append(auc)
        if to_target is None and auc >= TARGET_AUROC:
            to_target = r + 1
    loop_spent = time.perf_counter() - t_loop
    return {
        "attack": attack,
        "rounds_to_target": to_target,
        "target_auroc": TARGET_AUROC,
        "final_auroc": round(aurocs[-1], 4),
        "best_auroc": round(max(aurocs), 4),
        "backdoor_success_rate": round(
            _backdoor_success(state["global_models"], va, spec), 4),
        "s_per_round": round((loop_spent - eval_spent) / rounds, 4),
    }


def _beats(cell: dict, base: dict, rounds: int) -> bool:
    """Did a defense's cell beat the baseline's under the same attack?
    Any of: fewer rounds to target, clearly higher best AUROC, or (the
    score-invisible attack) a clearly lower backdoor success rate."""
    rtt = lambda c: (c["rounds_to_target"] if c["rounds_to_target"]
                     is not None else rounds + 1)
    return (rtt(cell) < rtt(base)
            or cell["best_auroc"] > base["best_auroc"] + 0.02
            or (cell["attack"] == "backdoor"
                and cell["backdoor_success_rate"] + 0.10
                < base["backdoor_success_rate"]))


def main(quick: bool = False) -> None:
    from repro.data.synthetic import make_task
    from repro.launch.mesh import make_host_mesh

    task = make_task("smnist")
    mesh = make_host_mesh()
    rounds = 10 if quick else 16
    clients, va, caps = _straggler_cohort(task, quick)

    from repro.core.federation_sharded import (
        batch_specs, init_round_state, make_blendfl_round)
    from repro.data.pipeline import FederatedBatcher
    from repro.launch import shardings as sh
    from repro.launch.train_federated import place_state

    records = []
    for defense, overrides in DEFENSE_GRID:
        spec = _make_spec(task, caps, overrides)
        round_fn = jax.jit(make_blendfl_round(spec))
        # warmup: compile the defense's round once on a throwaway state so
        # its first cell's s_per_round doesn't carry the compile
        shard = sh.batch_shardings(mesh, batch_specs(spec, ragged=True))
        wb = FederatedBatcher(clients, spec,
                              {"val_a": va.x_a, "val_b": va.x_b,
                               "val_y": va.y},
                              seed=0, shardings=shard)
        wstate = place_state(init_round_state(jax.random.PRNGKey(0), spec),
                             mesh)
        for _, batch in wb.rounds(0, 1, prefetch=0):
            jax.block_until_ready(round_fn(wstate, batch)[0])
        print(f"\n=== defense {defense}: C={N_CLIENTS} K={K}, {rounds} "
              f"rounds, uplink attackers {list(UPLINK_ATTACKER_IDS)}, "
              f"backdoor {list(BACKDOOR_ATTACKER_IDS)} ===")
        print(f"{'attack':>10s} {'to_target':>9s} {'final':>7s} "
              f"{'best':>7s} {'bdoor':>6s} {'s/round':>8s}")
        for attack, event_kwargs in ATTACK_GRID:
            rec = _run_cell(attack, event_kwargs, spec, round_fn, clients,
                            va, mesh, rounds)
            rec["defense"] = defense
            rec["n_attackers"] = sum(len(v) for v in event_kwargs.values())
            rec["compile_cache"] = int(round_fn._cache_size())
            records.append(rec)
            tt = ("-" if rec["rounds_to_target"] is None
                  else rec["rounds_to_target"])
            print(f"{attack:>10s} {tt!s:>9s} {rec['final_auroc']:7.3f} "
                  f"{rec['best_auroc']:7.3f} "
                  f"{rec['backdoor_success_rate']:6.3f} "
                  f"{rec['s_per_round']:8.3f}", flush=True)

    # record first, assert after: a failed acceptance still leaves the
    # measurement on disk for the next comparison
    write_bench_json("BENCH_attack.json",
                     {"bench": "attack",
                      "backend": jax.default_backend(),
                      "n_clients": N_CLIENTS, "k": K, "rounds": rounds,
                      "n_malicious": N_MALICIOUS,
                      "quick": quick,
                      "compile_cache": max(r["compile_cache"]
                                           for r in records),
                      "records": records})

    by = {(r["defense"], r["attack"]): r for r in records}
    for r in records:
        assert r["compile_cache"] == 1, \
            f"{r['defense']}/{r['attack']}: round program retraced " \
            f"(cache {r['compile_cache']}) — the attack axis must be data"
    wins = [(d, a) for d in ROBUST for a, _ in ATTACK_GRID if a != "none"
            and _beats(by[(d, a)], by[("fedavg", a)], rounds)]
    assert wins, ("no robust defense beat volume-weighted fedavg in any "
                  "attacked cell — the matrix shows no defense value")
    # blendavg "suffices" under an attack when it still reaches the
    # target, or holds its own unattacked best AUROC (candidates that
    # stop improving the server-val score earn omega 0 and drop out)
    clean = by[("blendavg", "none")]["best_auroc"]
    holds = [a for a, _ in ATTACK_GRID if a != "none"
             and (by[("blendavg", a)]["rounds_to_target"] is not None
                  or by[("blendavg", a)]["best_auroc"] >= clean - 0.02)]
    assert holds, ("blendavg collapsed under every attack — expected its "
                   "performance weighting to absorb at least one")
    print(f"\n--> robust wins over fedavg in cells {wins}; "
          f"blendavg's own improvement filter suffices under {holds}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
