"""§Client store: peak host RSS stays flat as the dataset outgrows RAM.

The out-of-core claim of ``repro.data.store.ClientStore``: a store-backed
``FederatedBatcher`` materializes only the drawn row subsets per round —
O(K*N*row_bytes) — so a federation's peak host RSS is independent of the
TOTAL dataset size, while the in-memory loader's RSS grows linearly with
it.

Protocol: for each total-rows scale in {1x, 2x, 4x} (K*N, C, and the
model held fixed) this driver

  1. imports the synthetic partition into an on-disk store in a throwaway
     subprocess (``repro.launch.train_federated import``), then
  2. runs one measuring subprocess per (mode, scale): ``--child`` builds
     the federation (mode ``inmem`` generates + holds the arrays in RAM;
     mode ``store`` opens the store) and drives real rounds through the
     jitted sharded round, reporting its own lifetime
     ``resource.getrusage`` high-water mark.

Fresh processes are the only honest way to compare RSS high-water marks:
``ru_maxrss`` never decreases, so measuring both modes (or two scales) in
one process would let the largest configuration mask all the others.

Acceptance (recorded, then asserted — the JSON always lands):
``store_rss_growth`` (max-scale RSS / 1x RSS, store mode) stays ~flat
(< 1.25) while ``inmem_rss_growth`` grows with the data; batches remain
bit-identical between the two modes by construction (see
``tests/test_store.py``).

    PYTHONPATH=src python -m benchmarks.client_store_bench [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

_MARK = "@@CLIENT_STORE_RESULT "


# ------------------------------------------------------------------ child --

def _child(mode: str, scale: int, args) -> None:
    """One measuring process: build the federation, run rounds, report
    lifetime max RSS. Printed as a marked JSON line for the parent."""
    import argparse as _ap

    import jax

    from benchmarks.common import max_rss_mb
    from repro.launch.train_federated import build_federation, place_state
    from repro.core.federation_sharded import init_round_state

    ns = _ap.Namespace(
        task="smnist", clients=args.clients, n_sampled=0,
        n_train=args.base_rows * scale, n_val=256, rows_cap=args.rows_cap,
        d_hidden=32, n_layers=1, lr=1e-2, optimizer="adamw",
        dirichlet_alpha=None, seed=0, data_seed=0, prefetch=1,
        store_dir=args.store_dir if mode == "store" else None)
    spec, batcher, round_fn, mesh = build_federation(ns)
    state = place_state(init_round_state(jax.random.PRNGKey(0), spec), mesh)
    # warmup round compiles; timed rounds then measure steady state
    for _, batch in batcher.rounds(0, 1, prefetch=0):
        state, _ = round_fn(state, batch)
    jax.block_until_ready(state)
    t0 = time.perf_counter()
    for _, batch in batcher.rounds(1, 1 + args.rounds):
        state, _ = round_fn(state, batch)
    jax.block_until_ready(state)
    rec = {
        "mode": mode, "scale": scale, "total_rows": ns.n_train,
        "max_rss_mb": round(max_rss_mb(), 1),
        "s_per_round": round((time.perf_counter() - t0) / args.rounds, 4),
        "compile_cache": int(round_fn._cache_size()),
    }
    print(_MARK + json.dumps(rec), flush=True)


# ----------------------------------------------------------------- parent --

def _spawn(argv: list[str]) -> str:
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(root, "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    proc = subprocess.run([sys.executable, *argv], env=env, cwd=root,
                          capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(f"child {argv} failed:\n{proc.stdout}\n{proc.stderr}")
    return proc.stdout


def _run_child(mode: str, scale: int, args) -> dict:
    out = _spawn(["-m", "benchmarks.client_store_bench", "--child",
                  "--mode", mode, "--scale", str(scale),
                  "--store-dir", args.store_dir or "",
                  "--clients", str(args.clients),
                  "--base-rows", str(args.base_rows),
                  "--rows-cap", str(args.rows_cap),
                  "--rounds", str(args.rounds)])
    for line in out.splitlines():
        if line.startswith(_MARK):
            return json.loads(line[len(_MARK):])
    raise RuntimeError(f"no result line in child output:\n{out}")


def main(quick: bool = False, args=None) -> None:
    import jax  # backend tag only; the measurements live in the children

    from benchmarks.common import write_bench_json

    # CLI overrides win; unset fields fall back to quick-aware defaults
    defaults = dict(clients=8 if quick else 16,
                    base_rows=4096 if quick else 16384,
                    rows_cap=32, rounds=2 if quick else 3, store_dir=None)
    if args is None:
        args = argparse.Namespace(**defaults)
    for k, v in defaults.items():
        if getattr(args, k, None) is None:
            setattr(args, k, v)
    scales = (1, 2) if quick else (1, 2, 4)
    print("\n=== client store: flat RSS as total rows grow "
          f"{scales[-1]}x (C={args.clients}, K*N fixed) ===")

    records = []
    with tempfile.TemporaryDirectory(prefix="client_store_bench_") as tmp:
        for scale in scales:
            store_dir = os.path.join(tmp, f"store_{scale}x")
            # import in a throwaway process: the converter materializes
            # the full dataset, which must not pollute any measurement
            _spawn(["-m", "repro.launch.train_federated", "import",
                    "--store-dir", store_dir,
                    "--clients", str(args.clients),
                    "--n-train", str(args.base_rows * scale),
                    "--n-val", "256"])
            for mode in ("inmem", "store"):
                cargs = argparse.Namespace(**{**vars(args),
                                              "store_dir": store_dir})
                records.append(_run_child(mode, scale, cargs))
                r = records[-1]
                print(f"{r['mode']:>6s} {r['scale']}x rows={r['total_rows']:6d} "
                      f"maxrss {r['max_rss_mb']:7.1f} MiB  "
                      f"{r['s_per_round']:.3f}s/round  cache {r['compile_cache']}")

    def _growth(mode: str) -> float:
        rss = {r["scale"]: r["max_rss_mb"] for r in records if r["mode"] == mode}
        return round(rss[scales[-1]] / rss[scales[0]], 3)

    summary = {"store_rss_growth": _growth("store"),
               "inmem_rss_growth": _growth("inmem"),
               "scales": list(scales)}
    print(f"--> RSS growth {scales[0]}x -> {scales[-1]}x: "
          f"store {summary['store_rss_growth']}x, "
          f"inmem {summary['inmem_rss_growth']}x")
    # emit before asserting: a failed acceptance still leaves evidence
    write_bench_json("BENCH_client_store.json",
                     {"bench": "client_store",
                      "backend": jax.default_backend(),
                      "n_clients": args.clients, "rows_cap": args.rows_cap,
                      "records": records, "summary": summary})
    assert all(r["compile_cache"] == 1 for r in records), \
        "store-backed rounds must reuse the one compiled program"
    if summary["store_rss_growth"] > 1.25:
        print(f"WARNING: store-backed RSS grew {summary['store_rss_growth']}x "
              f"across a {scales[-1]}x dataset (target ~flat, < 1.25x)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--mode", choices=["inmem", "store"])
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--store-dir", default=None)
    ap.add_argument("--clients", type=int, default=None)
    ap.add_argument("--base-rows", type=int, default=None)
    ap.add_argument("--rows-cap", type=int, default=None)
    ap.add_argument("--rounds", type=int, default=None)
    cli = ap.parse_args()
    if cli.child:
        _child(cli.mode, cli.scale, cli)
    else:
        main(quick=cli.quick, args=cli)
