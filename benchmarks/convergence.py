"""Fig. 2: convergence speedup of BlendAvg over FedAvg.

Measures rounds needed to reach a target multimodal AUROC for both
aggregators at varying local-epoch intervals under non-IID clients
(Dirichlet label skew — the heterogeneous setting BlendAvg is built for:
performance-weighting discards degrading client updates).

    Speedup = rounds_to_target(FedAvg) / rounds_to_target(BlendAvg)

Paper: speedup grows with the interval, peaking ~46% at 6 local epochs.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import ExpConfig, run_blendfl, setup


def rounds_to_target(history, target: float):
    for h in history:
        if h["multimodal_auroc"] >= target:
            return h["round"] + 1
    return None


def run(intervals=(1, 2, 4, 6), target: float = 0.78, rounds: int = 60,
        seeds=(0, 1), alpha: float = 0.3):
    print(f"target multimodal AUROC = {target}, dirichlet alpha = {alpha}")
    print(f"{'interval':>8s} {'fedavg':>8s} {'blendavg':>9s} {'speedup':>8s}")
    rows = []
    for k in intervals:
        per = {"fedavg": [], "blendavg": []}
        for seed in seeds:
            exp = ExpConfig(task="smnist", rounds=rounds, seed=seed,
                            dirichlet_alpha=alpha)
            te = setup(exp)[3]
            for agg in per:
                _, hist, _ = run_blendfl(exp, history_test=te, aggregator=agg,
                                         local_epochs=k)
                r = rounds_to_target(hist, target)
                per[agg].append(r if r is not None else rounds * 2)  # censored
        nf = float(np.mean(per["fedavg"]))
        nb = float(np.mean(per["blendavg"]))
        speedup = nf / nb
        rows.append({"local_epochs": k, "rounds_fedavg": nf,
                     "rounds_blendavg": nb, "speedup": round(speedup, 3),
                     "target_auroc": target})
        print(f"{k:8d} {nf:8.1f} {nb:9.1f} {speedup:8.2f}", flush=True)
    return rows


def main(quick: bool = False) -> None:
    import jax

    from benchmarks.common import write_bench_json

    print("\n=== Fig. 2: BlendAvg vs FedAvg convergence (non-IID) ===")
    if quick:
        rows = run(intervals=(1, 4), target=0.72, rounds=25, seeds=(0,))
    else:
        rows = run()
    write_bench_json("BENCH_convergence.json",
                     {"bench": "convergence", "backend": jax.default_backend(),
                      "quick": quick, "records": rows})


if __name__ == "__main__":
    main()
