"""§Round engine: legacy per-client loop vs the stacked engine.

Measures, at C ∈ {3, 8, 16} clients:
  - wall-clock per ``blendfl_round`` (training phases 1-3; aggregation is
    identical between the two drivers and host-metric bound),
  - jit compile-cache growth for the unimodal step: the legacy loop keys a
    cache entry per (modality, batch shape) and re-dispatches per client
    per batch; the engine compiles ONE program per phase (clients are a
    stacked axis, batches a lax.scan) and syncs one scalar per phase.

Emits a ``BENCH_round_engine.json`` record next to the other results.

    PYTHONPATH=src python -m benchmarks.round_engine_bench [--quick]
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _legacy_training_round(models, server_gmv, clients, ecfg, kind, lr, bs, rng):
    """The seed repo's phases 1-3: Python loops, per-client jit dispatches,
    per-batch float(loss) host syncs. Reconstructed from the per-client
    steps the baselines still use."""
    from repro.core import vfl
    from repro.core.baselines import (
        _client_bwd_update,
        _client_fwd,
        _paired_sgd_step,
        _server_fwd_bwd,
        _unimodal_sgd_step,
    )

    losses = []
    for k, cd in enumerate(clients):
        for mod, view in (("A", cd.all_a()), ("B", cd.all_b())):
            if len(view) == 0:
                continue
            f, g = models[k][f"f_{mod}"], models[k][f"g_{mod}"]
            idx = rng.permutation(len(view))
            for i in range(0, len(idx), bs):
                sel = idx[i : i + bs]
                f, g, loss = _unimodal_sgd_step(
                    f, g, jnp.asarray(view.x[sel]), jnp.asarray(view.y[sel]),
                    ecfg=ecfg, kind=kind, lr=lr, modality=mod)
                losses.append(float(loss))  # the legacy per-batch host sync
            models[k][f"f_{mod}"], models[k][f"g_{mod}"] = f, g

    for batch in vfl.build_vfl_batches(clients, 10**9, rng):
        x_a, x_b = jnp.asarray(batch.x_a), jnp.asarray(batch.x_b)
        n = len(batch.y)
        h_a = jnp.zeros((n, ecfg.d_hidden), jnp.float32)
        h_b = jnp.zeros((n, ecfg.d_hidden), jnp.float32)
        for k in range(len(clients)):
            ra = np.nonzero(batch.owner_a == k)[0]
            rb = np.nonzero(batch.owner_b == k)[0]
            if len(ra):
                h_a = h_a.at[ra].set(_client_fwd(models[k]["f_A"], x_a[ra], ecfg=ecfg))
            if len(rb):
                h_b = h_b.at[rb].set(_client_fwd(models[k]["f_B"], x_b[rb], ecfg=ecfg))
        loss, g_srv, g_ha, g_hb = _server_fwd_bwd(
            server_gmv, h_a, h_b, jnp.asarray(batch.y), kind=kind)
        server_gmv = jax.tree.map(lambda p, g: p - lr * g, server_gmv, g_srv)
        for k in range(len(clients)):
            ra = np.nonzero(batch.owner_a == k)[0]
            rb = np.nonzero(batch.owner_b == k)[0]
            if len(ra):
                models[k]["f_A"] = _client_bwd_update(
                    models[k]["f_A"], x_a[ra], g_ha[ra], ecfg=ecfg, lr=lr)
            if len(rb):
                models[k]["f_B"] = _client_bwd_update(
                    models[k]["f_B"], x_b[rb], g_hb[rb], ecfg=ecfg, lr=lr)
        losses.append(float(loss))

    for k, cd in enumerate(clients):
        if not cd.has_paired:
            continue
        m = models[k]
        idx = rng.permutation(len(cd.paired_a))
        for i in range(0, len(idx), bs):
            sel = idx[i : i + bs]
            m["f_A"], m["f_B"], m["g_M"], loss = _paired_sgd_step(
                m["f_A"], m["f_B"], m["g_M"],
                jnp.asarray(cd.paired_a.x[sel]), jnp.asarray(cd.paired_b.x[sel]),
                jnp.asarray(cd.paired_a.y[sel]), ecfg=ecfg, kind=kind, lr=lr)
            losses.append(float(loss))
    return models, server_gmv, losses


def _bench_one(n_clients: int, quick: bool) -> dict:
    from repro.core.baselines import _unimodal_sgd_step
    from repro.core.encoders import EncoderConfig, init_client_models
    from repro.core.federation import FedConfig, Federation
    from repro.core.partitioner import partition
    from repro.data.synthetic import make_task, train_val_test

    spec = make_task("smnist")
    n_train = 600 if quick else 1500
    tr, va, _ = train_val_test(spec, n_train, 200, 100, seed=0)
    clients = partition(tr, n_clients, seed=1)
    ecfg = EncoderConfig(d_hidden=48, n_layers=2, enc_type="mlp")
    cfg = FedConfig(n_clients=n_clients, rounds=3, lr=1e-2, batch_size=64, seed=0)
    reps = 2 if quick else 4

    # ---- stacked engine ----
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)

    def engine_round():
        fed._unimodal_phase()
        fed._vfl_phase()
        fed._paired_phase()

    engine_round()  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        engine_round()
    t_engine = (time.perf_counter() - t0) / reps
    engine_cache = int(fed.engine.unimodal_phase._cache_size())

    # ---- legacy per-client loop ----
    _unimodal_sgd_step._clear_cache()
    base = init_client_models(jax.random.PRNGKey(0), spec, ecfg)
    models = [jax.tree.map(jnp.copy, base) for _ in clients]
    gmv = jax.tree.map(jnp.copy, base["g_M"])
    rng = np.random.default_rng(0)
    models, gmv, _ = _legacy_training_round(
        models, gmv, clients, ecfg, spec.kind, cfg.lr, cfg.batch_size, rng)
    t0 = time.perf_counter()
    for _ in range(reps):
        models, gmv, _ = _legacy_training_round(
            models, gmv, clients, ecfg, spec.kind, cfg.lr, cfg.batch_size, rng)
    t_legacy = (time.perf_counter() - t0) / reps
    legacy_cache = int(_unimodal_sgd_step._cache_size())

    return {
        "n_clients": n_clients,
        "s_per_round_engine": round(t_engine, 4),
        "s_per_round_legacy": round(t_legacy, 4),
        "speedup": round(t_legacy / max(t_engine, 1e-9), 2),
        "unimodal_compile_cache_engine": engine_cache,
        "unimodal_compile_cache_legacy": legacy_cache,
    }


def main(quick: bool = False) -> None:
    print("\n=== round engine: stacked phases vs legacy per-client loop ===")
    sizes = (3, 8) if quick else (3, 8, 16)
    records = []
    hdr = (f"{'C':>3s} {'engine_s':>9s} {'legacy_s':>9s} {'speedup':>8s} "
           f"{'cache_eng':>9s} {'cache_leg':>9s}")
    print(hdr)
    for c in sizes:
        r = _bench_one(c, quick)
        records.append(r)
        print(f"{r['n_clients']:3d} {r['s_per_round_engine']:9.3f} "
              f"{r['s_per_round_legacy']:9.3f} {r['speedup']:8.2f} "
              f"{r['unimodal_compile_cache_engine']:9d} "
              f"{r['unimodal_compile_cache_legacy']:9d}")
    # record first, assert after: a cache regression must still leave
    # the measurement on disk for the next run to compare against
    from benchmarks.common import write_bench_json

    write_bench_json("BENCH_round_engine.json",
                     {"bench": "round_engine", "backend": jax.default_backend(),
                      "records": records})
    for r in records:
        assert r["unimodal_compile_cache_engine"] == 1, \
            "engine must compile the unimodal phase exactly once"
    print("--> one compiled program per phase regardless of C")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
