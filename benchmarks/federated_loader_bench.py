"""§Federated loader: ragged C=32 rounds, prefetch hides host build time.

Pushes the federated batch loader past PR 2's 16 in-host clients: a
C=32 ragged federation (partitioned synthetic multimodal data, per-client
row counts heterogeneous by construction) drives the sharded
``make_blendfl_round`` through ``FederatedBatcher``. Measures:

  - rounds/sec with the double-buffered prefetch worker OFF and ON
    (same jitted round function, same batch stream);
  - mean host batch-build seconds per round, and the fraction of that
    build time the prefetch overlap hides. Hidden time is measured
    directly — ``stall_seconds`` is how long the consumer actually
    blocked waiting for a staged batch, so
        hidden = 1 - stall / build
    (robust to wall-clock noise on a shared host; acceptance: >= 50%);
  - the compile-cache size of the jitted round after both sweeps (must
    stay 1: masks/weights/ids are data, not shape).

Emits ``BENCH_federated_loader.json`` next to the other results.

    PYTHONPATH=src python -m benchmarks.federated_loader_bench [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def _build(quick: bool):
    from repro.core.federation_sharded import (
        ShardedFedSpec, batch_specs, init_round_state, make_blendfl_round)
    from repro.core.partitioner import partition
    from repro.data.pipeline import FederatedBatcher
    from repro.data.synthetic import make_task, train_val_test
    from repro.launch import shardings as sh
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train_federated import client_arrays, place_state

    task = make_task("smnist")
    n_train = 8192 if quick else 16384
    tr, va, _ = train_val_test(task, n_train, 512, 64, seed=0)
    clients = partition(tr, 32, seed=1)
    counts = sorted(len(cd.all_a()) for cd in clients)
    print(f"ragged C=32 partition: per-client A rows "
          f"min={counts[0]} median={counts[16]} max={counts[-1]}")
    spec = ShardedFedSpec(
        n_clients=32, d_hidden=64 if quick else 128, n_layers=2,
        seq_a=task.seq_a, feat_a=task.feat_a, seq_b=task.seq_b,
        feat_b=task.feat_b, out_dim=task.out_dim, kind=task.kind,
        n_partial=128, n_frag=128, n_paired=128, n_val=512, lr=1e-2,
        optimizer="adamw")
    mesh = make_host_mesh()
    shard = sh.batch_shardings(mesh, batch_specs(spec, ragged=True))
    batcher = FederatedBatcher(
        [client_arrays(cd) for cd in clients], spec,
        {"val_a": va.x_a, "val_b": va.x_b, "val_y": va.y},
        seed=0, shardings=shard)
    return spec, batcher, jax.jit(make_blendfl_round(spec)), mesh


def _sweep(batcher, round_fn, state0, start: int, n: int, prefetch: int):
    """n timed rounds from a common start state; returns (s/round,
    host-build s/round, consumer-stall s/round)."""
    b0, s0 = batcher.build_seconds, batcher.stall_seconds
    t0 = time.perf_counter()
    state = state0
    for _, batch in batcher.rounds(start, start + n, prefetch=prefetch):
        state, metrics = round_fn(state, batch)
    jax.block_until_ready(state)
    return ((time.perf_counter() - t0) / n,
            (batcher.build_seconds - b0) / n,
            (batcher.stall_seconds - s0) / n)


def main(quick: bool = False) -> None:
    from repro.core.federation_sharded import init_round_state
    from repro.launch.train_federated import place_state

    print("\n=== federated loader: ragged C=32 round, prefetch overlap ===")
    spec, batcher, round_fn, mesh = _build(quick)
    state0 = place_state(init_round_state(jax.random.PRNGKey(0), spec), mesh)
    # warmup: compile + first transfer
    for _, batch in batcher.rounds(0, 1, prefetch=0):
        jax.block_until_ready(round_fn(state0, batch)[0])

    n = 4 if quick else 8
    t_nopf, build_nopf, _ = _sweep(batcher, round_fn, state0, 1, n, prefetch=0)
    t_pf, build_pf, stall = _sweep(batcher, round_fn, state0, 1, n, prefetch=1)
    caches = int(round_fn._cache_size())
    # build time the consumer never saw: it only waited `stall` (includes
    # the unhideable first build of the stream)
    hidden = 1.0 - stall / max(build_pf, 1e-9)
    from benchmarks.common import max_rss_mb, write_bench_json

    rec = {
        "n_clients": 32, "rounds_timed": n,
        "s_per_round_no_prefetch": round(t_nopf, 4),
        "s_per_round_prefetch": round(t_pf, 4),
        "rounds_per_sec_prefetch": round(1.0 / t_pf, 3),
        "host_build_s_per_round": round(build_pf, 4),
        "consumer_stall_s_per_round": round(stall, 4),
        "hidden_frac_of_build": round(hidden, 3),
        "compile_cache": caches,
        "max_rss_mb": round(max_rss_mb(), 1),
    }
    print(f"no-prefetch {t_nopf:.3f}s/round | prefetch {t_pf:.3f}s/round "
          f"({rec['rounds_per_sec_prefetch']} rounds/s) | host build "
          f"{build_pf:.3f}s/round, stall {stall:.3f}s -> {hidden:.0%} hidden "
          f"| cache {caches} | maxrss {rec['max_rss_mb']:.0f} MiB")
    # emit the record BEFORE any acceptance assert: a failed acceptance
    # must leave evidence on disk, not silently skip the write
    write_bench_json("BENCH_federated_loader.json",
                     {"bench": "federated_loader",
                      "backend": jax.default_backend(), "record": rec})
    assert caches == 1, "ragged rounds must reuse the one compiled program"
    if hidden < 0.5:
        print(f"WARNING: prefetch hid only {hidden:.0%} of host build time "
              "(target >= 50%)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
