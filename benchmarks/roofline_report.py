"""§Roofline report: renders the dry-run sweep (dryrun.jsonl) into the
per-(arch x shape x mesh) table EXPERIMENTS.md embeds.

Run the sweep first:
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes \
        --out benchmarks/results/dryrun.jsonl
"""
from __future__ import annotations

import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.jsonl")


def load(path: str = RESULTS) -> list:
    if not os.path.exists(path):
        return []
    recs = []
    for line in open(path):
        recs.append(json.loads(line))
    # keep the LAST record per (arch, shape, mesh) — reruns append
    dedup = {}
    for r in recs:
        dedup[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return list(dedup.values())


def render(recs: list, mesh: str = "16x16") -> str:
    lines = [
        f"{'arch':20s} {'shape':12s} {'tc_ms':>9s} {'tm_ms':>10s} {'tx_ms':>10s} "
        f"{'bottleneck':>10s} {'useful':>7s} {'collMB/dev':>11s}"]
    for r in sorted(recs, key=lambda r: (r.get("arch", ""), r.get("shape", ""))):
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skip":
            lines.append(f"{r['arch']:20s} {r['shape']:12s} "
                         f"{'SKIP (see DESIGN.md)':>60s}")
            continue
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        ro = r["roofline"]
        lines.append(
            f"{r['arch']:20s} {r['shape']:12s} {ro['t_compute_ms']:9.2f} "
            f"{ro['t_memory_ms']:10.1f} {ro['t_collective_ms']:10.1f} "
            f"{ro['bottleneck']:>10s} {ro['useful_ratio']:7.2f} "
            f"{ro['coll_mb_per_dev']:11.0f}")
    return "\n".join(lines)


def main(quick: bool = False) -> None:
    recs = load()
    if not recs:
        print("\n=== roofline: no dryrun.jsonl found (run the dry-run sweep) ===")
        return
    ok = sum(1 for r in recs if r.get("status") == "ok")
    sk = sum(1 for r in recs if r.get("status") == "skip")
    print(f"\n=== §Roofline (from compiled dry-run; {ok} ok / {sk} skip) ===")
    for mesh in ("16x16", "2x16x16"):
        print(f"\n-- mesh {mesh} --")
        print(render(recs, mesh))


if __name__ == "__main__":
    main()
