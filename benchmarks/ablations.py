"""Fig. 3 (paired/partial data-distribution ratios) and Fig. 4 (number of
clients): BlendFL vs FedAvg (HFL) vs SplitNN (VFL) on S-MNIST.

Validation targets (trend directions from the paper):
  Fig 3: more paired data helps SplitNN; more partial data helps FedAvg;
         BlendFL >= both at every ratio.
  Fig 4: HFL improves relative to VFL as client count grows;
         BlendFL >= both at every client count.
"""
from __future__ import annotations

from benchmarks.common import ExpConfig, run_baseline, run_blendfl


def run_data_distribution(ratios=((0.9, 0.1), (0.7, 0.3), (0.5, 0.5),
                                  (0.3, 0.7), (0.1, 0.9)),
                          rounds: int = 20, seed: int = 0):
    """'paired' axis = VFL-usable fraction (both modalities exist), split
    half within-client paired / half cross-client fragmented so the
    conventional-VFL baseline has a party structure to train on."""
    print(f"{'paired/partial':>14s} {'fedavg':>8s} {'splitnn':>8s} {'blendfl':>8s}")
    rows = []
    for paired, part in ratios:
        exp = ExpConfig(task="smnist", rounds=rounds, seed=seed,
                        frac_paired=paired / 2, frac_fragmented=paired / 2,
                        frac_partial=part)
        fa, _ = run_baseline("fedavg", exp)
        sp, _ = run_baseline("splitnn", exp)
        bl, _, _ = run_blendfl(exp)
        row = {"paired_partial": f"{int(paired*100)}/{int(part*100)}",
               "fedavg_auroc": fa["multimodal_auroc"],
               "splitnn_auroc": sp["multimodal_auroc"],
               "blendfl_auroc": bl["multimodal_auroc"]}
        rows.append(row)
        print(f"{row['paired_partial']:>14s} {row['fedavg_auroc']:8.3f} "
              f"{row['splitnn_auroc']:8.3f} {row['blendfl_auroc']:8.3f}",
              flush=True)
    return rows


def run_client_counts(counts=(4, 8, 12), rounds: int = 20, seed: int = 0):
    print(f"{'clients':>8s} {'fedavg':>8s} {'splitnn':>8s} {'blendfl':>8s}")
    rows = []
    for n in counts:
        exp = ExpConfig(task="smnist", rounds=rounds, seed=seed, n_clients=n,
                        n_train=600)
        fa, _ = run_baseline("fedavg", exp)
        sp, _ = run_baseline("splitnn", exp)
        bl, _, _ = run_blendfl(exp)
        rows.append({"n_clients": n, "fedavg_auroc": fa["multimodal_auroc"],
                     "splitnn_auroc": sp["multimodal_auroc"],
                     "blendfl_auroc": bl["multimodal_auroc"]})
        print(f"{n:8d} {rows[-1]['fedavg_auroc']:8.3f} "
              f"{rows[-1]['splitnn_auroc']:8.3f} "
              f"{rows[-1]['blendfl_auroc']:8.3f}", flush=True)
    return rows


def main(quick: bool = False) -> None:
    import jax

    from benchmarks.common import write_bench_json

    print("\n=== Fig. 3: data distribution (paired/partial) ===")
    fig3 = run_data_distribution(ratios=((0.7, 0.3), (0.3, 0.7)) if quick else
                                 ((0.9, 0.1), (0.7, 0.3), (0.5, 0.5),
                                  (0.3, 0.7), (0.1, 0.9)),
                                 rounds=10 if quick else 20)
    print("\n=== Fig. 4: number of clients ===")
    fig4 = run_client_counts(counts=(4, 8) if quick else (4, 8, 12),
                             rounds=10 if quick else 20)
    write_bench_json("BENCH_ablations.json",
                     {"bench": "ablations", "backend": jax.default_backend(),
                      "quick": quick,
                      "records": [dict(r, figure="fig3") for r in fig3]
                      + [dict(r, figure="fig4") for r in fig4]})


if __name__ == "__main__":
    main()
