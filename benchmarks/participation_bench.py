"""§Participation policies: adaptive selection beats uniform on stragglers.

A ragged C=16 / K=4 federation with a **straggler cohort**: half the
clients are data-rich (clean labels, many rows), half are stragglers
(a handful of rows with permuted = noise labels). Uniform K-of-C
sampling wastes ~half of every round's participation slots on clients
whose updates BlendAvg will mostly reject; an adaptive policy
(``repro.core.schedule`` — data_volume, omega_ema, staleness, ...)
routes slots to clients that move the global model.

For each policy the bench drives the SAME jitted sharded round (one
``make_blendfl_round`` instance — the ids are data, so the compile cache
must stay 1 across all policies) through a policy-specific
``FederatedBatcher`` and measures:

  - rounds to reach a target validation multimodal AUROC (host-side
    ``repro.metrics.auroc`` of the blended global model, evaluated
    outside the timed region);
  - per-round wall time (device round + host batch build);
  - the shared round's compile-cache size after the whole sweep.

Emits ``BENCH_participation.json``. Acceptance: at least one adaptive
policy reaches the target in fewer rounds than ``uniform``, and the
compile cache is exactly 1.

    PYTHONPATH=src python -m benchmarks.participation_bench [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import write_bench_json

POLICIES = ("uniform", "round_robin", "staleness", "omega_ema", "data_volume")
N_CLIENTS, K = 16, 4
TARGET_AUROC = 0.85


def _straggler_clients(task, tr, rich_paired: int, rich_partial: int,
                       straggler_rows: int, seed: int):
    """16 ragged clients: 8 rich (clean rows) + 8 stragglers (few rows,
    permuted labels — pure noise). Returns (clients, per-client rows)."""
    rng = np.random.default_rng(seed)
    clients, rows, cursor = [], [], 0

    def take(n):
        nonlocal cursor
        sl = slice(cursor, cursor + n)
        cursor += n
        return tr.x_a[sl], tr.x_b[sl], tr.y[sl]

    for c in range(N_CLIENTS):
        rich = c < N_CLIENTS // 2
        n_pair = rich_paired if rich else straggler_rows
        n_part = rich_partial if rich else straggler_rows
        pa, pb, py = take(n_pair)
        ua, ub, uy = take(n_part)
        if not rich:  # straggler labels are shuffled -> noise updates
            py = py[rng.permutation(len(py))]
            uy = uy[rng.permutation(len(uy))]
        clients.append({
            "paired_a": pa, "paired_b": pb, "paired_y": py,
            "partial_a": ua, "partial_ya": uy,
            "partial_b": ub, "partial_yb": uy,
        })
        rows.append(2 * n_pair + 2 * n_part)
    return clients, rows


def _build(quick: bool):
    from repro.core.federation_sharded import (
        ShardedFedSpec, batch_specs, make_blendfl_round)
    from repro.data.synthetic import make_task, train_val_test
    from repro.launch import shardings as sh
    from repro.launch.mesh import make_host_mesh

    task = make_task("smnist")
    rich_paired, rich_partial, strag = ((96, 48, 8) if quick
                                        else (160, 64, 8))
    need = (N_CLIENTS // 2) * (rich_paired + rich_partial + 2 * strag) + 64
    tr, va, _ = train_val_test(task, need, 512, 64, seed=0)
    clients, rows = _straggler_clients(task, tr, rich_paired, rich_partial,
                                       strag, seed=1)
    print(f"straggler cohort: per-client rows {sorted(rows)}")
    spec = ShardedFedSpec(
        n_clients=N_CLIENTS, d_hidden=32, n_layers=2, seq_a=task.seq_a,
        feat_a=task.feat_a, seq_b=task.seq_b, feat_b=task.feat_b,
        out_dim=task.out_dim, kind=task.kind, n_partial=rich_partial,
        n_frag=8, n_paired=rich_paired, n_val=512, lr=2e-2,
        optimizer="adamw", n_sampled=K)
    mesh = make_host_mesh()
    shard = sh.batch_shardings(mesh, batch_specs(spec, ragged=True))
    val = {"val_a": va.x_a, "val_b": va.x_b, "val_y": va.y}
    return spec, clients, val, va, shard, mesh, jax.jit(make_blendfl_round(spec))


def _run_policy(policy: str, spec, clients, val, va, shard, mesh, round_fn,
                rounds: int):
    """Drive one policy's federation. s_per_round is the true consumer
    wall time of the round loop (device round + whatever host build/
    stall the policy's path exposes — prefetch-hidden build time for
    state-free policies, synchronous build for state-reading ones) with
    the host-side AUROC eval subtracted out."""
    from repro.core.federation import eval_multimodal
    from repro.core.federation_sharded import init_round_state
    from repro.core.schedule import telemetry_from_state
    from repro.data.pipeline import FederatedBatcher
    from repro.launch.train_federated import place_state

    batcher = FederatedBatcher(clients, dataclasses.replace(spec, policy=policy),
                               val, seed=0, shardings=shard)
    state = place_state(init_round_state(jax.random.PRNGKey(0), spec), mesh)

    aurocs, eval_spent, to_target = [], 0.0, None
    t_loop = time.perf_counter()
    for r, batch in batcher.rounds(0, rounds,
                                   telemetry_fn=lambda: telemetry_from_state(state)):
        state, _ = round_fn(state, batch)
        jax.block_until_ready(state["global_models"])
        t0 = time.perf_counter()
        g = state["global_models"]
        auc = eval_multimodal(g["f_A"], g["f_B"], g["g_M"], va.x_a, va.x_b,
                              va.y, spec.ecfg, spec.kind)
        eval_spent += time.perf_counter() - t0
        aurocs.append(auc)
        if to_target is None and auc >= TARGET_AUROC:
            to_target = r + 1
    loop_spent = time.perf_counter() - t_loop
    part = np.asarray(jax.device_get(state["sched"]["part_count"]))
    return {
        "policy": policy,
        "rounds_to_target": to_target,
        "target_auroc": TARGET_AUROC,
        "final_auroc": round(aurocs[-1], 4),
        "best_auroc": round(max(aurocs), 4),
        "s_per_round": round((loop_spent - eval_spent) / rounds, 4),
        "rich_participation_frac": round(
            float(part[: N_CLIENTS // 2].sum()) / max(float(part.sum()), 1.0),
            3),
    }


def main(quick: bool = False) -> None:
    print("\n=== participation policies: straggler cohort, C=16 K=4 ===")
    spec, clients, val, va, shard, mesh, round_fn = _build(quick)
    rounds = 12 if quick else 24
    policies = (("uniform", "data_volume", "omega_ema") if quick else POLICIES)

    # warmup: compile the shared round once on a throwaway state so the
    # first policy's s_per_round doesn't carry the compile
    from repro.core.federation_sharded import init_round_state
    from repro.data.pipeline import FederatedBatcher
    from repro.launch.train_federated import place_state

    wb = FederatedBatcher(clients, spec, val, seed=0, shardings=shard)
    wstate = place_state(init_round_state(jax.random.PRNGKey(0), spec), mesh)
    for _, batch in wb.rounds(0, 1, prefetch=0):
        jax.block_until_ready(round_fn(wstate, batch)[0])
    print(f"{'policy':>12s} {'to_target':>9s} {'final':>7s} {'best':>7s} "
          f"{'s/round':>8s} {'rich%':>6s}")
    records = []
    for p in policies:
        rec = _run_policy(p, spec, clients, val, va, shard, mesh, round_fn,
                          rounds)
        records.append(rec)
        tt = "-" if rec["rounds_to_target"] is None else rec["rounds_to_target"]
        print(f"{p:>12s} {tt!s:>9s} {rec['final_auroc']:7.3f} "
              f"{rec['best_auroc']:7.3f} {rec['s_per_round']:8.3f} "
              f"{rec['rich_participation_frac']:6.2f}", flush=True)
    cache = int(round_fn._cache_size())
    print(f"round compile cache across all policies: {cache}")

    # record first, assert after: a failed acceptance still leaves the
    # measurement on disk for the next comparison
    write_bench_json("BENCH_participation.json",
                     {"bench": "participation",
                      "backend": jax.default_backend(),
                      "n_clients": N_CLIENTS, "k": K, "rounds": rounds,
                      "compile_cache": cache, "records": records})
    assert cache == 1, \
        "participation policies must share the one compiled round program"
    uni = next(r for r in records if r["policy"] == "uniform")
    adaptive = [r for r in records if r["policy"] != "uniform"
                and r["rounds_to_target"] is not None]
    uni_rounds = (uni["rounds_to_target"] if uni["rounds_to_target"] is not None
                  else rounds + 1)
    best = min(adaptive, key=lambda r: r["rounds_to_target"], default=None)
    assert best is not None and best["rounds_to_target"] < uni_rounds, \
        f"no adaptive policy beat uniform ({uni_rounds} rounds) to " \
        f"AUROC {TARGET_AUROC}"
    print(f"--> {best['policy']} reached AUROC {TARGET_AUROC} in "
          f"{best['rounds_to_target']} rounds vs uniform's "
          f"{uni['rounds_to_target'] or 'never'}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    main(quick=ap.parse_args().quick)
