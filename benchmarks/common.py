"""Shared experiment driver for the paper's tables/figures."""
from __future__ import annotations

import dataclasses
import json
import os
import resource
import sys
import time

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def write_bench_json(name: str, payload: dict) -> str:
    """Atomic, unconditional ``BENCH_*.json`` emission.

    Every benchmark writes its record through here so results can't rot
    silently: the write happens even when acceptance warnings fire
    (callers must write BEFORE asserting), and it stages to a ``.tmp``
    sibling and ``os.replace``s into place so a crashed or concurrent
    run (e.g. under ``make`` with a dirty tree) can never leave a
    truncated JSON for the next comparison to misread.
    """
    import tempfile

    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    # unique tmp per writer: concurrent runs of the same bench must not
    # interleave into one staging file
    fd, tmp = tempfile.mkstemp(dir=RESULTS_DIR, prefix=name + ".", suffix=".tmp")
    try:
        # mkstemp creates 0600; restore umask-default perms so CI
        # artifact collectors and group readers keep access
        umask = os.umask(0)
        os.umask(umask)
        os.chmod(tmp, 0o666 & ~umask)
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    print(f"wrote {path}")
    return path


def max_rss_mb() -> float:
    """Host RAM high-water mark of THIS process, in MiB (getrusage;
    ru_maxrss is KiB on Linux, bytes on macOS)."""
    rss = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return rss / 1024 / (1024 if sys.platform == "darwin" else 1)

from repro.core.baselines import BASELINES
from repro.core.encoders import EncoderConfig
from repro.core.federation import FedConfig, Federation, evaluate_global
from repro.core.partitioner import partition
from repro.data.synthetic import make_task, train_val_test
from repro.metrics import auprc, auroc, bootstrap_ci


@dataclasses.dataclass
class ExpConfig:
    task: str = "smnist"
    n_train: int = 500
    n_val: int = 400
    n_test: int = 600
    n_clients: int = 3
    rounds: int = 25
    lr: float = 1e-2
    batch_size: int = 64
    frac_paired: float = 0.4
    frac_fragmented: float = 0.3
    frac_partial: float = 0.3
    dirichlet_alpha: float | None = None  # label-skew (non-IID) if set
    d_hidden: int = 48
    seed: int = 0


def setup(exp: ExpConfig):
    spec = make_task(exp.task)
    tr, va, te = train_val_test(spec, exp.n_train, exp.n_val, exp.n_test,
                                seed=exp.seed)
    clients = partition(tr, exp.n_clients, frac_paired=exp.frac_paired,
                        frac_fragmented=exp.frac_fragmented,
                        frac_partial=exp.frac_partial,
                        dirichlet_alpha=exp.dirichlet_alpha, seed=exp.seed + 1)
    ecfg = EncoderConfig(d_hidden=exp.d_hidden, n_layers=2, enc_type="mlp")
    fcfg = FedConfig(n_clients=exp.n_clients, rounds=exp.rounds, lr=exp.lr,
                     batch_size=exp.batch_size, seed=exp.seed)
    return spec, tr, va, te, clients, ecfg, fcfg


def run_blendfl(exp: ExpConfig, history_test=None, aggregator="blendavg",
                local_epochs=1):
    spec, tr, va, te, clients, ecfg, fcfg = setup(exp)
    fcfg = FedConfig(**{**dataclasses.asdict(fcfg),
                        "aggregator": aggregator, "local_epochs": local_epochs})
    fed = Federation.init(jax.random.PRNGKey(exp.seed), fcfg, spec, ecfg,
                          clients, va)
    history = []
    for r in range(fcfg.rounds):
        fed.round()
        if history_test is not None:
            history.append(dict(evaluate_global(fed, history_test), round=r))
    return evaluate_global(fed, te), history, (fed, te)


def run_baseline(name: str, exp: ExpConfig, history_test=None):
    spec, tr, va, te, clients, ecfg, fcfg = setup(exp)
    return BASELINES[name](jax.random.PRNGKey(exp.seed), spec, ecfg, clients,
                           va, te, fcfg, history_test=history_test)


def scores_with_ci(fed, te):
    """Paper-style 'point (lo, hi)' strings for the global models."""
    from repro.core.encoders import task_scores
    from repro.core.federation import _client_fwd
    from repro.core.encoders import fusion_apply
    from repro.models.common import dense
    import jax.numpy as jnp

    g, ecfg, kind = fed.global_models, fed.ecfg, fed.spec.kind
    h_a = _client_fwd(g["f_A"], jnp.asarray(te.x_a), ecfg=ecfg)
    h_b = _client_fwd(g["f_B"], jnp.asarray(te.x_b), ecfg=ecfg)
    outs = {}
    for name, scores in [
        ("multimodal", task_scores(fusion_apply(g["g_M"], h_a, h_b), kind)),
        ("uni_a", task_scores(dense(g["g_A"], h_a), kind)),
        ("uni_b", task_scores(dense(g["g_B"], h_b), kind)),
    ]:
        s = np.asarray(scores)
        for mname, mfn in (("auroc", auroc), ("auprc", auprc)):
            p, lo, hi = bootstrap_ci(mfn, te.y, s, n_boot=100)
            outs[f"{name}_{mname}"] = f"{p:.3f} ({lo:.3f}, {hi:.3f})"
    return outs


def fmt_row(name: str, res: dict) -> str:
    cols = ["multimodal_auroc", "multimodal_auprc", "uni_a_auroc", "uni_a_auprc",
            "uni_b_auroc", "uni_b_auprc"]
    vals = []
    for c in cols:
        v = res.get(c, float("nan"))
        vals.append(f"{v:.3f}" if isinstance(v, float) else str(v))
    return f"{name:14s} " + " ".join(f"{v:>8s}" for v in vals)


def timeit(fn, n=20, warmup=3):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us
