"""§Serving: latency/throughput of the decentralized inference engine.

Trains a small BlendFL federation in-host (``benchmarks.common``), then
drives its blended models through ``repro.core.serving.ServingEngine``
under three request mixes spanning the paper's serving regimes:

  - ``all_multimodal``: every request carries both modalities — the
    happy path, pure local multimodal fusion;
  - ``mixed_unimodal``: 50/50 A-only / B-only — the modality-
    heterogeneous cohort, local unimodal heads;
  - ``vfl_heavy``: 60% conventional-VFL fallback — the comparison
    regime where every request pays server round-trip bytes.

All three mixes run through ONE engine (codec ``none``), so the
compile-cache invariant is measured across the union of their shapes:
exactly 1 per (route, capacity) no matter the mix. A second engine arm
repeats ``vfl_heavy`` with the ``int8_topk`` wire codec to price the
fallback's feature/score messages compressed. Per mix (after a warmup
pass that absorbs compiles): p50/p99 request latency, requests/sec,
rows/sec, measured bytes/request, and the analytic-vs-measured wire
byte reconciliation.

Emits ``BENCH_serve.json`` (before acceptance asserts, via the atomic
``write_bench_json``). Acceptance: every (route, capacity) compile
cache is exactly 1; every served score is bit-identical to a single-
request ``inference.predict`` call; measured wire bytes equal the
analytic ``communication_cost`` total on every mix.

    PYTHONPATH=src python -m benchmarks.serve_bench [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import ExpConfig, max_rss_mb, run_blendfl, write_bench_json


def serve_arm(engine, spec, ecfg, models, gmv, mix: str, n: int, rows: int,
              *, codec: str, seed: int, check_parity: bool) -> dict:
    """One measured pass of one mix through an engine (stats deltas are
    computed around the pass so arms sharing an engine stay separable).
    """
    from repro.core.inference import predict
    from repro.launch.serve_federated import make_requests

    reqs = make_requests(spec, mix, n, rows=rows, seed=seed)
    before = dict(engine.stats)
    t0 = time.perf_counter()
    results = engine.run(reqs)
    wall = time.perf_counter() - t0

    lat_ms = np.array([r.latency_s for r in results]) * 1e3
    total_rows = int(sum(len(r.scores) for r in results))
    analytic_bytes = int(sum(r.bytes for r in results))
    measured_bytes = int(engine.stats["wire_bytes"] - before["wire_bytes"])
    parity_checked = 0
    if check_parity:
        for res, req in zip(results, reqs):
            ref = predict(models, req, ecfg, spec.kind, server_gmv=gmv,
                          codec=codec if req.vfl else None)
            if not (res.route is ref.route
                    and np.array_equal(np.asarray(res.scores),
                                       np.asarray(ref.scores))):
                raise AssertionError(
                    f"mix {mix}: request {res.index} ({res.route.value}) "
                    "diverges from single-request predict")
            parity_checked += 1
    return {
        "mix": mix, "codec": codec, "requests": n, "rows": total_rows,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "rps": n / wall,
        "rows_per_s": total_rows / wall,
        "bytes_per_request": analytic_bytes / n,
        "wire_bytes_measured": measured_bytes,
        "wire_bytes_analytic": analytic_bytes,
        "wire_messages": int(engine.stats["wire_messages"]
                             - before["wire_messages"]),
        "batches": int(engine.stats["batches"] - before["batches"]),
        "parity_checked": parity_checked,
        "wall_s": wall,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller federation + request counts")
    ap.add_argument("--requests", type=int, default=None,
                    help="requests per mix (overrides --quick sizing)")
    args = ap.parse_args()

    from repro.core.serving import ServingConfig, ServingEngine
    from repro.data.synthetic import make_task
    from repro.launch.serve_federated import MIXES

    n_req = args.requests or (32 if args.quick else 96)
    rows = 6 if args.quick else 12
    exp = ExpConfig(rounds=4 if args.quick else 10,
                    n_train=240 if args.quick else 500,
                    d_hidden=32 if args.quick else 48)
    print(f"training serving models: {exp.n_clients} clients, "
          f"{exp.rounds} rounds, d_hidden {exp.d_hidden}")
    metrics, _, (fed, _te) = run_blendfl(exp)
    spec = make_task(exp.task)
    models, gmv, ecfg = fed.global_models, fed.server_gmv, fed.ecfg
    print(f"trained: multimodal AUROC {metrics['multimodal_auroc']:.3f}")

    capacities = (2, 4, 16, 64)
    scfg = ServingConfig(capacities=capacities, codec="none")
    engine = ServingEngine(models, ecfg, spec.kind, server_gmv=gmv, cfg=scfg)
    # warmup: absorb every (route, capacity) compile OUTSIDE the timed
    # passes — a latency percentile that includes XLA compile time
    # measures the compiler, not the engine
    for mix in sorted(MIXES):
        serve_arm(engine, spec, ecfg, models, gmv, mix, min(n_req, 24),
                  rows=rows, codec="none", seed=7, check_parity=False)

    records = []
    for mix in sorted(MIXES):
        rec = serve_arm(engine, spec, ecfg, models, gmv, mix, n_req,
                        rows=rows, codec="none", seed=1,
                        check_parity=True)
        records.append(rec)
        print(f"mix {mix:>15}: p50 {rec['p50_ms']:7.2f}ms "
              f"p99 {rec['p99_ms']:7.2f}ms {rec['rps']:7.1f} req/s "
              f"{rec['bytes_per_request']:8.0f} B/req")
    shared_caches = {f"{route}/cap{cap}": n
                     for (route, cap), n in engine.cache_counts().items()}

    # codec arm: its VFL program differs (quantize/sparsify ops inline),
    # so it gets its own engine — and its own cache-1 ledger
    codec_engine = ServingEngine(models, ecfg, spec.kind, server_gmv=gmv,
                                 cfg=ServingConfig(capacities=capacities,
                                                   codec="int8_topk"))
    serve_arm(codec_engine, spec, ecfg, models, gmv, "vfl_heavy",
              min(n_req, 24), rows=rows, codec="int8_topk", seed=7,
              check_parity=False)
    rec = serve_arm(codec_engine, spec, ecfg, models, gmv, "vfl_heavy",
                    n_req, rows=rows, codec="int8_topk", seed=1,
                    check_parity=True)
    records.append(rec)
    print(f"mix {'vfl_heavy/int8_topk':>15}: p50 {rec['p50_ms']:7.2f}ms "
          f"p99 {rec['p99_ms']:7.2f}ms {rec['rps']:7.1f} req/s "
          f"{rec['bytes_per_request']:8.0f} B/req")
    codec_caches = {f"{route}/cap{cap}": n
                    for (route, cap), n in codec_engine.cache_counts().items()}

    payload = {
        "bench": "serve_engine",
        "backend": jax.default_backend(),
        "quick": bool(args.quick),
        "records": records,
        "record_extra": {
            "capacities": list(capacities),
            "d_hidden": exp.d_hidden,
            "multimodal_auroc": metrics["multimodal_auroc"],
            "caches": sorted(shared_caches.values())
            + sorted(codec_caches.values()),
            "cache_map": shared_caches,
            "cache_map_codec": codec_caches,
            "engine_stats": {k: v for k, v in engine.stats.items()
                             if k != "batches_by_route"},
            "max_rss_mb": max_rss_mb(),
        },
    }
    write_bench_json("BENCH_serve.json", payload)

    # acceptance AFTER the atomic emission — a failed assert must still
    # leave the record on disk for comparison
    for label, caches in (("shared", shared_caches), ("codec", codec_caches)):
        assert caches and all(v == 1 for v in caches.values()), \
            f"{label} engine compile cache not 1 per (route, capacity): {caches}"
    for rec in records:
        assert rec["wire_bytes_measured"] == rec["wire_bytes_analytic"], \
            (rec["mix"], rec["codec"], rec["wire_bytes_measured"],
             rec["wire_bytes_analytic"])
        assert rec["parity_checked"] == rec["requests"]
    print(f"acceptance ok: caches all 1 "
          f"({len(shared_caches)} shared + {len(codec_caches)} codec "
          "programs); measured == analytic wire bytes on every mix; "
          "every request bit-exact vs predict")


if __name__ == "__main__":
    main()
