"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference on CPU.

CPU wall-times are NOT the deliverable (TPU is the target; interpret mode
executes the kernel body in Python) — this bench exists to (a) regression-
track the reference paths that run in real CPU experiments and (b) verify
kernels stay numerically tied to their oracles at bench shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit
from repro.kernels.blendavg.ops import blend_params
from repro.kernels.blendavg.ref import blend_params_ref
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mlstm_scan.ref import mlstm_scan_ref
from repro.models.attention import chunked_gqa_sdpa, causal_mask, gqa_sdpa
from repro.models.recurrent import gated_linear_scan


def main(quick: bool = False) -> None:
    print("\n=== kernel benches (CPU; reference paths) ===")
    print(f"{'name':34s} {'us_per_call':>12s} {'max_err':>10s}")
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    records = []

    def rec(name, us, err=None):
        r = {"name": name, "us_per_call": round(us, 1)}
        if err is not None:
            r["max_err"] = float(err)
        records.append(r)

    # attention: einsum vs chunked (the long-seq production path)
    b, hq, hkv, s, d = 2, 8, 2, 512, 64
    q = jax.random.normal(ks[0], (b, s, hq, d))
    k = jax.random.normal(ks[1], (b, s, hkv, d))
    v = jax.random.normal(ks[2], (b, s, hkv, d))
    f_ein = jax.jit(lambda q, k, v: gqa_sdpa(q, k, v, causal_mask(s, s)))
    f_chk = jax.jit(lambda q, k, v: chunked_gqa_sdpa(q, k, v, causal=True,
                                                     block_q=128, block_k=128))
    o1, o2 = f_ein(q, k, v), f_chk(q, k, v)
    err = float(jnp.max(jnp.abs(o1 - o2)))
    t1 = timeit(lambda: jax.block_until_ready(f_ein(q, k, v)), n=5)
    t2 = timeit(lambda: jax.block_until_ready(f_chk(q, k, v)), n=5)
    print(f"{'attention_einsum_512':34s} {t1:12.0f} {'-':>10s}")
    print(f"{'attention_chunked_512':34s} {t2:12.0f} {err:10.2e}")
    rec("attention_einsum_512", t1)
    rec("attention_chunked_512", t2, err)

    # blendavg fused blend vs ref (memory-bound server aggregation)
    L, N = 8, 1_000_000 if not quick else 100_000
    stacked = jax.random.normal(ks[3], (L, N))
    omega = jax.nn.softmax(jnp.arange(L) * 0.3)
    f_ref = jax.jit(blend_params_ref)
    o_ref = f_ref(stacked, omega)
    o_ker = blend_params(stacked, omega)
    err = float(jnp.max(jnp.abs(o_ref - o_ker)))
    t_ref = timeit(lambda: jax.block_until_ready(f_ref(stacked, omega)), n=5)
    print(f"{'blendavg_ref_8x1M':34s} {t_ref:12.0f} {err:10.2e}")
    rec("blendavg_ref_8x1M", t_ref, err)

    # mlstm chunkwise vs sequential (recurrence hot path)
    s2 = 1024 if not quick else 256
    q2 = jax.random.normal(ks[0], (1, 4, s2, 32))
    k2 = jax.random.normal(ks[1], (1, 4, s2, 32)) * 0.5
    v2 = jax.random.normal(ks[2], (1, 4, s2, 32))
    lf = -jnp.abs(jax.random.normal(ks[3], (1, 4, s2))) * 0.2
    f_seq = jax.jit(lambda *a: mlstm_scan_ref(*a))
    f_par = jax.jit(lambda *a: gated_linear_scan(*a, chunk=64))
    o1, o2 = f_seq(q2, k2, v2, lf), f_par(q2, k2, v2, lf)
    err = float(jnp.max(jnp.abs(o1 - o2)))
    t_seq = timeit(lambda: jax.block_until_ready(f_seq(q2, k2, v2, lf)), n=5)
    t_par = timeit(lambda: jax.block_until_ready(f_par(q2, k2, v2, lf)), n=5)
    print(f"{'mlstm_sequential_{}'.format(s2):34s} {t_seq:12.0f} {'-':>10s}")
    print(f"{'mlstm_chunkwise_{}'.format(s2):34s} {t_par:12.0f} {err:10.2e}")
    rec(f"mlstm_sequential_{s2}", t_seq)
    rec(f"mlstm_chunkwise_{s2}", t_par, err)
    print(f"--> chunkwise speedup over sequential: {t_seq/t_par:.1f}x "
          "(the schedule the Pallas kernel implements)")

    from benchmarks.common import write_bench_json

    write_bench_json("BENCH_kernels.json",
                     {"bench": "kernels", "backend": jax.default_backend(),
                      "quick": quick, "records": records})


if __name__ == "__main__":
    main()
