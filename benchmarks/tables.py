"""Tables I-III: BlendFL vs centralized + 7 FL baselines on three tasks.

Paper mapping (datasets simulated — MIMIC-IV/CXR is credentialed PHI,
S-MNIST not available offline; the synthetic generator preserves the
modal structure, see repro/data/synthetic.py):

  Table I    clinical conditions prediction  -> task 'conditions'
  Table II   in-hospital mortality           -> task 'mortality'
  Table III  S-MNIST audio-visual digits     -> task 'smnist'

Validation target: ordering BlendFL > FL baselines (AUROC, most columns),
BlendFL ~ centralized.
"""
from __future__ import annotations

from benchmarks.common import ExpConfig, fmt_row, run_baseline, run_blendfl

HEADER = (f"{'method':14s} " + " ".join(f"{c:>8s}" for c in
          ["mm_roc", "mm_prc", "A_roc", "A_prc", "B_roc", "B_prc"]))

ORDER = ["centralized", "fedavg", "fedma", "fedprox", "fednova",
         "oneshot_vfl", "hfcl", "splitnn"]


def run_table(task: str, rounds: int, n_train: int, seed: int = 0,
              lr: float = 1e-2) -> dict:
    exp = ExpConfig(task=task, rounds=rounds, n_train=n_train, seed=seed, lr=lr)
    results = {}
    for name in ORDER:
        res, _ = run_baseline(name, exp)
        results[name] = res
    res, _, _ = run_blendfl(exp)
    results["blendfl"] = res
    return results


def main(quick: bool = False) -> None:
    cfgs = {
        "I:conditions": ("conditions", 15 if quick else 80, 400 if quick else 600),
        "II:mortality": ("mortality", 15 if quick else 80, 400 if quick else 600),
        "III:smnist": ("smnist", 15 if quick else 100, 400 if quick else 500),
    }
    for label, (task, rounds, n_train) in cfgs.items():
        print(f"\n=== Table {label} (rounds={rounds}, n_train={n_train}) ===")
        print(HEADER)
        results = run_table(task, rounds, n_train)
        for name in ORDER + ["blendfl"]:
            print(fmt_row(name, results[name]), flush=True)
        # validation summary
        fl_best = max(results[n]["multimodal_auroc"] for n in ORDER[1:])
        ours = results["blendfl"]["multimodal_auroc"]
        cent = results["centralized"]["multimodal_auroc"]
        print(f"--> blendfl {ours:.3f} vs best-FL {fl_best:.3f} vs "
              f"centralized {cent:.3f} | beats_fl={ours >= fl_best - 0.005} "
              f"near_centralized={ours >= cent - 0.05}")


if __name__ == "__main__":
    main()
