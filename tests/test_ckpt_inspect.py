"""``tools/ckpt_inspect.py`` — the checkpoint layout/drift inspector.

Pins the three contracts the ``make ckpt-inspect`` debugging surface
promises on REAL checkpoints (saved through ``repro.checkpoint`` from a
sharded round state carrying every optional block):

- exit codes: 0 for a clean registered layout, 2 when the manifest has
  a top-level key no registered block claims (layout drift — the reason
  the tool exists), 1 when the directory has no checkpoints at all;
- the printed per-block table is ``state.manifest_layout`` verbatim —
  every block header, leaf path, shape, and dtype appears;
- capacity reporting follows a grow migration: a capacity-8 state
  inspects as 8 slots, and after ``state.grow`` to 16 the re-saved
  checkpoint inspects as 16.
"""
import io
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools"))
import ckpt_inspect  # noqa: E402

from repro.checkpoint import save_checkpoint  # noqa: E402
from repro.core import state as rstate  # noqa: E402


def _spec(C: int):
    from repro.core.federation_sharded import ShardedFedSpec

    return ShardedFedSpec(
        n_clients=C, d_hidden=8, n_layers=2, seq_a=4, feat_a=3, seq_b=4,
        feat_b=3, out_dim=3, kind="multiclass", n_partial=4, n_frag=4,
        n_paired=4, n_val=8, n_sampled=2, codec="int8_topk",
        strategy="scaffold", server_opt="adam", optimizer="adamw")


@pytest.fixture(scope="module")
def all_blocks_state():
    """A real round state with every optional block (codec residuals +
    scaffold control variates) at capacity 8."""
    from repro.core.federation_sharded import init_round_state

    return init_round_state(jax.random.PRNGKey(0), _spec(8))


def _inspect(ckpt_dir, step=None):
    buf = io.StringIO()
    code = ckpt_inspect.inspect(str(ckpt_dir), step=step, out=buf)
    return code, buf.getvalue()


def test_no_checkpoints_is_exit_1(tmp_path):
    code, out = _inspect(tmp_path)
    assert code == 1 and "no checkpoints" in out


def test_clean_layout_is_exit_0_and_matches_manifest_layout(
        tmp_path, all_blocks_state):
    from repro.checkpoint import read_manifest

    save_checkpoint(str(tmp_path), 3, all_blocks_state,
                    {"round": 3, "store_fingerprint": "f" * 64})
    code, out = _inspect(tmp_path)
    assert code == 0
    assert "step 3" in out and "round:       3" in out
    assert "f" * 12 + "…" in out  # fingerprint abbreviation
    assert "NOT IN REGISTRY" not in out
    layout = rstate.manifest_layout(read_manifest(str(tmp_path), 3))
    assert set(layout) == {b.name for b in rstate.REGISTRY}
    for name, leaves in layout.items():
        assert f"{name}  ({len(leaves)} leaves)" in out
        for path, shape, dtype in leaves:
            assert path in out and str(tuple(shape)) in out and dtype in out


def test_unregistered_key_is_exit_2(tmp_path, all_blocks_state):
    state = dict(all_blocks_state, rogue={"x": jax.numpy.zeros(3)})
    save_checkpoint(str(tmp_path), 1, state, {"round": 1})
    code, out = _inspect(tmp_path)
    assert code == 2
    assert "UNREGISTERED: ?rogue" in out and "NOT IN REGISTRY" in out


def test_capacity_reported_across_grow(tmp_path, all_blocks_state):
    """The migration dispatch key: 8 slots before, 16 after a bucket
    grow — and --step selects among coexisting checkpoints."""
    save_checkpoint(str(tmp_path), 2, all_blocks_state, {"round": 2})
    grown = rstate.grow(all_blocks_state, 16)
    save_checkpoint(str(tmp_path), 5, grown, {"round": 5})
    code, out = _inspect(tmp_path, step=2)
    assert code == 0 and "capacity:    8 client slots" in out
    code, out = _inspect(tmp_path)  # latest = the grown one
    assert code == 0 and "step 5" in out
    assert "capacity:    16 client slots" in out
