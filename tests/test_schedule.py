"""Participation scheduler (``repro.core.schedule``) + its integration
into both federation drivers.

Core invariants:
  * every policy is deterministic given (rng state, telemetry) — the
    property bit-exact checkpoint/resume rests on;
  * ``uniform`` consumes the rng byte-identically to the pre-scheduler
    sampled round (the existing K-of-C parity tests stay green);
  * ``round_robin`` covers every client at least once per ceil(C/K)
    consecutive rounds, from any start round;
  * the omega-EMA telemetry update matches a plain numpy reference,
    participants-only;
  * at K = C every policy selects all clients — scheduling is a no-op
    and the batch stream matches ``uniform`` exactly;
  * checkpoint/resume stays bit-exact under a state-reading policy
    (slow lane, ``--policy omega_ema``).
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import schedule
from repro.core.schedule import POLICIES, make_policy
from repro.data.pipeline import FederatedBatcher
from test_federated_loader import _ragged_clients, _spec, _val

C, K = 8, 3


def _telemetry(round_no=5, last_round=None, omega_ema=None, rows=None):
    return {
        "round": round_no,
        "last_round": np.full(C, -1, np.int64) if last_round is None
        else np.asarray(last_round),
        "omega_ema": np.zeros(C) if omega_ema is None else np.asarray(omega_ema),
        "part_count": np.zeros(C, np.int64),
        "rows": np.ones(C) if rows is None else np.asarray(rows, np.float64),
    }


# ------------------------------------------------------- policy semantics --

@pytest.mark.parametrize("name", POLICIES)
def test_policy_deterministic_and_well_formed(name):
    """Same (seed, round)-keyed rng + same telemetry -> same sorted ids;
    ids are a valid K-subset of [0, C)."""
    pol = make_policy(name, C, K)
    t = _telemetry(rows=np.arange(1, C + 1.0))
    picks = [pol.select(np.random.default_rng([7, 5]), t) for _ in range(2)]
    np.testing.assert_array_equal(picks[0], picks[1])
    ids = picks[0]
    assert ids.shape == (K,)
    assert (np.diff(ids) > 0).all(), "ids must be sorted and distinct"
    assert 0 <= ids.min() and ids.max() < C


def test_policies_vary_across_rounds():
    """Different per-round rng keys / round indices give the scheduler
    room to vary the subset (no policy is stuck on one cohort)."""
    t_rows = np.arange(1, C + 1.0)
    for name in POLICIES:
        pol = make_policy(name, C, K)
        subsets = {tuple(pol.select(np.random.default_rng([7, r]),
                                    _telemetry(round_no=r, rows=t_rows)))
                   for r in range(8)}
        assert len(subsets) > 1, name


def test_uniform_matches_prescheduler_draw():
    """Bit-exactness anchor: the uniform policy consumes the rng exactly
    like the code it replaced (one sorted no-replacement choice)."""
    pol = make_policy("uniform", C, K)
    for r in range(4):
        want_rng = np.random.default_rng([3, r])
        want = np.sort(want_rng.choice(C, size=K, replace=False))
        got_rng = np.random.default_rng([3, r])
        np.testing.assert_array_equal(pol.select(got_rng, _telemetry()), want)
        # and the post-selection stream position is identical too (the
        # row draws that follow in build() must not shift)
        np.testing.assert_array_equal(want_rng.random(4), got_rng.random(4))


@pytest.mark.parametrize("c,k", [(8, 3), (7, 2), (16, 4), (5, 5)])
def test_round_robin_coverage_bound(c, k):
    """Every client participates at least once in ANY ceil(C/K)
    consecutive rounds — the coverage guarantee."""
    pol = make_policy("round_robin", c, k)
    w = pol.coverage_rounds
    rng = np.random.default_rng(0)
    for start in (0, 1, 5, 123):
        seen = set()
        for r in range(start, start + w):
            seen.update(pol.select(rng, _telemetry(round_no=r)).tolist())
        assert seen == set(range(c)), (c, k, start)


def test_staleness_prefers_stale_clients():
    pol = make_policy("staleness", C, K)
    last = np.full(C, 9)  # all fresh at round 10 …
    last[[1, 4, 6]] = 2  # … except three 7-rounds-stale clients
    ids = pol.select(np.random.default_rng(0),
                     _telemetry(round_no=10, last_round=last))
    np.testing.assert_array_equal(ids, [1, 4, 6])


def test_omega_ema_prefers_high_ema_within_pool():
    """Power-of-choice: the K picks are the top-EMA members of the
    oversampled pool (never a lower-EMA pool member over a higher one)."""
    pol = make_policy("omega_ema", C, K)
    ema = np.arange(C, dtype=float)
    for r in range(6):
        rng = np.random.default_rng([1, r])
        ids = pol.select(rng, _telemetry(omega_ema=ema))
        # reconstruct the pool this rng drew
        pool = np.random.default_rng([1, r]).choice(C, size=pol.pool,
                                                    replace=False)
        want = np.sort(pool[np.argsort(-ema[pool], kind="stable")[:K]])
        np.testing.assert_array_equal(ids, want)


def test_data_volume_tracks_row_counts():
    """Rows-proportional sampling: over many draws, a client with 50x
    the rows participates far more often than a near-empty one; zero-row
    clients are never picked while K data-holding clients exist."""
    pol = make_policy("data_volume", C, K)
    rows = np.array([500.0, 500, 500, 10, 10, 10, 10, 0])
    counts = np.zeros(C)
    for r in range(300):
        ids = pol.select(np.random.default_rng([2, r]), _telemetry(rows=rows))
        counts[ids] += 1
    assert counts[7] == 0
    assert counts[:3].min() > 2 * counts[3:7].max()


def test_make_policy_validates():
    with pytest.raises(ValueError, match="unknown participation policy"):
        make_policy("best_effort", C, K)
    with pytest.raises(ValueError, match="must be in"):
        make_policy("uniform", C, C + 1)


# ------------------------------------------------------- omega-EMA update --

def test_ema_update_matches_numpy_reference():
    """schedule.ema_update (the jnp scatter the sharded round jits) vs a
    plain numpy reference, participants-only and full-participation."""
    rng = np.random.default_rng(0)
    ema = rng.random(C).astype(np.float32)
    omega = rng.random(K).astype(np.float32)
    idx = np.array([1, 4, 6])
    beta = 0.9

    ref = ema.copy()
    ref[idx] = beta * ref[idx] + (1 - beta) * omega
    got = np.asarray(schedule.ema_update(jnp.asarray(ema), jnp.asarray(omega),
                                         beta, idx=jnp.asarray(idx)))
    np.testing.assert_allclose(got, ref, rtol=1e-6)
    # untouched slots are BIT-identical, not merely close
    mask = np.ones(C, bool)
    mask[idx] = False
    np.testing.assert_array_equal(got[mask], ema[mask])

    omega_full = rng.random(C).astype(np.float32)
    ref_full = beta * ema + (1 - beta) * omega_full
    got_full = np.asarray(schedule.ema_update(jnp.asarray(ema),
                                              jnp.asarray(omega_full), beta))
    np.testing.assert_allclose(got_full, ref_full, rtol=1e-6)


# ----------------------------------------------- K = C no-op parity --------

def test_k_equals_c_selects_everyone():
    for name in POLICIES:
        pol = make_policy(name, C, C)
        ids = pol.select(np.random.default_rng(0),
                         _telemetry(rows=np.arange(1, C + 1.0)))
        np.testing.assert_array_equal(ids, np.arange(C), err_msg=name)


def test_k_equals_c_batch_stream_matches_uniform():
    """With K = C and capacities >= every client's rows, build() draws no
    row subsets — so every policy's batch stream is bit-identical to
    uniform's (scheduling degenerates to a no-op)."""
    import dataclasses

    # generate clients against smaller caps, batch against roomier ones:
    # every client's rows then fit, so _draw never consumes the rng and
    # the only stream divergence between policies would be selection
    gen = _spec()
    rng = np.random.default_rng(0)
    clients = _ragged_clients(gen, rng)
    val = _val(gen, rng)
    spec = _spec(n_sampled=4, n_partial=gen.n_partial + 4,
                 n_frag=gen.n_frag + 4, n_paired=gen.n_paired + 4)
    ref = FederatedBatcher(clients, spec, val, seed=1).build(3)
    sched = {"last_round": np.full(4, -1, np.int64),
             "omega_ema": np.zeros(4), "part_count": np.zeros(4, np.int64)}
    for name in POLICIES:
        b = FederatedBatcher(clients, dataclasses.replace(spec, policy=name),
                             val, seed=1)
        got = b.build(3, sched=sched)
        for k in ref:
            np.testing.assert_array_equal(got[k], ref[k],
                                          err_msg=f"{name}:{k}")


# --------------------------------------------------- driver integration ----

def test_nonuniform_policy_requires_sampling():
    spec = _spec(policy="staleness")  # n_sampled defaults to 0
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="requires spec.n_sampled"):
        FederatedBatcher(_ragged_clients(spec, rng), spec, _val(spec, rng))


def test_needs_state_policy_requires_telemetry():
    spec = _spec(n_sampled=2, policy="staleness")
    rng = np.random.default_rng(0)
    b = FederatedBatcher(_ragged_clients(spec, rng), spec, _val(spec, rng))
    with pytest.raises(ValueError, match="telemetry"):
        b.build(0)
    with pytest.raises(ValueError, match="telemetry_fn"):
        next(b.rounds(0, 1))


def test_inhost_federation_policy_telemetry():
    """In-host driver: a state-reading policy runs end to end, fills the
    omega-EMA/participation telemetry, and never retraces a phase."""
    from repro.core.encoders import EncoderConfig
    from repro.core.federation import FedConfig, Federation
    from repro.core.partitioner import partition
    from repro.data.synthetic import make_task, train_val_test

    spec = make_task("smnist")
    tr, va, _ = train_val_test(spec, 240, 200, 100, seed=3)
    clients = partition(tr, 4, frac_paired=0.6, frac_fragmented=0.3,
                        frac_partial=0.1, seed=4)
    ecfg = EncoderConfig(d_hidden=32, n_layers=1, enc_type="mlp")
    cfg = FedConfig(n_clients=4, rounds=3, lr=1e-2, batch_size=32, seed=0,
                    n_sampled=2, async_mode=True, policy="staleness")
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)
    for _ in range(3):
        logs = fed.round()
        assert len(logs["sampled"]) == 2
    # staleness policy + async broadcast bounds the sync gap: after
    # ceil(C/K)+1 = 3 rounds every client has participated
    assert (fed.part_count > 0).all()
    assert int(fed.part_count.sum()) == 6
    assert np.isfinite(fed.omega_ema).all()
    assert fed.engine.unimodal_phase._cache_size() == 1

    with pytest.raises(ValueError, match="requires n_sampled"):
        Federation.init(jax.random.PRNGKey(0),
                        FedConfig(n_clients=4, policy="omega_ema"),
                        spec, ecfg, clients, va)


@pytest.mark.slow
def test_resume_parity_omega_ema_policy(tmp_path):
    """Slow lane: killed-and-resumed parity is bit-exact under a
    state-reading adaptive policy — the sched telemetry block rides the
    full-round-state checkpoint, so the resumed scheduler picks the same
    ids the uninterrupted run did."""
    from repro.launch.train_federated import selftest_resume
    from test_federated_loader import _loader_args

    selftest_resume(_loader_args(clients=6, n_sampled=3, policy="omega_ema"))
