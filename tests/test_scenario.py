"""``repro.data.scenario`` + the scenario-aware ``FederatedBatcher``.

Covers the three layers of the churn harness separately:

- the declarative model: event validation, pure membership queries
  (``n_clients_at`` / ``active_mask`` / ``corrupt_ids``), the attack
  events (sign_flip / scale / backdoor: id queries, the per-round
  ``attack_coef`` uplink vector, the trigger/target transforms), and
  the label-flip transforms;
- file loading: ``_mini_yaml`` (the no-PyYAML fallback the CI image
  uses) must parse the supported subset IDENTICALLY to PyYAML, so a
  scenario file means the same thing on every machine — the fallback is
  unit-tested directly because environments with PyYAML installed would
  otherwise never execute it;
- the batcher: inactive clients are never sampled, corrupt clients'
  labels arrive flipped, backdoor clients' batches carry the trigger
  pattern on a deterministic row prefix, the batch stream stays a pure
  function of (seed, round), and misuse (no sampling, short roster,
  K > active) fails loudly.
"""
import numpy as np
import pytest

from repro.data.scenario import (SCALE_FACTOR, TRIGGER_VALUE, Event, Scenario,
                                 _mini_yaml, apply_trigger, backdoor_rows,
                                 backdoor_target, flip_labels, load_scenario,
                                 parse_scenario)

# ------------------------------------------------------- declarative model --


def _scn():
    return Scenario((Event(round=2, join=4),
                     Event(round=3, leave=(0,), corrupt=(1,)))).validate(4)


def test_event_validation():
    with pytest.raises(ValueError, match="start at round 1"):
        Event(round=0, join=1)
    with pytest.raises(ValueError, match="join must be >= 0"):
        Event(round=1, join=-2)
    with pytest.raises(ValueError, match="ids must be >= 0"):
        Event(round=1, leave=(-1,))
    with pytest.raises(ValueError, match="duplicate event rounds"):
        Scenario((Event(round=1, join=1), Event(round=1, join=2)))


def test_validate_checks_ids_against_cohort():
    with pytest.raises(ValueError, match="references client 9"):
        Scenario((Event(round=1, leave=(9,)),)).validate(4)
    # client 5 exists only after the round-2 join -> corrupting it at
    # round 1 is an error, at round 2 it is fine
    with pytest.raises(ValueError, match="references client 5"):
        Scenario((Event(round=1, corrupt=(5,)),
                  Event(round=2, join=4))).validate(4)
    Scenario((Event(round=2, join=4),
              Event(round=3, corrupt=(5,)))).validate(4)
    with pytest.raises(ValueError, match="already-departed"):
        Scenario((Event(round=1, leave=(0,)),
                  Event(round=2, leave=(0,)))).validate(4)


def test_membership_queries_are_pure_in_round():
    s = _scn()
    assert [s.n_clients_at(r, 4) for r in (-1, 0, 1, 2, 3)] == [4, 4, 4, 8, 8]
    assert s.total_joins() == 4
    assert s.left_ids(2) == () and s.left_ids(3) == (0,)
    assert s.corrupt_ids(2) == () and s.corrupt_ids(3) == (1,)
    assert s.events_at(2).join == 4 and s.events_at(1) is None


def test_active_mask():
    s = _scn()
    np.testing.assert_array_equal(
        s.active_mask(0, 4, 8), [1, 1, 1, 1, 0, 0, 0, 0])
    np.testing.assert_array_equal(
        s.active_mask(2, 4, 8), [1, 1, 1, 1, 1, 1, 1, 1])
    np.testing.assert_array_equal(
        s.active_mask(3, 4, 8), [0, 1, 1, 1, 1, 1, 1, 1])
    with pytest.raises(ValueError, match="exceed state capacity"):
        s.active_mask(2, 4, 4)


def test_flip_labels():
    one_hot = np.eye(3, dtype=np.float32)[[0, 1, 2]]
    np.testing.assert_array_equal(flip_labels(one_hot, "multiclass"),
                                  np.eye(3, dtype=np.float32)[[1, 2, 0]])
    y = np.array([[0.0], [1.0]], np.float32)
    np.testing.assert_array_equal(flip_labels(y, "binary"),
                                  np.array([[1.0], [0.0]], np.float32))


def test_flip_labels_regressions():
    """The two silent-no-op traps: a multiclass flip over a single class
    (np.roll identity) must refuse instead of pretending to corrupt, and
    the flip must be a deterministic involution-like shift — applying it
    out_dim times round-trips multiclass labels, twice round-trips
    binary — so corrupt batches are reproducible, never RNG-dependent."""
    with pytest.raises(ValueError, match=">= 2 classes"):
        flip_labels(np.ones((4, 1), np.float32), "multiclass")
    one_hot = np.eye(3, dtype=np.float32)[[2, 0, 1]]
    y = one_hot
    for _ in range(3):
        y = flip_labels(y, "multiclass")
    np.testing.assert_array_equal(y, one_hot)
    # two classes: one flip swaps, a second flip restores
    two = np.eye(2, dtype=np.float32)[[0, 1, 0]]
    np.testing.assert_array_equal(
        flip_labels(flip_labels(two, "multiclass"), "multiclass"), two)
    b = np.array([[0.0], [1.0]], np.float32)
    np.testing.assert_array_equal(flip_labels(flip_labels(b, "binary"),
                                              "binary"), b)
    # pure function of its input: same labels in, same corruption out
    np.testing.assert_array_equal(flip_labels(one_hot, "multiclass"),
                                  flip_labels(one_hot.copy(), "multiclass"))


# ------------------------------------------------------------ attack model --


def _attack_scn():
    return Scenario((Event(round=2, sign_flip=(1,), backdoor=(3,)),
                     Event(round=4, scale=(2,), sign_flip=(0,)))).validate(4)


def test_attack_event_validation():
    with pytest.raises(ValueError, match="ids must be >= 0"):
        Event(round=1, sign_flip=(-1,))
    with pytest.raises(ValueError, match="ids must be >= 0"):
        Event(round=1, backdoor=(0, -2))
    with pytest.raises(ValueError, match="references client 7"):
        Scenario((Event(round=1, scale=(7,)),)).validate(4)
    # one client in both uplink-attack sets would make its coefficient
    # ambiguous — refused at validate time, not resolved silently
    with pytest.raises(ValueError, match="ambiguous"):
        Scenario((Event(round=1, sign_flip=(1,)),
                  Event(round=2, scale=(1,)))).validate(4)


def test_attack_queries_are_cumulative_and_pure():
    s = _attack_scn()
    assert s.sign_flip_ids(1) == ()
    assert s.sign_flip_ids(2) == (1,)
    assert s.sign_flip_ids(4) == (0, 1) == s.sign_flip_ids(9)
    assert s.scale_ids(3) == () and s.scale_ids(4) == (2,)
    assert s.backdoor_ids(1) == () and s.backdoor_ids(2) == (3,)
    assert s.has_uplink_attacks()
    assert not Scenario((Event(round=1, backdoor=(0,)),)).has_uplink_attacks()
    assert not Scenario((Event(round=2, join=2),)).has_uplink_attacks()


def test_attack_coef_vector():
    s = _attack_scn()
    ids = np.array([0, 1, 2, 3])
    np.testing.assert_array_equal(s.attack_coef(1, ids), np.ones(4))
    np.testing.assert_array_equal(s.attack_coef(2, ids), [1.0, -1.0, 1.0, 1.0])
    coef = s.attack_coef(5, ids)
    assert coef.dtype == np.float32
    np.testing.assert_array_equal(coef, [-1.0, -1.0, SCALE_FACTOR, 1.0])
    # backdoor is data poisoning, never an uplink coefficient
    assert float(s.attack_coef(9, np.array([3]))[0]) == 1.0


def test_apply_trigger_and_target():
    x = np.zeros((5, 4, 3), np.float32)
    out = apply_trigger(x)
    assert np.all(x == 0.0), "apply_trigger must copy, not mutate"
    np.testing.assert_array_equal(out[:, 0, :2],
                                  np.full((5, 2), TRIGGER_VALUE))
    assert np.all(out[:, 0, 2:] == 0.0) and np.all(out[:, 1:] == 0.0)
    # narrow feature axes clamp the stamp instead of failing
    assert np.all(apply_trigger(np.zeros((2, 3, 1)))[:, 0, 0]
                  == TRIGGER_VALUE)
    np.testing.assert_array_equal(backdoor_target("multiclass", 4),
                                  [1.0, 0.0, 0.0, 0.0])
    np.testing.assert_array_equal(backdoor_target("binary", 1), [1.0])
    assert backdoor_rows(5) == 3 and backdoor_rows(0) == 0


# ----------------------------------------------------------- file loading --

_DOC = """\
# a comment
events:
  - round: 2
    join: 4        # trailing comment
  - round: 3
    leave: [0, 1]
    corrupt: []
"""


def test_mini_yaml_matches_pyyaml():
    yaml = pytest.importorskip("yaml")
    assert _mini_yaml(_DOC) == yaml.safe_load(_DOC)


def test_mini_yaml_parses_the_subset():
    doc = _mini_yaml(_DOC)
    assert doc == {"events": [{"round": 2, "join": 4},
                              {"round": 3, "leave": [0, 1], "corrupt": []}]}
    s = parse_scenario(doc)
    assert s.total_joins() == 4 and s.left_ids(3) == (0, 1)


_ATTACK_DOC = """\
events:
  - round: 2
    sign_flip: [1]
    backdoor: [3, 4]
  - round: 3
    scale: [2]
"""


def test_mini_yaml_parses_attack_events_like_pyyaml():
    doc = _mini_yaml(_ATTACK_DOC)
    assert doc == {"events": [{"round": 2, "sign_flip": [1],
                               "backdoor": [3, 4]},
                              {"round": 3, "scale": [2]}]}
    s = parse_scenario(doc)
    assert s.sign_flip_ids(2) == (1,) and s.scale_ids(3) == (2,)
    assert s.backdoor_ids(2) == (3, 4)
    yaml = pytest.importorskip("yaml")
    assert _mini_yaml(_ATTACK_DOC) == yaml.safe_load(_ATTACK_DOC)


def test_mini_yaml_rejects_out_of_subset():
    with pytest.raises(ValueError, match="unsupported top-level"):
        _mini_yaml("settings:\n  - round: 1\n")
    with pytest.raises(ValueError, match="content before 'events:'"):
        _mini_yaml("  - round: 1\n")
    with pytest.raises(ValueError, match="mapping line outside an item"):
        _mini_yaml("events:\n  round: 1\n")


def test_parse_scenario_rejects_unknown_keys():
    with pytest.raises(ValueError, match="unknown scenario event keys"):
        parse_scenario({"events": [{"round": 1, "jion": 2}]})
    with pytest.raises(ValueError, match="missing 'round'"):
        parse_scenario({"events": [{"join": 2}]})
    with pytest.raises(ValueError, match="must be a mapping"):
        parse_scenario([1, 2])


def test_load_scenario_file(tmp_path):
    p = tmp_path / "s.yaml"
    p.write_text(_DOC)
    s = load_scenario(str(p))
    assert s == parse_scenario(_mini_yaml(_DOC))


def test_ci_scenario_file_loads_and_validates():
    """The checked-in CI scenario must stay loadable by BOTH parsers and
    valid for the ci-smoke lane's --clients 6."""
    import os

    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "examples", "scenarios", "ci_join.yaml")
    with open(path) as f:
        text = f.read()
    s = parse_scenario(_mini_yaml(text))
    s.validate(6)
    assert s.total_joins() > 0, "the CI scenario must exercise a join"
    yaml = pytest.importorskip("yaml")
    assert _mini_yaml(text) == yaml.safe_load(text)


# ------------------------------------------------------- batcher behavior --


def _spec(**kw):
    from repro.core.federation_sharded import ShardedFedSpec

    base = dict(n_clients=8, d_hidden=8, n_layers=2, seq_a=2, feat_a=3,
                seq_b=2, feat_b=3, out_dim=3, kind="multiclass", n_partial=2,
                n_frag=2, n_paired=4, n_val=4, n_sampled=2)
    base.update(kw)
    return ShardedFedSpec(**base)


def _client(rng, spec, label: int):
    """A paired-only client whose every row carries one-hot ``label`` —
    so any drawn subset's labels are that constant."""
    n = spec.n_paired
    y = np.zeros((n, spec.out_dim), np.float32)
    y[:, label] = 1.0
    return {"paired_a": rng.random((n, spec.seq_a, spec.feat_a),
                                   dtype=np.float32),
            "paired_b": rng.random((n, spec.seq_b, spec.feat_b),
                                   dtype=np.float32),
            "paired_y": y}


def _val(spec):
    rng = np.random.default_rng(7)
    return {"val_a": rng.random((spec.n_val, spec.seq_a, spec.feat_a),
                                dtype=np.float32),
            "val_b": rng.random((spec.n_val, spec.seq_b, spec.feat_b),
                                dtype=np.float32),
            "val_y": np.zeros((spec.n_val, spec.out_dim), np.float32)}


def _batcher(scenario, n_initial, n_roster, spec=None, prefetch=0):
    from repro.data.pipeline import FederatedBatcher

    spec = spec or _spec()
    rng = np.random.default_rng(0)
    clients = [_client(rng, spec, label=0) for _ in range(n_roster)]
    return FederatedBatcher(clients, spec, _val(spec), seed=3,
                            prefetch=prefetch, scenario=scenario,
                            n_initial=n_initial)


def test_scenario_requires_sampled_rounds():
    with pytest.raises(ValueError, match="requires sampled rounds"):
        _batcher(_scn(), 4, 8, spec=_spec(n_sampled=0))


def test_scenario_requires_full_roster():
    with pytest.raises(ValueError, match="scenario needs 8 client datasets"):
        _batcher(_scn(), 4, 5)


def test_rounds_iterator_refused_under_scenario():
    b = _batcher(_scn(), 4, 8)
    with pytest.raises(ValueError, match="round-by-round"):
        next(iter(b.rounds(0, 2)))


def test_inactive_clients_are_never_sampled():
    b = _batcher(_scn(), 4, 8)
    for r in range(6):
        idx = b.build(r)["sampled"]
        active = np.flatnonzero(b.scenario.active_mask(r, 4, 8))
        assert set(idx.tolist()) <= set(active.tolist()), \
            f"round {r}: sampled {idx} outside active {active}"
        if r >= 3:
            assert 0 not in idx, "departed client 0 must never be sampled"


def test_corrupt_client_labels_arrive_flipped():
    scn = Scenario((Event(round=1, corrupt=(1,)),)).validate(2)
    b = _batcher(scn, 2, 2)
    flipped = np.roll(np.eye(3, dtype=np.float32)[[0] * 4], 1, axis=-1)
    for r in range(3):
        batch = b.build(r)
        for k, i in enumerate(batch["sampled"]):
            want = flipped if (r >= 1 and i == 1) else \
                np.eye(3, dtype=np.float32)[[0] * 4]
            np.testing.assert_array_equal(batch["paired_y"][k], want,
                                          err_msg=f"round {r} client {i}")


def test_batch_stream_is_pure_in_seed_and_round():
    a = _batcher(_scn(), 4, 8)
    b = _batcher(_scn(), 4, 8)
    for r in range(5):
        ba, bb = a.build(r), b.build(r)
        assert set(ba) == set(bb)
        for k in ba:
            np.testing.assert_array_equal(ba[k], bb[k], err_msg=k)


def test_k_above_active_count_raises():
    scn = Scenario((Event(round=1, leave=(0,)),)).validate(2)
    b = _batcher(scn, 2, 2)
    b.build(0)  # 2 active, K=2 — fine
    with pytest.raises(ValueError, match="only 1 clients are active"):
        b.build(1)


# ------------------------------------------------------ attacked batches --


def test_backdoor_batches_carry_trigger_prefix():
    """From the event round on, a backdoor client's drawn slab has the
    trigger stamped and the target label written on exactly the
    ``backdoor_rows`` prefix; the suffix and every other client's rows
    stay clean. The clients carry label 1, so the class-0 target is
    distinguishable from honest labels."""
    from repro.data.pipeline import FederatedBatcher

    spec = _spec(n_clients=2, n_sampled=2)
    rng = np.random.default_rng(0)
    clients = [_client(rng, spec, label=1) for _ in range(2)]
    scn = Scenario((Event(round=1, backdoor=(1,)),)).validate(2)
    b = FederatedBatcher(clients, spec, _val(spec), seed=3, prefetch=0,
                         scenario=scn, n_initial=2)
    nb = backdoor_rows(spec.n_paired)
    assert 0 < nb < spec.n_paired
    honest_y = np.eye(3, dtype=np.float32)[[1] * spec.n_paired]
    target_y = np.eye(3, dtype=np.float32)[0]
    for r in range(3):
        batch = b.build(r)
        for k, i in enumerate(batch["sampled"]):
            x, y = batch["paired_a"][k], batch["paired_y"][k]
            if r >= 1 and i == 1:
                assert np.all(x[:nb, 0, :2] == TRIGGER_VALUE)
                np.testing.assert_array_equal(y[:nb],
                                              np.tile(target_y, (nb, 1)))
                np.testing.assert_array_equal(y[nb:], honest_y[nb:])
                assert not np.any(x[nb:, 0, :2] == TRIGGER_VALUE)
            else:
                np.testing.assert_array_equal(y, honest_y)
                assert not np.any(x[:, 0, :2] == TRIGGER_VALUE)


def test_attack_coef_rides_the_batch():
    """With spec.attacks on, every built batch carries the per-candidate
    uplink coefficient vector — scenario-derived, or all-ones without a
    scenario (the none-attack arm of a sweep shares the same program)."""
    from repro.data.pipeline import FederatedBatcher

    spec = _spec(attacks=True)
    scn = Scenario((Event(round=2, sign_flip=(1,), scale=(2,)),)).validate(8)
    b = _batcher(scn, 8, 8, spec=spec)
    for r in (0, 2):
        batch = b.build(r)
        coef = batch["attack_coef"]
        assert coef.shape == (2,) and coef.dtype == np.float32
        want = {1: -1.0 if r >= 2 else 1.0, 2: SCALE_FACTOR if r >= 2 else 1.0}
        for k, i in enumerate(batch["sampled"]):
            assert coef[k] == want.get(int(i), 1.0)
    rng = np.random.default_rng(0)
    plain = FederatedBatcher([_client(rng, spec, 0) for _ in range(8)],
                             spec, _val(spec), seed=3, prefetch=0)
    np.testing.assert_array_equal(plain.build(0)["attack_coef"],
                                  np.ones(2, np.float32))


def test_attacked_batch_stream_is_pure_in_seed_and_round():
    """Kill-and-resume determinism for ATTACKED scenarios: a fresh
    batcher (the post-restore situation) rebuilds bit-identical corrupt,
    backdoored, and coefficient-bearing batches for any round."""
    spec = _spec(attacks=True)
    scn = Scenario((Event(round=1, corrupt=(3,), backdoor=(4,)),
                    Event(round=2, sign_flip=(1,), scale=(2,)))).validate(8)
    a = _batcher(scn, 8, 8, spec=spec)
    b = _batcher(scn, 8, 8, spec=spec)
    for r in (3, 0, 2, 1):  # out of order: no hidden iteration state
        ba, bb = a.build(r), b.build(r)
        assert set(ba) == set(bb)
        for k in ba:
            np.testing.assert_array_equal(ba[k], bb[k],
                                          err_msg=f"round {r} key {k}")


def test_ci_attack_scenario_file_loads_and_validates():
    """The checked-in attacked-CI scenario must stay loadable by BOTH
    parsers, valid for the ci-smoke lane's --clients 6, and must carry a
    join (the resume selftest's capacity-growth requirement) plus live
    uplink attacks (the lane exists to pin the attack hook)."""
    import os

    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "examples", "scenarios",
        "ci_attack.yaml")
    with open(path) as f:
        text = f.read()
    s = parse_scenario(_mini_yaml(text))
    s.validate(6)
    assert s.total_joins() > 0, "resume selftest needs a capacity crossing"
    assert s.has_uplink_attacks()
    yaml = pytest.importorskip("yaml")
    assert _mini_yaml(text) == yaml.safe_load(text)
