"""Partial-participation rounds: K-of-C client sampling and the
staleness-weighted async BlendAvg, on both federation drivers.

The core invariants:
  * a sampled round with K = C is the existing full round — bit-for-bit
    on every global-model leaf (sampling is a gather, not new math);
  * sampled rounds never retrace: the sampled ids are data, so 3 rounds
    over different subsets at fixed K leave every phase cache at 1;
  * a straggler's stale candidate gets a damped omega, and clients that
    did not finish are masked out of the blend entirely;
  * async broadcast touches the participants only — stragglers keep
    their stale weights until they are next sampled.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blendavg import blendavg_weights
from repro.core.encoders import EncoderConfig
from repro.core.engine import (
    EngineConfig,
    make_phase_fns,
    sample_clients,
    sample_opt_state,
    scatter_clients,
    scatter_opt_state,
)
from repro.core.federation import FedConfig, Federation
from repro.core.federation_sharded import (
    ShardedFedSpec,
    batch_specs,
    init_round_state,
    make_blendfl_round,
)
from repro.core.partitioner import partition
from repro.data.synthetic import make_task, train_val_test


@pytest.fixture(scope="module")
def small_fed():
    spec = make_task("smnist")
    tr, va, te = train_val_test(spec, 240, 200, 100, seed=3)
    clients = partition(tr, 4, frac_paired=0.6, frac_fragmented=0.3,
                        frac_partial=0.1, seed=4)
    ecfg = EncoderConfig(d_hidden=32, n_layers=1, enc_type="mlp")
    return spec, va, clients, ecfg


# ------------------------------------------------- engine-level helpers ----

def test_sample_scatter_roundtrip():
    tree = {"w": jnp.arange(24.0).reshape(6, 4), "b": jnp.arange(6.0)}
    idx = jnp.asarray([4, 1], jnp.int32)
    sub = sample_clients(tree, idx)
    np.testing.assert_array_equal(np.asarray(sub["w"])[0],
                                  np.asarray(tree["w"])[4])
    # scatter modified rows back; untouched rows survive
    sub = jax.tree.map(lambda x: x + 100.0, sub)
    out = scatter_clients(tree, sub, idx)
    np.testing.assert_array_equal(np.asarray(out["b"]),
                                  np.array([0, 101, 2, 3, 104, 5]))


def test_sample_opt_state_keeps_shared_step():
    state = {"step": jnp.asarray(7, jnp.int32),
             "mu": {"g": {"w": jnp.arange(12.0).reshape(4, 3)}}}
    idx = jnp.asarray([2, 0], jnp.int32)
    sub = sample_opt_state(state, idx)
    assert int(sub["step"]) == 7  # shared counter passes through
    np.testing.assert_array_equal(np.asarray(sub["mu"]["g"]["w"])[0],
                                  np.arange(6.0, 9.0))
    sub = {"step": jnp.asarray(9, jnp.int32),
           "mu": {"g": {"w": jnp.zeros((2, 3))}}}
    out = scatter_opt_state(state, sub, idx)
    assert int(out["step"]) == 9  # advanced by the sampled round
    np.testing.assert_array_equal(np.asarray(out["mu"]["g"]["w"])[1],
                                  np.arange(3.0, 6.0))


# --------------------------------------------- async omega semantics -------

def test_straggler_omega_damped_host():
    """blendavg_weights: equal improvements, one candidate 3 rounds stale
    -> its omega is (1+3)^-0.5 = half the fresh one's."""
    w = blendavg_weights([0.7, 0.7], 0.5, staleness=[0.0, 3.0],
                         staleness_exp=0.5)
    np.testing.assert_allclose(w[1] / w[0], 0.5, rtol=1e-12)
    np.testing.assert_allclose(w.sum(), 1.0)
    # no damping when the exponent is disabled
    w0 = blendavg_weights([0.7, 0.7], 0.5, staleness=[0.0, 3.0],
                          staleness_exp=0.0)
    np.testing.assert_allclose(w0, [0.5, 0.5])


def test_straggler_omega_damped_engine():
    """Engine blendavg_update: same scores, staleness [0, 3] -> the stale
    candidate's omega is damped; unfinished candidates are masked out."""
    cfg = EngineConfig(ecfg=EncoderConfig(d_hidden=8, n_layers=1),
                       kind="binary", staleness_exp=0.5)
    fns = make_phase_fns(cfg)
    glob = {"w": jnp.zeros(4)}
    cands = {"w": jnp.stack([jnp.ones(4), 3 * jnp.ones(4)])}
    scores = jnp.asarray([0.7, 0.7])
    _, omega, up = fns.blendavg_update(glob, cands, scores, 0.5,
                                       staleness=jnp.asarray([0.0, 3.0]))
    assert bool(up)
    np.testing.assert_allclose(float(omega[1]) / float(omega[0]), 0.5,
                               rtol=1e-5)
    # a non-finished client is masked exactly like an empty batch
    new, omega, up = fns.blendavg_update(
        glob, cands, scores, 0.5, finished=jnp.asarray([True, False]))
    np.testing.assert_allclose(np.asarray(omega), [1.0, 0.0], atol=1e-6)
    np.testing.assert_allclose(np.asarray(new["w"]), np.ones(4), rtol=1e-6)
    # nobody finished -> keep the previous global model
    new, omega, up = fns.blendavg_update(
        glob, cands, scores, 0.5, finished=jnp.asarray([False, False]))
    assert not bool(up)
    np.testing.assert_array_equal(np.asarray(new["w"]), np.zeros(4))


# ------------------------------------------------- in-host federation ------

@pytest.mark.slow
def test_sampled_round_k_equals_c_parity(small_fed):
    """K = C sampling must reproduce the full-participation round
    bit-for-bit on every global-model leaf: the gather is the identity,
    the remapped VFL alignment is the original one, and the key stream
    is consumed in the same order."""
    spec, va, clients, ecfg = small_fed
    common = dict(n_clients=4, rounds=2, lr=5e-2, batch_size=512, seed=0)
    full = Federation.init(jax.random.PRNGKey(7), FedConfig(**common),
                           spec, ecfg, clients, va)
    samp = Federation.init(jax.random.PRNGKey(7),
                           FedConfig(**common, n_sampled=4),
                           spec, ecfg, clients, va)
    for _ in range(2):
        lf, ls = full.round(), samp.round()
        np.testing.assert_array_equal(ls["sampled"], np.arange(4))
        np.testing.assert_allclose(lf["loss_partial"], ls["loss_partial"],
                                   rtol=1e-6)
        for grp in ("f_A", "g_A", "f_B", "g_B", "g_M"):
            for a, b in zip(jax.tree.leaves(full.global_models[grp]),
                            jax.tree.leaves(samp.global_models[grp])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sampled_rounds_compile_once(small_fed):
    """Acceptance criterion: 3 rounds over DIFFERENT sampled subsets at
    fixed K leave each phase's compile cache at exactly 1 — the sampled
    ids are data, not shape."""
    spec, va, clients, ecfg = small_fed
    cfg = FedConfig(n_clients=4, rounds=3, lr=1e-2, batch_size=32, seed=0,
                    n_sampled=2)
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)
    subsets = {tuple(fed.round()["sampled"]) for _ in range(3)}
    assert len(subsets) > 1  # the RNG actually varied the subset
    assert fed.engine.unimodal_phase._cache_size() == 1
    assert fed.engine.paired_phase._cache_size() == 1
    assert fed.engine.vfl_phase._cache_size() == 1


def test_async_broadcast_is_participants_only(small_fed):
    """Async mode: non-sampled clients keep their stale weights and their
    last_round stays behind; participants sync to the new global."""
    spec, va, clients, ecfg = small_fed
    cfg = FedConfig(n_clients=4, rounds=4, lr=1e-2, batch_size=64, seed=0,
                    n_sampled=2, async_mode=True)
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)
    pre = jax.tree.map(jnp.copy, fed.stacked)
    logs = fed.round()
    idx = logs["sampled"]
    out = set(range(4)) - set(idx.tolist())
    for k in out:  # stragglers: untouched weights, last_round behind
        assert fed.last_round[k] == -1
        for a, b in zip(jax.tree.leaves(pre), jax.tree.leaves(fed.stacked)):
            np.testing.assert_array_equal(np.asarray(a)[k], np.asarray(b)[k])
    for k in idx:  # participants: synced to the new global
        assert fed.last_round[k] == 0
        for grp in ("f_A", "g_M"):
            for a, g in zip(jax.tree.leaves(fed.stacked[grp]),
                            jax.tree.leaves(fed.global_models[grp])):
                np.testing.assert_array_equal(np.asarray(a)[k], np.asarray(g))
    # omegas cover the K candidates (+ server head for g_M) and stay a
    # simplex or zero through later, genuinely-stale rounds
    for _ in range(3):
        logs = fed.round()
    assert len(logs["omega_A"]) == 2 and len(logs["omega_M"]) == 3
    for key in ("omega_A", "omega_B", "omega_M"):
        w = np.asarray(logs[key])
        assert (w >= 0).all()
        assert abs(w.sum() - 1.0) < 1e-6 or w.sum() == 0.0


def test_async_requires_sampling(small_fed):
    spec, va, clients, ecfg = small_fed
    with pytest.raises(ValueError):
        Federation.init(jax.random.PRNGKey(0),
                        FedConfig(n_clients=4, async_mode=True),
                        spec, ecfg, clients, va)
    with pytest.raises(ValueError):
        Federation.init(jax.random.PRNGKey(0),
                        FedConfig(n_clients=4, n_sampled=9),
                        spec, ecfg, clients, va)


@pytest.mark.slow
def test_sampled_async_learns(small_fed):
    """Convergence smoke: 10 async K-of-C rounds still improve the
    training losses (the paper's no-degradation premise under partial
    participation)."""
    spec, va, clients, ecfg = small_fed
    cfg = FedConfig(n_clients=4, rounds=10, lr=1e-2, batch_size=64, seed=0,
                    n_sampled=2, async_mode=True)
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)
    hist = fed.fit()
    first = hist[0]["loss_partial"]
    last = hist[-1]["loss_partial"]
    assert np.isfinite(last) and last < first


# ------------------------------------------------- sharded federation ------

def _sharded_batch(spec, rng, idx=None):
    batch = {}
    for k, sd in batch_specs(spec).items():
        if k == "perm_b":
            batch[k] = jnp.asarray(
                rng.permutation(spec.k_round * spec.n_frag).astype(np.int32))
        elif k == "sampled":
            batch[k] = jnp.asarray(idx, jnp.int32)
        elif k.endswith("y") or k.endswith("ya") or k.endswith("yb"):
            batch[k] = jnp.asarray((rng.random(sd.shape) < 0.3).astype(np.float32))
        else:
            batch[k] = jnp.asarray(rng.normal(0, 1, sd.shape).astype(np.float32))
    return batch


@pytest.fixture(scope="module")
def sharded_sampled():
    spec = ShardedFedSpec(n_clients=6, n_sampled=3, d_hidden=32, n_layers=2,
                          seq_a=8, feat_a=6, seq_b=8, feat_b=6, out_dim=5,
                          n_partial=32, n_frag=32, n_paired=32, n_val=64,
                          lr=5e-2)
    return spec, np.random.default_rng(0)


def test_sharded_sampled_round_bookkeeping(sharded_sampled):
    spec, rng = sharded_sampled
    state = init_round_state(jax.random.PRNGKey(0), spec)
    assert state["last_round"].shape == (spec.n_clients,)
    rf = jax.jit(make_blendfl_round(spec))
    idx = np.array([1, 3, 4])
    pre = jax.tree.map(jnp.copy, state["models"])
    state, m = rf(state, _sharded_batch(spec, rng, idx))
    assert np.isfinite(float(m["loss_uni"]))
    assert len(np.asarray(m["omega_A"])) == spec.n_sampled
    assert len(np.asarray(m["omega_M"])) == spec.n_sampled + 1
    np.testing.assert_array_equal(
        np.asarray(state["last_round"]), np.where(np.isin(np.arange(6), idx), 0, -1))
    assert int(state["round"]) == 1
    # async broadcast: stragglers' stacked rows are untouched
    for a, b in zip(jax.tree.leaves(pre), jax.tree.leaves(state["models"])):
        for k in (0, 2, 5):
            np.testing.assert_array_equal(np.asarray(a)[k], np.asarray(b)[k])
    # participants hold the new global
    for grp in ("f_A", "g_M"):
        for leaf, gleaf in zip(jax.tree.leaves(state["models"][grp]),
                               jax.tree.leaves(state["global_models"][grp])):
            for k in idx:
                np.testing.assert_allclose(np.asarray(leaf)[k], np.asarray(gleaf),
                                           rtol=1e-6, atol=1e-7)


def test_sharded_sampled_compiles_once_across_subsets(sharded_sampled):
    spec, _ = sharded_sampled
    rng = np.random.default_rng(7)
    state = init_round_state(jax.random.PRNGKey(0), spec)
    rf = jax.jit(make_blendfl_round(spec))
    losses = []
    for _ in range(4):
        idx = np.sort(rng.choice(spec.n_clients, spec.n_sampled, replace=False))
        state, m = rf(state, _sharded_batch(spec, rng, idx))
        losses.append(float(m["loss_uni"]))
    assert rf._cache_size() == 1
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]  # sampled rounds still learn
