"""Serving engine + redesigned inference API (``repro.core.serving`` /
``repro.core.inference``).

Covers the standing serving invariants:

- route selection over all 4 modality-presence combos (both / A-only /
  B-only / neither-raises), plus the VFL opt-in and its missing-modality
  ``ValueError`` (the old surface's bare ``assert``, retired);
- bit-exactness: every request served out of a padded, coalesced,
  masked micro-batch scores bit-identically to a single-request
  ``predict`` call — including requests chunked across batches and the
  lossy-codec VFL route (per-row wire messages make padding rows
  inert);
- compile-cache discipline: exactly 1 per (route, capacity) across
  arbitrary request mixes;
- measured-vs-analytic wire bytes reconciliation for the ``none`` and
  ``int8_topk`` codecs;
- the deprecated wrappers (``local_predict`` / ``vfl_server_inference``
  and the ``repro.launch.serve`` module stub) warn and forward.
"""
import warnings

import jax
import numpy as np
import pytest

from repro.core import codec as wire
from repro.core.encoders import EncoderConfig, init_client_models
from repro.core.inference import (InferenceRequest, PredictResult, Route,
                                  communication_cost, local_predict, predict,
                                  route_for, vfl_server_inference)
from repro.core.serving import (ServingConfig, ServingEngine, bucket_for)
from repro.data.synthetic import make_task

CAPS = (2, 4, 8)


@pytest.fixture(scope="module")
def setup():
    spec = make_task("smnist")
    ecfg = EncoderConfig(d_hidden=24, n_layers=1, enc_type="mlp")
    models = init_client_models(jax.random.PRNGKey(0), spec, ecfg)
    gmv = init_client_models(jax.random.PRNGKey(1), spec, ecfg)["g_M"]
    return spec, ecfg, models, gmv


def _req(spec, rng, n, a=True, b=True, vfl=False):
    xa = rng.standard_normal((n, spec.seq_a, spec.feat_a)).astype(np.float32) if a else None
    xb = rng.standard_normal((n, spec.seq_b, spec.feat_b)).astype(np.float32) if b else None
    return InferenceRequest(xa, xb, vfl=vfl)


# ------------------------------------------------------------- routing ----

def test_route_selection_all_modality_combos(setup):
    spec, ecfg, models, gmv = setup
    rng = np.random.default_rng(0)
    combos = [
        (dict(a=True, b=True), Route.MULTIMODAL),
        (dict(a=True, b=False), Route.UNIMODAL_A),
        (dict(a=False, b=True), Route.UNIMODAL_B),
        (dict(a=True, b=True, vfl=True), Route.VFL_FALLBACK),
    ]
    for kw, want in combos:
        assert route_for(_req(spec, rng, 3, **kw)) is want
    with pytest.raises(ValueError, match="no modality"):
        route_for(InferenceRequest(None, None))
    # VFL needs both parties — a ValueError, not the old bare assert
    for kw in (dict(a=True, b=False), dict(a=False, b=True)):
        with pytest.raises(ValueError, match="both parties"):
            route_for(_req(spec, rng, 3, vfl=True, **kw))
    with pytest.raises(ValueError, match="disagree"):
        route_for(InferenceRequest(
            rng.standard_normal((3, spec.seq_a, spec.feat_a)).astype(np.float32),
            rng.standard_normal((4, spec.seq_b, spec.feat_b)).astype(np.float32)))


def test_predict_returns_typed_result(setup):
    spec, ecfg, models, gmv = setup
    rng = np.random.default_rng(1)
    res = predict(models, _req(spec, rng, 4), ecfg, spec.kind)
    assert isinstance(res, PredictResult)
    assert res.route is Route.MULTIMODAL
    assert res.scores.shape == (4, spec.out_dim)
    assert (res.messages, res.bytes) == (0, 0)  # local = no network

    vfl = predict(models, _req(spec, rng, 4, vfl=True), ecfg, spec.kind,
                  server_gmv=gmv)
    cost = communication_cost(4, ecfg.d_hidden, "vfl", spec.out_dim)
    assert vfl.route is Route.VFL_FALLBACK
    assert (vfl.messages, vfl.bytes) == (3, cost["bytes"])
    with pytest.raises(ValueError, match="server_gmv"):
        predict(models, _req(spec, rng, 4, vfl=True), ecfg, spec.kind)


def test_single_row_predict_matches_batched(setup):
    """A 1-row request must score bit-identically to the same row inside
    a larger request — predict pads it to MIN_COMPILED_ROWS because
    XLA's 1-row (matrix-vector) lowering drifts an ulp from every
    batched shape."""
    spec, ecfg, models, gmv = setup
    rng = np.random.default_rng(2)
    big = _req(spec, rng, 5)
    solo = InferenceRequest(big.x_a[:1], big.x_b[:1])
    got = predict(models, solo, ecfg, spec.kind)
    ref = predict(models, big, ecfg, spec.kind)
    assert got.scores.shape == (1, spec.out_dim)
    assert np.array_equal(np.asarray(got.scores), np.asarray(ref.scores[:1]))


# ------------------------------------------------------ deprecated API ----

def test_deprecated_wrappers_warn_and_forward(setup):
    spec, ecfg, models, gmv = setup
    rng = np.random.default_rng(3)
    req = _req(spec, rng, 4)
    with pytest.warns(DeprecationWarning, match="local_predict"):
        scores, mode = local_predict(models, req, ecfg, spec.kind)
    assert mode == "multimodal"
    ref = predict(models, req, ecfg, spec.kind)
    assert np.array_equal(np.asarray(scores), np.asarray(ref.scores))

    with pytest.warns(DeprecationWarning, match="vfl_server_inference"):
        scores, msgs = vfl_server_inference(models, gmv, req, ecfg, spec.kind)
    assert msgs == 3
    vref = predict(models, _req_copy_vfl(req), ecfg, spec.kind,
                   server_gmv=gmv)
    assert np.array_equal(np.asarray(scores), np.asarray(vref.scores))
    # missing modality through the wrapper: ValueError, never AssertionError
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        with pytest.raises(ValueError, match="both parties"):
            vfl_server_inference(models, gmv,
                                 InferenceRequest(req.x_a, None), ecfg,
                                 spec.kind)


def _req_copy_vfl(req):
    return InferenceRequest(req.x_a, req.x_b, vfl=True)


def test_serve_module_stub_warns_and_forwards():
    import importlib
    import sys

    sys.modules.pop("repro.launch.serve", None)
    with pytest.warns(DeprecationWarning, match="serve_lm"):
        mod = importlib.import_module("repro.launch.serve")
    from repro.launch import serve_lm
    assert mod.main is serve_lm.main


# ------------------------------------------------------------- engine -----

def _mixed_requests(spec, rng):
    """All four routes, several sizes, incl. one above the top capacity
    (chunking) and 1-row requests (min-capacity padding)."""
    return [
        _req(spec, rng, 3),
        _req(spec, rng, 1, b=False),
        _req(spec, rng, 2, a=False),
        _req(spec, rng, 5, vfl=True),
        _req(spec, rng, 19),  # > top capacity: chunks into 8+8+3
        _req(spec, rng, 1, vfl=True),
        _req(spec, rng, 1),
        _req(spec, rng, 4, b=False),
    ]


@pytest.mark.parametrize("codec", ["none", "int8_topk"])
def test_padded_batches_bit_exact_vs_predict(setup, codec):
    spec, ecfg, models, gmv = setup
    rng = np.random.default_rng(4)
    eng = ServingEngine(models, ecfg, spec.kind, server_gmv=gmv,
                        cfg=ServingConfig(capacities=CAPS, codec=codec,
                                          window=6))
    reqs = _mixed_requests(spec, rng)
    results = eng.run(reqs)
    assert [r.index for r in results] == list(range(len(reqs)))
    for res, req in zip(results, reqs):
        ref = predict(models, req, ecfg, spec.kind, server_gmv=gmv,
                      codec=codec if req.vfl else None)
        assert res.route is ref.route
        assert res.scores.shape == ref.scores.shape
        assert np.array_equal(np.asarray(res.scores),
                              np.asarray(ref.scores)), \
            f"request {res.index} ({res.route.value}) diverged under {codec}"
        assert res.latency_s >= 0.0


@pytest.mark.parametrize("codec", ["none", "int8_topk"])
def test_wire_bytes_measured_reconciles_analytic(setup, codec):
    spec, ecfg, models, gmv = setup
    rng = np.random.default_rng(5)
    cdc = wire.make_codec(codec)
    eng = ServingEngine(models, ecfg, spec.kind, server_gmv=gmv,
                        cfg=ServingConfig(capacities=CAPS, codec=codec))
    reqs = [_req(spec, rng, n, vfl=v)
            for n, v in ((3, True), (2, False), (1, True), (7, True))]
    results = eng.run(reqs)
    vfl_rows = 3 + 1 + 7
    analytic = communication_cost(vfl_rows, ecfg.d_hidden, "vfl",
                                  spec.out_dim, codec=cdc)["bytes"]
    # engine-measured == sum of per-request logical == whole-stream formula:
    # bytes are per-row, so coalescing can't change the total
    assert eng.stats["wire_bytes"] == analytic
    assert sum(r.bytes for r in results) == analytic
    assert all(r.messages == 3 for r in results if r.route is Route.VFL_FALLBACK)
    assert all(r.bytes == 0 for r in results if r.route is not Route.VFL_FALLBACK)


def test_cache_exactly_one_per_route_capacity_across_mixes(setup):
    spec, ecfg, models, gmv = setup
    rng = np.random.default_rng(6)
    eng = ServingEngine(models, ecfg, spec.kind, server_gmv=gmv,
                        cfg=ServingConfig(capacities=CAPS, window=4))
    mixes = [
        [_req(spec, rng, 4), _req(spec, rng, 4)],  # all multimodal
        [_req(spec, rng, 2, b=False), _req(spec, rng, 2, a=False)],
        [_req(spec, rng, 3, vfl=True), _req(spec, rng, 6)],
        [_req(spec, rng, 1), _req(spec, rng, 8, vfl=True)],
    ]
    for mix in mixes:
        eng.run(mix)
    counts = eng.cache_counts()
    assert counts, "engine compiled nothing"
    assert all(n == 1 for n in counts.values()), counts
    # replaying every mix adds no compiles
    for mix in mixes:
        eng.run(mix)
    assert eng.cache_counts() == counts


def test_chunked_request_reassembles_in_order(setup):
    spec, ecfg, models, gmv = setup
    rng = np.random.default_rng(7)
    req = _req(spec, rng, 21)  # 8 + 8 + 5 across three micro-batches
    eng = ServingEngine(models, ecfg, spec.kind,
                        cfg=ServingConfig(capacities=CAPS))
    (res,) = eng.run([req])
    ref = predict(models, req, ecfg, spec.kind)
    assert res.scores.shape == (21, spec.out_dim)
    assert np.array_equal(np.asarray(res.scores), np.asarray(ref.scores))
    assert eng.stats["batches"] == 3


def test_stream_yields_and_propagates_errors(setup):
    spec, ecfg, models, gmv = setup
    rng = np.random.default_rng(8)
    eng = ServingEngine(models, ecfg, spec.kind,
                        cfg=ServingConfig(capacities=CAPS, window=2))
    good = [_req(spec, rng, 2), _req(spec, rng, 3, b=False)]
    got = list(eng.serve_stream(iter(good)))
    assert {r.index for r in got} == {0, 1}
    # an unservable request mid-stream surfaces on the consumer thread
    with pytest.raises(ValueError, match="no modality"):
        list(eng.serve_stream(iter(good + [InferenceRequest(None, None)])))
    with pytest.raises(ValueError, match="server_gmv"):
        eng.run([_req(spec, rng, 2, vfl=True)])  # engine built without head


def test_sync_and_prefetch_paths_agree(setup):
    spec, ecfg, models, gmv = setup
    rng = np.random.default_rng(9)
    reqs = _mixed_requests(spec, rng)
    outs = []
    for prefetch in (0, 2):
        eng = ServingEngine(models, ecfg, spec.kind, server_gmv=gmv,
                            cfg=ServingConfig(capacities=CAPS, window=3,
                                              prefetch=prefetch))
        outs.append([np.asarray(r.scores) for r in eng.run(reqs)])
    for a, b in zip(*outs):
        assert np.array_equal(a, b)


# ------------------------------------------------------------- config -----

def test_serving_config_validation():
    with pytest.raises(ValueError, match="ascending"):
        ServingConfig(capacities=(4, 2))
    with pytest.raises(ValueError, match="floor"):
        ServingConfig(capacities=(1, 4))  # 1-row programs break parity
    with pytest.raises(ValueError, match="codec"):
        ServingConfig(codec="zstd")
    with pytest.raises(ValueError, match="window"):
        ServingConfig(window=0)
    with pytest.raises(ValueError, match="prefetch"):
        ServingConfig(prefetch=-1)


def test_bucket_for_ladder():
    assert bucket_for(1, CAPS) == 2
    assert bucket_for(2, CAPS) == 2
    assert bucket_for(3, CAPS) == 4
    assert bucket_for(8, CAPS) == 8
    with pytest.raises(ValueError, match="exceed"):
        bucket_for(9, CAPS)
    with pytest.raises(ValueError):
        bucket_for(0, CAPS)


def test_communication_cost_per_row_pricing():
    """Per-row message pricing: the serving engine's reconciliation
    contract. Dense fp32 is numerically unchanged from the old
    batch-as-one-message formula; codec'd rows each carry their own
    scale/index overhead."""
    dense = communication_cost(8, 64, "vfl", 25)
    assert dense["bytes"] == 8 * (2 * 64 + 25) * 4
    i8 = communication_cost(8, 64, "vfl", 25, codec="int8")
    row = wire.leaf_payload_bytes(64, wire.make_codec("int8"))
    out = wire.leaf_payload_bytes(25, wire.make_codec("int8"))
    assert i8["bytes"] == 8 * (2 * row + out)
