"""Substrate tests: optimizers, schedules, metrics, checkpointing, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro import optim
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.pipeline import Batcher, token_batches
from repro.data.synthetic import generate, make_task, train_val_test
from repro.metrics import auprc, auroc, bootstrap_ci


# -------------------------------------------------------------- optimizers --

def test_adamw_minimizes_quadratic():
    opt = optim.adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p: 2 * p, params)
        updates, state = opt.update(grads, state, params)
        params = optim.apply_updates(params, updates)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_adamw_weight_decay_shrinks():
    opt = optim.adamw(0.01, weight_decay=0.5)
    params = {"w": jnp.ones(4)}
    state = opt.init(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    for _ in range(10):
        updates, state = opt.update(zero_g, state, params)
        params = optim.apply_updates(params, updates)
    assert float(params["w"][0]) < 1.0


def test_sgd_momentum():
    opt = optim.sgd(0.1, momentum=0.9)
    params = {"w": jnp.asarray(4.0)}
    state = opt.init(params)
    for _ in range(200):
        updates, state = opt.update({"w": 2 * params["w"]}, state, params)
        params = optim.apply_updates(params, updates)
    assert abs(float(params["w"])) < 5e-2


def test_global_norm_clip():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = optim.global_norm_clip(g, 1.0)
    np.testing.assert_allclose(float(norm), 5.0, rtol=1e-6)
    np.testing.assert_allclose(
        float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"])))), 1.0, rtol=1e-5)


def test_schedules():
    sched = optim.linear_warmup_cosine(1.0, warmup=10, total_steps=100)
    assert float(sched(jnp.asarray(0))) == 0.0
    np.testing.assert_allclose(float(sched(jnp.asarray(10))), 1.0, rtol=1e-5)
    assert float(sched(jnp.asarray(100))) < 0.2


# ----------------------------------------------------------------- metrics --

def test_auroc_known_values():
    y = np.array([0, 0, 1, 1])
    s = np.array([0.1, 0.4, 0.35, 0.8])
    np.testing.assert_allclose(auroc(y, s), 0.75)  # sklearn's doc example
    assert auroc(np.array([1, 1]), np.array([0.5, 0.6])) != auroc(y, s)  # nan path
    assert np.isnan(auroc(np.array([1, 1]), np.array([0.5, 0.6])))


def test_auroc_perfect_and_random():
    y = np.array([0, 0, 1, 1])
    assert auroc(y, np.array([0.1, 0.2, 0.8, 0.9])) == 1.0
    assert auroc(y, np.array([0.9, 0.8, 0.2, 0.1])) == 0.0
    np.testing.assert_allclose(auroc(y, np.array([0.5, 0.5, 0.5, 0.5])), 0.5)


def test_auprc_known_value():
    y = np.array([0, 0, 1, 1])
    s = np.array([0.1, 0.4, 0.35, 0.8])
    np.testing.assert_allclose(auprc(y, s), 0.8333333, rtol=1e-5)


@given(n=st.integers(10, 200), seed=st.integers(0, 100))
@settings(max_examples=30, deadline=None)
def test_auroc_is_rank_statistic(n, seed):
    """AUROC must be invariant to any monotone transform of the scores."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 2, n)
    s = rng.normal(0, 1, n)
    if y.sum() in (0, n):
        return
    a1 = auroc(y, s)
    a2 = auroc(y, np.tanh(s) * 3 + 7)
    np.testing.assert_allclose(a1, a2, rtol=1e-9)


def test_bootstrap_ci_brackets_point():
    rng = np.random.default_rng(0)
    y = rng.integers(0, 2, 500)
    s = y * 0.5 + rng.normal(0, 0.5, 500)
    point, lo, hi = bootstrap_ci(auroc, y, s, n_boot=100)
    assert lo <= point <= hi
    assert hi - lo < 0.2


# ------------------------------------------------------------- checkpoints --

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.int32), "d": [jnp.zeros(2), jnp.ones(1)]}}
    save_checkpoint(str(tmp_path), 7, tree, {"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    zeros = jax.tree.map(jnp.zeros_like, tree)
    restored = restore_checkpoint(str(tmp_path), zeros)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros(3)})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), {"a": jnp.zeros(4)})


def test_checkpoint_picks_latest(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros(2)})
    save_checkpoint(str(tmp_path), 12, {"a": jnp.ones(2)})
    out = restore_checkpoint(str(tmp_path), {"a": jnp.zeros(2)})
    np.testing.assert_array_equal(np.asarray(out["a"]), np.ones(2))


def test_checkpoint_save_is_atomic(tmp_path):
    """A crash mid-write must never leave a partial ``step_N`` for
    ``latest_step`` to pick up: writes stage in ``step_N.tmp`` and rename
    into place; stale .tmp dirs are invisible to step selection."""
    import os

    # simulate a writer that died mid-write: a .tmp staging dir exists
    crashed = tmp_path / "step_00000009.tmp"
    crashed.mkdir()
    (crashed / "arrays.npz").write_bytes(b"partial garbage")
    assert latest_step(str(tmp_path)) is None  # .tmp is not a checkpoint

    save_checkpoint(str(tmp_path), 3, {"a": jnp.ones(2)})
    assert latest_step(str(tmp_path)) == 3
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path)
                   if d.startswith("step_00000003"))
    # a save of the crashed step sweeps the stale staging dir
    save_checkpoint(str(tmp_path), 9, {"a": jnp.full(2, 5.0)})
    assert not crashed.exists()
    out = restore_checkpoint(str(tmp_path), {"a": jnp.zeros(2)})
    np.testing.assert_array_equal(np.asarray(out["a"]), np.full(2, 5.0))
    # overwriting an existing step replaces it atomically
    save_checkpoint(str(tmp_path), 9, {"a": jnp.full(2, 7.0)})
    out = restore_checkpoint(str(tmp_path), {"a": jnp.zeros(2)}, step=9)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.full(2, 7.0))


def test_checkpoint_recovers_crashed_overwrite_swap(tmp_path):
    """Crash between the overwrite swap's two renames leaves the complete
    previous step as ``step_N.old``; latest_step/restore must still find
    it (read-only fallback — no rename, so readers can't race a live
    writer) instead of silently falling back to an older step."""
    import os

    save_checkpoint(str(tmp_path), 3, {"a": jnp.ones(2)})
    save_checkpoint(str(tmp_path), 9, {"a": jnp.full(2, 9.0)})
    # simulate the crash window: step_9 moved aside, new rename never ran
    os.rename(tmp_path / "step_00000009", tmp_path / "step_00000009.old")
    assert latest_step(str(tmp_path)) == 9  # found via .old, not 3
    out = restore_checkpoint(str(tmp_path), {"a": jnp.zeros(2)})
    np.testing.assert_array_equal(np.asarray(out["a"]), np.full(2, 9.0))
    # the completed step wins over its own leftover .old, which the next
    # save of that step sweeps
    (tmp_path / "step_00000003.old").mkdir()
    out = restore_checkpoint(str(tmp_path), {"a": jnp.zeros(2)}, step=3)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.ones(2))
    save_checkpoint(str(tmp_path), 3, {"a": jnp.full(2, 4.0)})
    assert not (tmp_path / "step_00000003.old").exists()
    # re-saving the crashed step itself also sweeps the stale .old
    save_checkpoint(str(tmp_path), 9, {"a": jnp.full(2, 10.0)})
    assert not (tmp_path / "step_00000009.old").exists()
    out = restore_checkpoint(str(tmp_path), {"a": jnp.zeros(2)}, step=9)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.full(2, 10.0))


def test_checkpoint_dtype_kind_mismatch_raises(tmp_path):
    """An int leaf restored into a float tree (e.g. ``last_round`` into a
    model leaf) must raise instead of passing a shape-only check."""
    save_checkpoint(str(tmp_path), 1, {"a": jnp.arange(3, dtype=jnp.int32)})
    with pytest.raises(ValueError, match="dtype mismatch"):
        restore_checkpoint(str(tmp_path), {"a": jnp.zeros(3, jnp.float32)})


def test_checkpoint_within_kind_casts_to_target(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": np.arange(3, dtype=np.float64)})
    out = restore_checkpoint(str(tmp_path), {"a": jnp.zeros(3, jnp.float32)})
    assert np.asarray(out["a"]).dtype == np.float32
    np.testing.assert_array_equal(np.asarray(out["a"]), [0.0, 1.0, 2.0])


def test_checkpoint_duplicate_flat_key_raises(tmp_path):
    """Nested {"a": {"b": ...}} collides with a literal "a/b" key in the
    flattened npz namespace — one leaf would silently win."""
    tree = {"a": {"b": jnp.zeros(2)}, "a/b": jnp.ones(2)}
    with pytest.raises(ValueError, match="duplicate"):
        save_checkpoint(str(tmp_path), 1, tree)


def test_train_style_resume_restores_opt_state(tmp_path):
    """Regression for the launch/train.py resume bug: params and
    opt_state checkpoint and restore TOGETHER, so AdamW moments and the
    schedule step survive a resume instead of replaying warmup."""
    from repro import optim

    params = {"w": jnp.ones((2, 2)), "b": jnp.zeros(2)}
    opt = optim.adamw(optim.linear_warmup_cosine(1e-3, warmup=10, total_steps=100))
    opt_state = opt.init(params)
    grads = jax.tree.map(jnp.ones_like, params)
    for _ in range(7):
        updates, opt_state = opt.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
    save_checkpoint(str(tmp_path), 7, {"params": params, "opt_state": opt_state})

    fresh = {"w": jnp.ones((2, 2)), "b": jnp.zeros(2)}
    target = {"params": fresh, "opt_state": opt.init(fresh)}
    restored = restore_checkpoint(str(tmp_path), target)
    assert int(restored["opt_state"]["step"]) == 7  # schedule step survives
    assert restored["opt_state"]["step"].dtype == np.int32
    for a, b in zip(jax.tree.leaves(restored["opt_state"]),
                    jax.tree.leaves(opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------------------------------- data --

def test_synthetic_is_learnable_and_complementary():
    """Modality A and B must each be predictive, and jointly more so —
    the structural property the paper's tables depend on."""
    spec = make_task("mortality")
    tr, va, te = train_val_test(spec, 2000, 10, 500, seed=0)

    # linear probe: least squares on flattened features
    def probe(xtr, xte):
        a = xtr.reshape(len(xtr), -1)
        w = np.linalg.lstsq(np.c_[a, np.ones(len(a))], tr.y[:, 0], rcond=None)[0]
        at = xte.reshape(len(xte), -1)
        return at @ w[:-1] + w[-1]

    flat = lambda d: d.reshape(len(d), -1)
    sa = probe(tr.x_a, te.x_a)
    sb = probe(tr.x_b, te.x_b)
    sj = probe(np.concatenate([flat(tr.x_a), flat(tr.x_b)], 1),
               np.concatenate([flat(te.x_a), flat(te.x_b)], 1))
    a_a, a_b, a_j = (auroc(te.y[:, 0], s) for s in (sa, sb, sj))
    assert a_a > 0.6 and a_b > 0.6
    assert a_j > max(a_a, a_b) - 0.02


def test_splits_are_disjoint():
    spec = make_task("smnist")
    tr, va, te = train_val_test(spec, 100, 50, 50, seed=0)
    assert not (set(tr.ids) & set(va.ids) or set(tr.ids) & set(te.ids)
                or set(va.ids) & set(te.ids))


def test_batcher_covers_all_rows():
    arrays = {"x": np.arange(23), "y": np.arange(23) * 2}
    bt = Batcher(arrays, 5, seed=0)
    seen = np.concatenate([b["x"] for b in bt.epoch()])
    assert sorted(seen.tolist()) == list(range(23))
    bt2 = Batcher(arrays, 5, seed=0, drop_remainder=True)
    seen2 = np.concatenate([b["x"] for b in bt2.epoch()])
    assert len(seen2) == 20


def test_token_batches_shapes():
    for b in token_batches(100, 4, 16, 3):
        assert b["tokens"].shape == (4, 16) and b["labels"].shape == (4, 16)
        assert b["tokens"].max() < 100
