"""All eight baselines run end-to-end and return the common metric dict."""
import jax
import numpy as np
import pytest

from repro.core.baselines import BASELINES
from repro.core.encoders import EncoderConfig
from repro.core.federation import FedConfig
from repro.core.partitioner import partition
from repro.data.synthetic import make_task, train_val_test

KEYS = ["multimodal_auroc", "uni_a_auroc", "uni_b_auroc",
        "multimodal_auprc", "uni_a_auprc", "uni_b_auprc"]


@pytest.fixture(scope="module")
def setup():
    spec = make_task("smnist")
    tr, va, te = train_val_test(spec, 300, 200, 200, seed=0)
    clients = partition(tr, 3, seed=1)
    ecfg = EncoderConfig(d_hidden=32, n_layers=2, enc_type="mlp")
    cfg = FedConfig(n_clients=3, rounds=2, lr=1e-2, batch_size=64, seed=0)
    return spec, clients, va, te, ecfg, cfg


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(BASELINES))
def test_baseline_runs(setup, name):
    spec, clients, va, te, ecfg, cfg = setup
    res, hist = BASELINES[name](jax.random.PRNGKey(0), spec, ecfg, clients,
                                va, te, cfg)
    for k in KEYS:
        assert k in res
        assert np.isnan(res[k]) or 0.0 <= res[k] <= 1.0


@pytest.mark.slow
def test_centralized_learns(setup):
    spec, clients, va, te, ecfg, _ = setup
    cfg = FedConfig(n_clients=3, rounds=25, lr=1e-2, batch_size=64, seed=0)
    res, _ = BASELINES["centralized"](jax.random.PRNGKey(0), spec, ecfg, clients,
                                      va, te, cfg)
    assert res["multimodal_auroc"] > 0.62


@pytest.mark.slow
def test_history_tracking(setup):
    spec, clients, va, te, ecfg, cfg = setup
    _, hist = BASELINES["fedavg"](jax.random.PRNGKey(0), spec, ecfg, clients,
                                  va, te, cfg, history_test=te)
    assert len(hist) == cfg.rounds
    assert all("multimodal_auroc" in h for h in hist)
