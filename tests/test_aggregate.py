"""Aggregation strategy interface (``repro.core.aggregate``).

Covers the strategy family's contracts: config validation and the
static structure flags; the "stateless adds NO state keys" layout rule
(default rounds keep the pre-strategy checkpoint layout); the FedProx
client term and mu=0 ≡ fedavg bit-exactness across full / sampled /
async rounds; SCAFFOLD's Option-II control-variate update against a
pure-numpy reference loop; and server-Adam moments surviving the full
round-state checkpoint path bit-exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregate
from repro.core.aggregate import StrategyConfig, make_strategy
from repro.core.encoders import EncoderConfig
from repro.core.federation import FedConfig, Federation
from repro.core.partitioner import partition
from repro.data.synthetic import make_task, train_val_test


# --------------------------------------------------- config + state layout --

def test_strategy_config_validation():
    with pytest.raises(ValueError, match="not in"):
        StrategyConfig(name="fedrandom")
    with pytest.raises(ValueError, match="server_opt"):
        StrategyConfig(server_opt="sgd")
    with pytest.raises(ValueError, match=">= 0"):
        StrategyConfig(name="fedprox", fedprox_mu=-0.1)
    with pytest.raises(ValueError, match="requires strategy 'fedprox'"):
        StrategyConfig(name="fedavg", fedprox_mu=0.1)


def test_strategy_structure_flags():
    default = StrategyConfig()
    assert default.score_based and not default.stateful
    assert not default.client_active
    scaffold = make_strategy("scaffold")
    assert scaffold.control and scaffold.stateful and scaffold.client_active
    assert not scaffold.score_based
    prox = make_strategy("fedprox", fedprox_mu=0.01)
    assert prox.prox and prox.client_active and not prox.stateful
    # fedprox at mu=0 degenerates to plain fedavg: no client term at all
    assert not make_strategy("fedprox", fedprox_mu=0.0).client_active
    adam = make_strategy("fedavg", server_opt="adam")
    assert adam.stateful and not adam.client_active


def test_stateless_strategies_add_no_state_keys():
    """The layout rule that keeps default checkpoints bit-compatible:
    only scaffold / server-opt strategies own state."""
    stacked = {"f_A": {"w": jnp.ones((3, 4))}}
    glob = {"f_A": {"w": jnp.ones(4)}}
    for scfg in (StrategyConfig(), make_strategy("fedavg"),
                 make_strategy("fedprox", fedprox_mu=0.1)):
        assert aggregate.init_state(scfg, stacked, glob) == {}
    st = aggregate.init_state(make_strategy("scaffold"), stacked, glob)
    assert set(st) == {"c_global", "c_local"}
    assert st["c_local"]["f_A"]["w"].shape == (3, 4)
    st = aggregate.init_state(make_strategy("fedavg", server_opt="adam"),
                              stacked, glob)
    assert set(st) == {"srv"} and set(st["srv"]) == {"m", "v", "t"}
    st = aggregate.init_state(make_strategy("fedavg", server_opt="momentum"),
                              stacked, glob)
    assert set(st["srv"]) == {"m", "t"}


def test_sharded_round_state_strat_block():
    """Sharded driver: default rounds carry no "strat" key; scaffold and
    server-opt rounds carry exactly their state, stacked over C."""
    from repro.core.federation_sharded import ShardedFedSpec, init_round_state

    kw = dict(n_clients=3, d_hidden=16, n_layers=1, seq_a=4, feat_a=3,
              seq_b=4, feat_b=3, out_dim=2, n_partial=8, n_frag=8,
              n_paired=8, n_val=16)
    assert "strat" not in init_round_state(
        jax.random.PRNGKey(0), ShardedFedSpec(**kw))
    state = init_round_state(
        jax.random.PRNGKey(0), ShardedFedSpec(strategy="scaffold", **kw))
    assert set(state["strat"]) == {"c_global", "c_local"}
    for leaf in jax.tree.leaves(state["strat"]["c_local"]):
        assert leaf.shape[0] == 3
    state = init_round_state(
        jax.random.PRNGKey(0),
        ShardedFedSpec(strategy="fedavg", server_opt="adam", **kw))
    assert set(state["strat"]) == {"srv"}


# ------------------------------------------------------------ client terms --

def test_client_term_prox_and_control():
    rng = np.random.default_rng(0)
    g = {"g_A": {"w": jnp.asarray(rng.normal(0, 1, (3, 4)).astype(np.float32))}}
    p = {"g_A": {"w": jnp.asarray(rng.normal(0, 1, (3, 4)).astype(np.float32))}}
    anchor = {"g_A": {"w": jnp.asarray(
        rng.normal(0, 1, (3, 4)).astype(np.float32))}}
    out = aggregate.client_term(make_strategy("fedprox", fedprox_mu=0.05),
                                g, p, {"anchor": anchor})
    np.testing.assert_allclose(
        np.asarray(out["g_A"]["w"]),
        np.asarray(g["g_A"]["w"])
        + 0.05 * (np.asarray(p["g_A"]["w"]) - np.asarray(anchor["g_A"]["w"])),
        rtol=1e-6)
    # control: unstacked c_global broadcasts against the stacked rows
    cg = {"g_A": {"w": jnp.asarray(rng.normal(0, 1, 4).astype(np.float32))}}
    cl = {"g_A": {"w": jnp.asarray(
        rng.normal(0, 1, (3, 4)).astype(np.float32))}}
    out = aggregate.client_term(make_strategy("scaffold"), g, p,
                                {"c_global": cg, "c_local": cl})
    np.testing.assert_allclose(
        np.asarray(out["g_A"]["w"]),
        np.asarray(g["g_A"]["w"]) + np.asarray(cg["g_A"]["w"])[None]
        - np.asarray(cl["g_A"]["w"]), rtol=1e-6)
    # None / inactive strat: grads pass through untouched (the default trace)
    assert aggregate.client_term(StrategyConfig(), g, p, None) is g


# ----------------------------------------------- SCAFFOLD numpy reference --

def test_scaffold_round_matches_numpy_reference():
    """Option II over two groups with different step counts, K=2 of C=4
    participants gathered: c_i+ = c_i - c + (anchor - trained)/(steps*lr),
    c+ = c + frac * mean_i(c_i+ - c_i)."""
    rng = np.random.default_rng(7)
    k, lr, frac = 2, 0.05, 2 / 4
    steps = {"f": 3.0, "g": 1.0}
    shapes = {"f": (5,), "g": (2, 3)}
    cg = {grp: {"w": rng.normal(0, 1, s).astype(np.float32)}
          for grp, s in shapes.items()}
    cl = {grp: {"w": rng.normal(0, 1, (k,) + s).astype(np.float32)}
          for grp, s in shapes.items()}
    anchor = {grp: {"w": rng.normal(0, 1, (k,) + s).astype(np.float32)}
              for grp, s in shapes.items()}
    trained = {grp: {"w": rng.normal(0, 1, (k,) + s).astype(np.float32)}
               for grp, s in shapes.items()}

    new_cg, new_cl = aggregate.scaffold_round(
        make_strategy("scaffold"),
        jax.tree.map(jnp.asarray, cg), jax.tree.map(jnp.asarray, cl),
        jax.tree.map(jnp.asarray, anchor), jax.tree.map(jnp.asarray, trained),
        steps, lr, frac)

    for grp in shapes:
        ref_cl = np.stack([
            cl[grp]["w"][i] - cg[grp]["w"]
            + (anchor[grp]["w"][i] - trained[grp]["w"][i])
            / (steps[grp] * lr)
            for i in range(k)])
        ref_cg = cg[grp]["w"] + frac * np.mean(ref_cl - cl[grp]["w"], axis=0)
        np.testing.assert_allclose(np.asarray(new_cl[grp]["w"]), ref_cl,
                                   rtol=2e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(new_cg[grp]["w"]), ref_cg,
                                   rtol=2e-5, atol=1e-5)


# --------------------------------------------- federation-level semantics --

@pytest.fixture(scope="module")
def small_fed():
    spec = make_task("smnist")
    tr, va, _ = train_val_test(spec, 240, 120, 40, seed=3)
    clients = partition(tr, 4, frac_paired=0.6, frac_fragmented=0.3,
                        frac_partial=0.1, seed=4)
    ecfg = EncoderConfig(d_hidden=16, n_layers=1, enc_type="mlp")
    return spec, clients, va, ecfg


def _run(small_fed, rounds=2, **kw):
    spec, clients, va, ecfg = small_fed
    cfg = FedConfig(n_clients=4, rounds=rounds, lr=1e-2, batch_size=32,
                    seed=0, **kw)
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)
    fed.fit()
    return fed


def _assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_aggregator_alias_fills_strategy():
    """`aggregator=` (the pre-strategy spelling) and `strategy=` configure
    the identical federation — the two fields are always equal."""
    assert FedConfig(aggregator="fedavg") == FedConfig(strategy="fedavg")
    cfg = FedConfig()
    assert cfg.strategy == cfg.aggregator == "blendavg"


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["full", "sampled", "async"])
def test_fedprox_mu0_is_fedavg_bitexact(small_fed, mode):
    """mu=0 kills the proximal term entirely (no strat block, identical
    trace), so fedprox degenerates to fedavg bit-for-bit — in full
    participation, K-of-C sampled, and async sampled rounds."""
    kw = {"full": {}, "sampled": {"n_sampled": 2},
          "async": {"n_sampled": 2, "async_mode": True}}[mode]
    a = _run(small_fed, strategy="fedavg", **kw)
    b = _run(small_fed, strategy="fedprox", fedprox_mu=0.0, **kw)
    _assert_tree_equal(a.global_models, b.global_models)
    _assert_tree_equal(a.stacked, b.stacked)


def test_scaffold_federation_updates_control_variates(small_fed):
    """In-host SCAFFOLD: control variates start at zero, move after a
    sampled round (participants' rows only), and c_global absorbs the
    K/C-weighted shift."""
    fed = _run(small_fed, rounds=2, strategy="scaffold", n_sampled=2)
    st = fed.strat_state
    assert set(st) >= {"c_global", "c_local"}
    assert any(float(np.abs(np.asarray(l)).max()) > 0
               for l in jax.tree.leaves(st["c_global"]))
    # only ever-sampled clients' c_local rows can be nonzero
    sampled = set(np.nonzero(fed.part_count)[0].tolist())
    for leaf in jax.tree.leaves(st["c_local"]):
        arr = np.asarray(leaf)
        for c in range(4):
            if c not in sampled:
                assert np.abs(arr[c]).max() == 0.0


@pytest.mark.slow
def test_fedprox_pull_shrinks_update_norm(small_fed):
    """Directional: a large mu pulls clients toward their round anchor,
    so the global model moves less than under plain fedavg."""
    a = _run(small_fed, rounds=1, strategy="fedavg")
    b = _run(small_fed, rounds=1, strategy="fedprox", fedprox_mu=10.0)
    spec, clients, va, ecfg = small_fed
    base = Federation.init(jax.random.PRNGKey(0),
                           FedConfig(n_clients=4, rounds=1, lr=1e-2,
                                     batch_size=32, seed=0),
                           spec, ecfg, clients, va).global_models

    def dist(fed):
        return sum(float(np.linalg.norm(np.asarray(x) - np.asarray(y)))
                   for x, y in zip(jax.tree.leaves(fed.global_models),
                                   jax.tree.leaves(base)))

    assert dist(b) < dist(a)


# ------------------------------------------- server-opt checkpoint parity --

def _tiny_sharded_batch(spec, rng):
    from repro.core.federation_sharded import batch_specs

    batch = {}
    for k, sd in batch_specs(spec).items():
        if k == "perm_b":
            batch[k] = jnp.asarray(rng.permutation(
                spec.n_clients * spec.n_frag).astype(np.int32))
        elif k.endswith("_y") or k.startswith("partial_y") or k == "val_y":
            batch[k] = jnp.asarray(
                (rng.random(sd.shape) < 0.3).astype(np.float32))
        else:
            batch[k] = jnp.asarray(rng.normal(0, 1, sd.shape).astype(np.float32))
    return batch


def test_server_adam_moments_checkpoint_parity(tmp_path):
    """FedAdam server moments ride the full-round-state checkpoint: a
    save/restore at round 2 then two more rounds is bit-identical to four
    uninterrupted rounds — moments, t, and the global models."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.core.federation_sharded import (
        ShardedFedSpec, init_round_state, make_blendfl_round)

    spec = ShardedFedSpec(n_clients=3, d_hidden=16, n_layers=1, seq_a=4,
                          feat_a=3, seq_b=4, feat_b=3, out_dim=2, n_partial=8,
                          n_frag=8, n_paired=8, n_val=16, strategy="fedavg",
                          server_opt="adam", server_lr=0.5)
    batches = [_tiny_sharded_batch(spec, np.random.default_rng(r))
               for r in range(4)]
    rf = jax.jit(make_blendfl_round(spec))

    state = init_round_state(jax.random.PRNGKey(0), spec)
    for b in batches[:2]:
        state, _ = rf(state, b)
    assert int(state["strat"]["srv"]["t"]) == 2
    save_checkpoint(str(tmp_path), 2, state)
    restored = restore_checkpoint(str(tmp_path),
                                  init_round_state(jax.random.PRNGKey(0), spec),
                                  step=2)
    _assert_tree_equal(state["strat"], restored["strat"])
    for b in batches[2:]:
        state, _ = rf(state, b)
        restored, _ = rf(restored, b)
    _assert_tree_equal(state, restored)
