"""Integration: the BlendFL federation (Algorithm 1) learns, its global
models broadcast correctly, and decentralized inference serves locally."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.encoders import EncoderConfig
from repro.core.federation import FedConfig, Federation, evaluate_global
from repro.core.inference import InferenceRequest, communication_cost, local_predict
from repro.core.partitioner import partition
from repro.data.synthetic import make_task, train_val_test


@pytest.fixture(scope="module")
def fed_setup():
    spec = make_task("smnist")
    tr, va, te = train_val_test(spec, 400, 300, 300, seed=0)
    clients = partition(tr, 3, seed=1)
    ecfg = EncoderConfig(d_hidden=48, n_layers=2, enc_type="mlp")
    return spec, tr, va, te, clients, ecfg


@pytest.mark.slow
def test_blendfl_learns(fed_setup):
    spec, tr, va, te, clients, ecfg = fed_setup
    cfg = FedConfig(n_clients=3, rounds=25, lr=1e-2, batch_size=64, seed=0)
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)
    r0 = evaluate_global(fed, te)
    fed.fit()
    r1 = evaluate_global(fed, te)
    assert r1["multimodal_auroc"] > max(r0["multimodal_auroc"] + 0.05, 0.6)
    assert r1["uni_a_auroc"] > 0.6 and r1["uni_b_auroc"] > 0.6


@pytest.mark.slow
def test_broadcast_synchronizes_clients(fed_setup):
    spec, tr, va, te, clients, ecfg = fed_setup
    cfg = FedConfig(n_clients=3, rounds=1, lr=1e-2, batch_size=64, seed=0)
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)
    fed.round()
    for k in range(3):
        for grp in ("f_A", "g_A", "g_M"):
            for a, b in zip(jax.tree.leaves(fed.models[k][grp]),
                            jax.tree.leaves(fed.global_models[grp])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_fedavg_aggregator_variant(fed_setup):
    spec, tr, va, te, clients, ecfg = fed_setup
    cfg = FedConfig(n_clients=3, rounds=3, lr=1e-2, batch_size=64,
                    aggregator="fedavg", seed=0)
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)
    hist = fed.fit()
    assert len(hist) == 3


@pytest.mark.slow
def test_decentralized_inference_all_modality_combos(fed_setup):
    spec, tr, va, te, clients, ecfg = fed_setup
    cfg = FedConfig(n_clients=3, rounds=2, lr=1e-2, batch_size=64, seed=0)
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)
    fed.fit()
    m = fed.global_models
    xb = te.x_b[:5]
    xa = te.x_a[:5]
    for req, expect in [
        (InferenceRequest(xa, xb), "multimodal"),
        (InferenceRequest(xa, None), "unimodal_A"),
        (InferenceRequest(None, xb), "unimodal_B"),
    ]:
        scores, mode = local_predict(m, req, ecfg, spec.kind)
        assert mode == expect
        assert np.asarray(scores).shape == (5, spec.out_dim)
    with pytest.raises(ValueError):
        local_predict(m, InferenceRequest(None, None), ecfg, spec.kind)


def test_inference_comm_cost():
    """Regression: the reported bytes must cover all 3 messages — the two
    feature uploads AND the score download (batch * out_dim * 4), which
    the old signature silently omitted."""
    dec = communication_cost(8, 64, "decentralized", 25)
    srv = communication_cost(8, 64, "vfl", 25)
    assert dec["bytes"] == 0 and dec["messages"] == 0
    assert srv["messages"] == 3
    assert srv["bytes"] == 2 * 8 * 64 * 4 + 8 * 25 * 4


@pytest.mark.slow
def test_blendavg_faster_or_equal_convergence_smoke(fed_setup):
    """Directional check behind Fig. 2 (full sweep in benchmarks)."""
    spec, tr, va, te, clients, ecfg = fed_setup
    scores = {}
    for agg in ("blendavg", "fedavg"):
        cfg = FedConfig(n_clients=3, rounds=8, lr=1e-2, batch_size=64,
                        aggregator=agg, seed=0)
        fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)
        fed.fit()
        scores[agg] = evaluate_global(fed, te)["multimodal_auroc"]
    # BlendAvg must be at least competitive early in training
    assert scores["blendavg"] >= scores["fedavg"] - 0.05
