"""Wire codec (repro.core.codec): round-trip error bounds, identity
cases, error-feedback accumulation vs a numpy reference, analytic byte
accounting, and codec-enabled federation rounds (both drivers) incl.
resume parity."""
import argparse
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec as wire
from repro.core.codec import (
    CODECS,
    CodecConfig,
    encode_decode_stacked,
    leaf_payload_bytes,
    make_codec,
    round_bytes,
    topk_k,
    uplink_roundtrip,
    zeros_like_tree,
)


def _tree(key, l=3):
    ks = jax.random.split(key, 3)
    return {"w": jax.random.normal(ks[0], (l, 16, 8)),
            "b": jax.random.normal(ks[1], (l, 8)) * 0.1,
            "v": jax.random.normal(ks[2], (l, 333))}


# ------------------------------------------------------------ round-trips --

def test_codec_names_validated():
    assert set(CODECS) == {"none", "int8", "topk", "int8_topk"}
    with pytest.raises(ValueError):
        CodecConfig(name="fp8")
    with pytest.raises(ValueError):
        CodecConfig(name="topk", topk_frac=0.0)


def test_none_codec_is_identity_object():
    t = _tree(jax.random.PRNGKey(0))
    assert encode_decode_stacked(t, CodecConfig()) is t


def test_int8_roundtrip_error_bound():
    """Symmetric int8: |dec - x| <= scale/254 per element (nearest
    rounding over a 127-level grid, scale = per-(row, leaf) abs-max)."""
    t = _tree(jax.random.PRNGKey(1))
    dec = encode_decode_stacked(t, make_codec("int8"))
    for k in t:
        x = np.asarray(t[k]).reshape(t[k].shape[0], -1)
        d = np.asarray(dec[k]).reshape(x.shape)
        scale = np.abs(x).max(axis=1, keepdims=True)
        assert (np.abs(d - x) <= scale / 254 + 1e-7).all()


def test_topk_full_frac_bitexact_with_none():
    """topk at frac=1.0 is the identity codec — bit-exact with none."""
    t = _tree(jax.random.PRNGKey(2))
    dec = encode_decode_stacked(t, make_codec("topk", topk_frac=1.0))
    for k in t:
        np.testing.assert_array_equal(np.asarray(dec[k]), np.asarray(t[k]))


def test_topk_keeps_largest_magnitudes():
    x = {"w": jnp.asarray(np.random.default_rng(0)
                          .permutation(np.arange(1.0, 101.0))
                          .reshape(1, 100))}
    dec = encode_decode_stacked(x, make_codec("topk", topk_frac=0.25))
    got = np.asarray(dec["w"])[0]
    keep = got != 0
    assert keep.sum() == 25
    assert set(np.asarray(x["w"])[0][keep]) == set(range(76, 101))
    np.testing.assert_array_equal(got[keep], np.asarray(x["w"])[0][keep])


def _np_int8_topk(x, frac):
    """Numpy oracle of one int8_topk message round-trip (per row)."""
    out = np.zeros_like(x)
    for i, row in enumerate(x):
        k = max(1, math.ceil(frac * row.size))
        mags = np.sort(np.abs(row))[::-1]
        thresh, scale = mags[k - 1], max(mags[0], 1e-30)
        q = np.clip(np.round(row * (127.0 / scale)), -127, 127)
        deq = q * (scale / 127.0)
        out[i] = np.where(np.abs(row) >= thresh, deq, 0.0)
    return out


def test_error_feedback_matches_numpy_reference():
    """Drive uplink_roundtrip for several rounds against a numpy EF loop
    and check the telescoping identity sum(dec) = sum(delta) - resid_T."""
    cfg = make_codec("int8_topk", topk_frac=0.25)
    rng = np.random.default_rng(3)
    base_np = rng.normal(size=(2, 40)).astype(np.float32)
    base = {"w": jnp.asarray(base_np)}
    resid = zeros_like_tree(base)
    resid_np = np.zeros_like(base_np)
    cur_np = base_np.copy()
    sum_delta = np.zeros_like(base_np)
    sum_dec = np.zeros_like(base_np)

    for step in range(4):
        delta = rng.normal(scale=0.1, size=base_np.shape).astype(np.float32)
        trained = {"w": jnp.asarray(cur_np + delta)}
        cand, resid = uplink_roundtrip(trained, {"w": jnp.asarray(cur_np)},
                                       resid, cfg)
        # numpy reference: c = delta + resid; dec = codec(c); resid' = c - dec
        delta_np = np.asarray(trained["w"]) - cur_np
        c = delta_np + resid_np
        dec = _np_int8_topk(c, 0.25)
        resid_np = c - dec
        np.testing.assert_allclose(np.asarray(resid["w"]), resid_np,
                                   atol=1e-6, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(cand["w"]), cur_np + dec,
                                   atol=1e-6, rtol=1e-5)
        sum_delta += delta_np
        sum_dec += dec
        cur_np = cur_np + dec  # receiver view advances by the decoded delta

    np.testing.assert_allclose(sum_dec + resid_np, sum_delta,
                               atol=1e-6, rtol=1e-5)
    # lossy codec on noise: the residual must actually be carrying error
    assert np.abs(resid_np).max() > 0


def test_error_feedback_off_keeps_residual():
    cfg = CodecConfig(name="int8", error_feedback=False)
    base = {"w": jnp.zeros((1, 8))}
    resid = zeros_like_tree(base)
    trained = {"w": jnp.full((1, 8), 0.3)}
    _, new_resid = uplink_roundtrip(trained, base, resid, cfg)
    assert new_resid is resid  # untouched, not accumulated


# --------------------------------------------------------- byte accounting --

def test_leaf_payload_bytes():
    n = 1000
    assert leaf_payload_bytes(n, CodecConfig()) == 4 * n
    assert leaf_payload_bytes(n, make_codec("int8")) == n + 4
    k = topk_k(n, 0.25)
    assert leaf_payload_bytes(n, make_codec("topk")) == k * (4 + 2)
    assert leaf_payload_bytes(n, make_codec("int8_topk")) == 4 + k * (1 + 2)
    # wide leaves need 4-byte indices
    wide = 70000
    kw = topk_k(wide, 0.25)
    assert leaf_payload_bytes(wide, make_codec("topk")) == kw * (4 + 4)


def test_round_bytes_ratio_meets_target():
    """int8_topk at the default frac must price >= 3.5x below dense —
    the bench acceptance is analytic, so the unit test can assert it."""
    t = {"w": jnp.zeros((64, 64)), "b": jnp.zeros((64,))}
    rb = round_bytes(t, make_codec("int8_topk", topk_frac=0.25),
                     n_up=4, n_down=4)
    assert rb["compression_ratio"] >= 3.5
    assert rb["bytes_per_round"] == 8 * rb["bytes_per_message"]
    assert rb["dense_bytes_per_round"] == 8 * (64 * 64 + 64) * 4


def test_communication_cost_codec_aware():
    from repro.core.inference import communication_cost

    dec = communication_cost(8, 64, "decentralized", 25)
    assert dec == {"messages": 0, "bytes": 0}
    dense = communication_cost(8, 64, "vfl", 25)
    assert dense["bytes"] == (2 * 8 * 64 + 8 * 25) * 4  # fp32 default
    bf16 = communication_cost(8, 64, "vfl", 25, dtype_bytes=2)
    assert bf16["bytes"] == dense["bytes"] // 2
    # each sample row is its own wire message (per-row int8 scale), the
    # convention the serving engine's padded batches rely on: feature
    # rows are 64 values + a 4-byte scale, score rows 25 values + scale
    i8 = communication_cost(8, 64, "vfl", 25, codec="int8")
    assert i8["bytes"] == 2 * 8 * (64 + 4) + 8 * (25 + 4)
    assert i8["messages"] == 3


# ------------------------------------------------- federation integration --

def _sharded_batch(spec, rng):
    from repro.core.federation_sharded import batch_specs

    batch = {}
    for k, sd in batch_specs(spec).items():
        if k == "perm_b":
            batch[k] = jnp.asarray(
                rng.permutation(spec.k_round * spec.n_frag).astype(np.int32))
        elif k == "sampled":
            batch[k] = jnp.asarray(rng.choice(
                spec.n_clients, spec.n_sampled, replace=False).astype(np.int32))
        elif k.endswith("_y") or k.startswith("partial_y") or k == "val_y":
            batch[k] = jnp.asarray((rng.random(sd.shape) < 0.3).astype(np.float32))
        else:
            batch[k] = jnp.asarray(rng.normal(0, 1, sd.shape).astype(np.float32))
    return batch


def _tiny_spec(**kw):
    from repro.core.federation_sharded import ShardedFedSpec

    base = dict(n_clients=4, d_hidden=16, n_layers=1, seq_a=4, feat_a=3,
                seq_b=4, feat_b=3, out_dim=2, n_partial=8, n_frag=8,
                n_paired=8, n_val=16, lr=5e-2)
    base.update(kw)
    return ShardedFedSpec(**base)


@pytest.mark.slow
def test_sharded_codec_round_state_and_cache():
    """Codec rounds thread residual state, keep the one-compile-per-
    round invariant, and accumulate a nonzero uplink residual; codec
    "none" adds no state keys (checkpoint layout unchanged)."""
    from repro.core.federation_sharded import (
        init_round_state, make_blendfl_round)

    assert "codec" not in init_round_state(jax.random.PRNGKey(0), _tiny_spec())

    spec = _tiny_spec(codec="int8_topk", n_sampled=2)
    state = init_round_state(jax.random.PRNGKey(0), spec)
    assert set(state["codec"]) == {"resid_up", "resid_down"}
    for leaf in jax.tree.leaves(state["codec"]["resid_up"]):
        assert leaf.shape[0] == spec.n_clients
    rf = jax.jit(make_blendfl_round(spec))
    rng = np.random.default_rng(0)
    for _ in range(2):
        state, m = rf(state, _sharded_batch(spec, rng))
    assert rf._cache_size() == 1
    for k in ("loss_uni", "loss_vfl", "loss_paired"):
        assert np.isfinite(float(m[k]))
    rmax = max(float(jnp.abs(l).max())
               for l in jax.tree.leaves(state["codec"]["resid_up"]))
    assert rmax > 0


@pytest.mark.slow
def test_sharded_identity_codec_bitexact_with_none():
    """topk at frac=1.0 must leave the whole round bit-identical to the
    uncompressed round — the codec stage adds no float noise of its own."""
    from repro.core.federation_sharded import (
        init_round_state, make_blendfl_round)

    outs = []
    for codec in ("none", "topk"):
        spec = _tiny_spec(codec=codec, topk_frac=1.0)
        state = init_round_state(jax.random.PRNGKey(0), spec)
        rf = jax.jit(make_blendfl_round(spec))
        rng = np.random.default_rng(1)
        for _ in range(2):
            state, _ = rf(state, _sharded_batch(spec, rng))
        outs.append(state)
    for key in ("models", "global_models", "server_gmv", "opt"):
        for a, b in zip(jax.tree.leaves(outs[0][key]),
                        jax.tree.leaves(outs[1][key])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # identity codec: residuals stay exactly zero
    for leaf in jax.tree.leaves(outs[1]["codec"]):
        assert not np.asarray(leaf).any()


@pytest.mark.slow
def test_inhost_codec_round_runs():
    """In-host driver: codec rounds run (full + sampled/async), losses
    finite, residuals accumulate."""
    from repro.core.encoders import EncoderConfig
    from repro.core.federation import FedConfig, Federation
    from repro.core.partitioner import partition
    from repro.data.synthetic import make_task, train_val_test

    spec = make_task("smnist")
    tr, va, _ = train_val_test(spec, 200, 100, 100, seed=0)
    ecfg = EncoderConfig(d_hidden=16, n_layers=1, enc_type="mlp")

    cfg = FedConfig(n_clients=3, rounds=2, lr=1e-2, batch_size=64, seed=0,
                    codec="int8_topk", topk_frac=0.25)
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg,
                          partition(tr, 3, seed=1), va)
    for _ in range(2):
        logs = fed.round()
    assert np.isfinite(logs["loss_partial"])
    rmax = max(float(abs(np.asarray(l)).max())
               for l in jax.tree.leaves(fed.resid_up))
    assert rmax > 0

    cfg = FedConfig(n_clients=4, rounds=2, lr=1e-2, batch_size=64, seed=0,
                    n_sampled=2, async_mode=True, codec="int8")
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg,
                          partition(tr, 4, seed=1), va)
    for _ in range(2):
        logs = fed.round()
    assert len(logs["sampled"]) == 2


@pytest.mark.slow
def test_resume_parity_codec(tmp_path):
    """Killed-and-resumed codec runs stay bit-identical: the residual
    trees checkpoint/restore through the full-round-state path."""
    from repro.launch.train_federated import selftest_resume

    selftest_resume(argparse.Namespace(
        task="smnist", clients=6, n_sampled=3, rounds=4, n_train=384,
        n_val=64, rows_cap=16, d_hidden=16, n_layers=1, lr=1e-2,
        optimizer="adamw", dirichlet_alpha=None, seed=0, data_seed=0,
        prefetch=1, ckpt_dir=None, ckpt_every=2, log_every=0,
        codec="int8_topk", topk_frac=0.25))
