"""``tools/bench_check.py`` — the BENCH_*.json schema gate in `make ci`.

Runs the checker as a subprocess against scratch results directories
(the same way the Makefile invokes it), covering: empty-dir pass,
conforming records pass, and one failure per schema rule — unparseable
JSON, missing envelope keys, record/records ambiguity, non-finite
numbers (incl. the non-RFC ``NaN`` literal ``json.dump`` emits),
compile-cache counts < 1, wire-codec compression fields (ratio < 1,
zero byte counts; null ``bytes_to_target`` stays valid), and
convergence fields (``rounds_to_target`` null-or-int>=1, AUROCs inside
the unit interval), scenario event counts (``n_join`` / ``n_leave`` /
``n_corrupt`` int >= 0), attack accounting
(``backdoor_success_rate`` a number in [0, 1]), and serving accounting
(``p50_ms`` / ``p99_ms`` >= 0 with p50 <= p99 per record, ``rps`` /
``rows_per_s`` > 0, ``bytes_per_request`` >= 0).
"""
import json
import os
import subprocess
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(results_dir):
    return subprocess.run(
        [sys.executable, os.path.join(REPO_ROOT, "tools", "bench_check.py"),
         str(results_dir)], capture_output=True, text=True)


def _write(tmp_path, name, payload):
    path = tmp_path / name
    path.write_text(payload if isinstance(payload, str)
                    else json.dumps(payload))
    return path


GOOD = {"bench": "round_engine", "backend": "cpu",
        "records": [{"n_clients": 3, "s_per_round": 0.12, "caches": [1, 1]},
                    {"n_clients": 8, "s_per_round": 0.33, "compile_cache": 1}]}


def test_empty_dir_passes(tmp_path):
    r = _run(tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "nothing to validate" in r.stdout


def test_conforming_records_pass(tmp_path):
    _write(tmp_path, "BENCH_a.json", GOOD)
    _write(tmp_path, "BENCH_b.json",
           {"bench": "loader", "backend": "cpu", "record": {"x": 1.5}})
    r = _run(tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "2 record file(s)" in r.stdout


def test_violations_fail_with_paths(tmp_path):
    _write(tmp_path, "BENCH_trunc.json", '{"bench": "x", "backend":')
    _write(tmp_path, "BENCH_envelope.json", {"record": {"x": 1}})
    _write(tmp_path, "BENCH_both.json",
           {"bench": "b", "backend": "cpu", "record": {}, "records": []})
    # json.dump writes NaN as a bare literal; the checker must flag it
    _write(tmp_path, "BENCH_nan.json",
           '{"bench": "n", "backend": "cpu", "record": {"t": NaN}}')
    _write(tmp_path, "BENCH_cache.json",
           {"bench": "c", "backend": "cpu",
            "records": [{"compile_cache": 0}]})
    r = _run(tmp_path)
    assert r.returncode == 1
    out = r.stdout
    assert "unparseable JSON" in out
    assert "BENCH_envelope.json.bench" in out
    assert "need exactly one of" in out
    assert "non-finite number" in out
    assert "cache count must be an int >= 1" in out


def test_compression_fields_validated(tmp_path):
    _write(tmp_path, "BENCH_ratio.json",
           {"bench": "comm", "backend": "cpu",
            "records": [{"codec": "int8_topk", "compression_ratio": 0.8}]})
    _write(tmp_path, "BENCH_bytes.json",
           {"bench": "comm", "backend": "cpu",
            "record": {"bytes_per_round": 0}})
    r = _run(tmp_path)
    assert r.returncode == 1
    assert "compression ratio must be a number >= 1" in r.stdout
    assert "byte count must be a number > 0" in r.stdout


def test_null_bytes_to_target_is_valid(tmp_path):
    """`bytes_to_target: null` means the run never hit the target AUROC —
    a legitimate measurement, not a schema violation."""
    _write(tmp_path, "BENCH_comm.json",
           {"bench": "comm_codec", "backend": "cpu",
            "records": [{"codec": "topk", "compression_ratio": 2.7,
                         "bytes_per_round": 96816, "bytes_to_target": None,
                         "compile_cache": 1}]})
    r = _run(tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr


def test_convergence_fields_validated(tmp_path):
    _write(tmp_path, "BENCH_rounds.json",
           {"bench": "aggregation", "backend": "cpu",
            "records": [{"strategy": "scaffold", "rounds_to_target": 0},
                        {"strategy": "fedavg", "rounds_to_target": 3.5}]})
    _write(tmp_path, "BENCH_auroc.json",
           {"bench": "aggregation", "backend": "cpu",
            "record": {"final_auroc": 1.2}})
    r = _run(tmp_path)
    assert r.returncode == 1
    assert r.stdout.count("rounds-to-target must be an int >= 1") == 2
    assert "AUROC must be a number in [0, 1]" in r.stdout


def test_null_rounds_to_target_is_valid(tmp_path):
    """`rounds_to_target: null` means the strategy never hit the target
    within the bench's round budget — a measurement, not a violation."""
    _write(tmp_path, "BENCH_agg.json",
           {"bench": "aggregation", "backend": "cpu",
            "records": [{"strategy": "fedavg", "cohort": "dirichlet",
                         "rounds_to_target": None, "target_auroc": 0.8,
                         "final_auroc": 0.76, "best_auroc": 0.79,
                         "compile_cache": 1}]})
    r = _run(tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr


def test_event_counts_validated(tmp_path):
    _write(tmp_path, "BENCH_events.json",
           {"bench": "scenario", "backend": "cpu",
            "records": [{"policy": "uniform", "n_join": -1},
                        {"policy": "omega_ema", "n_leave": 1.5},
                        {"policy": "data_volume", "n_corrupt": True}]})
    r = _run(tmp_path)
    assert r.returncode == 1
    assert r.stdout.count("scenario event count must be an int >= 0") == 3


def test_zero_event_counts_are_valid(tmp_path):
    """A churn-free scenario record (all counts 0) is a measurement,
    not a violation."""
    _write(tmp_path, "BENCH_scenario.json",
           {"bench": "scenario", "backend": "cpu",
            "n_join": 0, "n_leave": 0, "n_corrupt": 0,
            "records": [{"policy": "uniform", "rounds_to_target": None,
                         "target_auroc": 0.8, "final_auroc": 0.7,
                         "best_auroc": 0.75, "caches": [1, 1]}]})
    r = _run(tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr


def test_backdoor_success_rate_validated(tmp_path):
    _write(tmp_path, "BENCH_atk.json",
           {"bench": "attack", "backend": "cpu",
            "records": [{"attack": "backdoor", "backdoor_success_rate": 1.2},
                        {"attack": "scale", "backdoor_success_rate": -0.1},
                        {"attack": "none", "backdoor_success_rate": None}]})
    r = _run(tmp_path)
    assert r.returncode == 1
    assert r.stdout.count("attack success rate must be a number in "
                          "[0, 1]") == 3


def test_attack_matrix_record_conforms(tmp_path):
    """A full BENCH_attack cell — both rate extremes are legal values."""
    _write(tmp_path, "BENCH_attack.json",
           {"bench": "attack", "backend": "cpu",
            "records": [{"attack": "backdoor", "defense": "median",
                         "rounds_to_target": None, "target_auroc": 0.8,
                         "final_auroc": 0.77, "best_auroc": 0.79,
                         "backdoor_success_rate": 0.0, "compile_cache": 1},
                        {"attack": "sign_flip", "defense": "fedavg",
                         "rounds_to_target": 7, "target_auroc": 0.8,
                         "final_auroc": 0.85, "best_auroc": 0.85,
                         "backdoor_success_rate": 1.0, "compile_cache": 1}]})
    r = _run(tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr


def test_latency_fields_validated(tmp_path):
    _write(tmp_path, "BENCH_lat.json",
           {"bench": "serve", "backend": "cpu",
            "records": [{"mix": "all_multimodal", "p50_ms": -1.0},
                        {"mix": "vfl_heavy", "p99_ms": "fast"}]})
    _write(tmp_path, "BENCH_tp.json",
           {"bench": "serve", "backend": "cpu",
            "records": [{"rps": 0}, {"rows_per_s": -3.2}]})
    _write(tmp_path, "BENCH_breq.json",
           {"bench": "serve", "backend": "cpu",
            "record": {"bytes_per_request": -8}})
    r = _run(tmp_path)
    assert r.returncode == 1
    assert r.stdout.count("latency must be a number >= 0 ms") == 2
    assert r.stdout.count("throughput must be a number > 0") == 2
    assert "byte count must be a number >= 0" in r.stdout


def test_inverted_percentiles_flagged(tmp_path):
    """p50 > p99 in the same record means the percentile bookkeeping
    broke, even though both values are individually valid."""
    _write(tmp_path, "BENCH_pinv.json",
           {"bench": "serve", "backend": "cpu",
            "records": [{"mix": "vfl_heavy", "p50_ms": 40.0,
                         "p99_ms": 12.0, "rps": 55.0}]})
    r = _run(tmp_path)
    assert r.returncode == 1
    assert "exceeds p99_ms" in r.stdout


def test_serve_record_conforms(tmp_path):
    """A full BENCH_serve record — zero bytes/request on an all-local
    mix is a measurement, not a violation (unlike round-traffic bytes)."""
    _write(tmp_path, "BENCH_serve.json",
           {"bench": "serve_engine", "backend": "cpu",
            "records": [{"mix": "all_multimodal", "codec": "none",
                         "p50_ms": 2.4, "p99_ms": 6.1, "rps": 4100.0,
                         "rows_per_s": 24500.0, "bytes_per_request": 0.0},
                        {"mix": "vfl_heavy", "codec": "int8_topk",
                         "p50_ms": 38.0, "p99_ms": 122.0, "rps": 61.0,
                         "rows_per_s": 370.0, "bytes_per_request": 160.4}],
            "record_extra": {"caches": [1, 1, 1, 1]}})
    r = _run(tmp_path)
    assert r.returncode == 0, r.stdout + r.stderr


def test_repo_results_dir_conforms():
    """Whatever records this machine's bench runs have produced must
    already conform — the gate `make ci` applies."""
    r = _run(os.path.join(REPO_ROOT, "benchmarks", "results"))
    assert r.returncode == 0, r.stdout + r.stderr
