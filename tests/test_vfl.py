"""Split (VFL) training: the wire protocol must equal joint autodiff."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import vfl
from repro.core.encoders import EncoderConfig, encoder_init, fusion_init


def _setup(seed=0, n=16):
    rng = np.random.default_rng(seed)
    ecfg = EncoderConfig(d_hidden=32, n_layers=2, enc_type="mlp")
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    f_a = encoder_init(ks[0], 6, ecfg)
    f_b = encoder_init(ks[1], 5, ecfg)
    gmv = fusion_init(ks[2], 32, 3)
    batch = vfl.VflBatch(
        x_a=rng.normal(0, 1, (n, 4, 6)).astype(np.float32),
        x_b=rng.normal(0, 1, (n, 7, 5)).astype(np.float32),
        y=np.eye(3, dtype=np.float32)[rng.integers(0, 3, n)],
        owner_a=np.zeros(n), owner_b=np.ones(n))
    return f_a, f_b, gmv, batch, ecfg


def test_split_equals_joint_autodiff():
    f_a, f_b, gmv, batch, ecfg = _setup()
    l1, g1 = vfl.vfl_step(f_a, f_b, gmv, batch, ecfg, "multiclass")
    l2, g2 = vfl.vfl_step_split(f_a, f_b, gmv, batch, ecfg, "multiclass")
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    for k in ("f_A", "f_B", "g_M_v"):
        for a, b in zip(jax.tree.leaves(g1[k]), jax.tree.leaves(g2[k])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, rtol=1e-5)


def test_client_backward_is_exact_vjp():
    f_a, _, _, batch, ecfg = _setup(1)
    x = jnp.asarray(batch.x_a)
    cot = jax.random.normal(jax.random.PRNGKey(9), (len(batch.y), 32))

    g1 = vfl.client_backward(f_a, x, cot, ecfg)
    # finite-difference check on one scalar parameter direction
    leaf_path = ("in", "w")
    eps = 1e-3
    def loss(f):
        h = vfl.client_forward(f, x, ecfg)
        return jnp.sum(h * cot)
    def perturb(f, d):
        return {**f, "in": {**f["in"], "w": f["in"]["w"] + d}}
    direction = jnp.zeros_like(f_a["in"]["w"]).at[0, 0].set(1.0)
    fd = (loss(perturb(f_a, eps * direction)) - loss(perturb(f_a, -eps * direction))) / (2 * eps)
    np.testing.assert_allclose(float(g1["in"]["w"][0, 0]), float(fd), rtol=1e-2, atol=1e-3)


def test_align_by_id():
    ia = np.array([10, 3, 7, 99])
    ib = np.array([7, 11, 3])
    common, ra, rb = vfl.align_by_id(ia, ib)
    np.testing.assert_array_equal(common, [3, 7])
    np.testing.assert_array_equal(ia[ra], common)
    np.testing.assert_array_equal(ib[rb], common)


def test_build_vfl_batches_alignment():
    from repro.core.partitioner import partition
    from repro.data.synthetic import generate, make_task

    spec = make_task("smnist")
    data = generate(spec, 200, seed=0)
    clients = partition(data, 3, seed=0)
    rng = np.random.default_rng(0)
    batches = vfl.build_vfl_batches(clients, 64, rng)
    # every aligned row must carry the same underlying sample: the
    # synthetic generator makes x_a/x_b deterministic per id, so check
    # labels agree row-for-row
    seen = 0
    for b in batches:
        seen += len(b.y)
        assert b.x_a.shape[0] == b.x_b.shape[0] == b.y.shape[0]
        assert (b.owner_a != b.owner_b).all()  # fragmented = split across clients
    from repro.core.partitioner import fragmented_overlap
    assert seen == len(fragmented_overlap(clients))
