"""SPMD BlendFL round (federation_sharded): semantics on the host device.

The sharded round is the dry-run's distribution entry; here we verify its
MATH matches the paper's aggregation semantics when run unsharded (the
SPMD program is identical math on 1 or 512 devices — that's the point of
SPMD). Since the refactor it is also a consumer of the shared
``repro.core.engine`` phase functions, so these tests double as engine
coverage for the mask-free (uniform-rows) layout."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.federation_sharded import (
    ShardedFedSpec,
    batch_specs,
    init_round_state,
    init_stacked_models,
    make_blendfl_round,
)


def _make_batch(spec, rng):
    batch = {}
    for k, sd in batch_specs(spec).items():
        if k == "perm_b":
            batch[k] = jnp.asarray(
                rng.permutation(spec.n_clients * spec.n_frag).astype(np.int32))
        elif "y" in k.split("_")[-1] or k.endswith("_y") or k.startswith("partial_y") or k == "val_y":
            batch[k] = jnp.asarray((rng.random(sd.shape) < 0.3).astype(np.float32))
        else:
            # class-conditional-ish signal so training reduces the loss
            base = rng.normal(0, 1, sd.shape).astype(np.float32)
            batch[k] = jnp.asarray(base)
    return batch


@pytest.fixture(scope="module")
def small():
    spec = ShardedFedSpec(n_clients=4, d_hidden=32, n_layers=2, seq_a=8, feat_a=6,
                          seq_b=8, feat_b=6, out_dim=5, n_partial=32, n_frag=32,
                          n_paired=32, n_val=64, lr=5e-2)
    return spec, _make_batch(spec, np.random.default_rng(0))


def test_round_runs_and_losses_finite(small):
    spec, batch = small
    state = init_round_state(jax.random.PRNGKey(0), spec)
    rf = jax.jit(make_blendfl_round(spec))
    state, m = rf(state, batch)
    for k in ("loss_uni", "loss_vfl", "loss_paired"):
        assert np.isfinite(float(m[k]))


@pytest.mark.slow
def test_loss_decreases_over_rounds(small):
    spec, batch = small
    state = init_round_state(jax.random.PRNGKey(0), spec)
    rf = jax.jit(make_blendfl_round(spec))
    losses = []
    for _ in range(6):
        state, m = rf(state, batch)
        losses.append(float(m["loss_uni"]) + float(m["loss_vfl"])
                      + float(m["loss_paired"]))
    assert losses[-1] < losses[0]


@pytest.mark.slow
def test_adamw_round_decreases_loss(small):
    spec, batch = small
    spec = ShardedFedSpec(**{**spec.__dict__, "optimizer": "adamw", "lr": 1e-2})
    state = init_round_state(jax.random.PRNGKey(0), spec)
    # per-client AdamW moments live inside the state dict, stacked over C
    assert "mu" in state["opt"]
    for leaf in jax.tree.leaves(state["opt"]["mu"]):
        assert leaf.shape[0] == spec.n_clients
    rf = jax.jit(make_blendfl_round(spec))
    losses = []
    for _ in range(5):
        state, m = rf(state, batch)
        losses.append(float(m["loss_uni"]) + float(m["loss_vfl"])
                      + float(m["loss_paired"]))
    assert losses[-1] < losses[0]


def test_omega_is_simplex_or_zero(small):
    spec, batch = small
    state = init_round_state(jax.random.PRNGKey(0), spec)
    rf = jax.jit(make_blendfl_round(spec))
    _, m = rf(state, batch)
    for key in ("omega_A", "omega_B", "omega_M"):
        w = np.asarray(m[key])
        assert (w >= 0).all()
        assert abs(w.sum() - 1.0) < 1e-5 or w.sum() == 0.0


def test_broadcast_resets_all_clients_to_blend(small):
    spec, batch = small
    state = init_round_state(jax.random.PRNGKey(0), spec)
    rf = jax.jit(make_blendfl_round(spec))
    state, _ = rf(state, batch)
    for grp in ("f_A", "g_A", "g_M"):
        for leaf, gleaf in zip(jax.tree.leaves(state["models"][grp]),
                               jax.tree.leaves(state["global_models"][grp])):
            for c in range(spec.n_clients):
                np.testing.assert_allclose(np.asarray(leaf[c]), np.asarray(gleaf),
                                           rtol=1e-6, atol=1e-7)


def test_server_head_opt_state_uses_srv_opt():
    """Regression: init_round_state used fns.opt.init for the server head,
    so a spec with its own server schedule horizon would thread state
    initialized by the WRONG optimizer. The state must come from
    fns.srv_opt (the server_total_steps horizon), and a cosine round with
    distinct client/server horizons must run."""
    from repro.core.engine import make_phase_fns

    spec = ShardedFedSpec(n_clients=2, d_hidden=16, n_layers=1, seq_a=4,
                          feat_a=3, seq_b=4, feat_b=3, out_dim=2, n_partial=8,
                          n_frag=8, n_paired=8, n_val=16, optimizer="adamw",
                          schedule="cosine", total_steps=64,
                          server_total_steps=4)
    assert spec.engine_cfg.server_total_steps == 4  # plumbed through
    fns = make_phase_fns(spec.engine_cfg)
    assert fns.srv_opt is not fns.opt  # server horizon = its own optimizer
    state = init_round_state(jax.random.PRNGKey(0), spec)
    ref = fns.srv_opt.init(state["server_gmv"])
    assert (jax.tree.structure(state["srv_opt"]) == jax.tree.structure(ref))
    for a, b in zip(jax.tree.leaves(state["srv_opt"]), jax.tree.leaves(ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    rf = jax.jit(make_blendfl_round(spec))
    batch = _make_batch(spec, np.random.default_rng(0))
    state, m = rf(state, batch)
    assert np.isfinite(float(m["loss_vfl"]))
    assert int(state["srv_opt"]["step"]) == 1


def test_init_stacked_models_back_compat():
    spec = ShardedFedSpec(n_clients=2, d_hidden=16, n_layers=1, seq_a=4, feat_a=3,
                          seq_b=4, feat_b=3, out_dim=2, n_partial=8, n_frag=8,
                          n_paired=8, n_val=16)
    stacked, gmv, gm = init_stacked_models(jax.random.PRNGKey(0), spec)
    for leaf in jax.tree.leaves(stacked):
        assert leaf.shape[0] == spec.n_clients
    state = init_round_state(jax.random.PRNGKey(0), spec)
    for a, b in zip(jax.tree.leaves(state["models"]), jax.tree.leaves(stacked)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_vfl_alignment_gather_grads():
    """Permuted alignment must produce the same loss as pre-aligned data."""
    spec = ShardedFedSpec(n_clients=2, d_hidden=16, n_layers=1, seq_a=4, feat_a=3,
                          seq_b=4, feat_b=3, out_dim=2, n_partial=8, n_frag=8,
                          n_paired=8, n_val=16)
    rng = np.random.default_rng(1)
    batch = {}
    for k, sd in batch_specs(spec).items():
        if k == "perm_b":
            batch[k] = jnp.arange(spec.n_clients * spec.n_frag, dtype=jnp.int32)
        elif k.endswith("y") or k.endswith("ya") or k.endswith("yb"):
            batch[k] = jnp.asarray((rng.random(sd.shape) < 0.5).astype(np.float32))
        else:
            batch[k] = jnp.asarray(rng.normal(0, 1, sd.shape).astype(np.float32))
    state = init_round_state(jax.random.PRNGKey(0), spec)
    rf = jax.jit(make_blendfl_round(spec))
    _, m_id = rf(state, batch)

    # shuffle b-side rows and pass the inverse permutation: same math
    perm = rng.permutation(spec.n_clients * spec.n_frag)
    fb = np.asarray(batch["frag_b"]).reshape(spec.n_clients * spec.n_frag, 4, 3)
    batch2 = dict(batch)
    batch2["frag_b"] = jnp.asarray(fb[perm].reshape(np.asarray(batch["frag_b"]).shape))
    inv = np.argsort(perm)
    # gathered h_b rows are aligned via perm_b: h_b_shuffled[inv] == h_b
    batch2["perm_b"] = jnp.asarray(inv.astype(np.int32))
    _, m_perm = rf(state, batch2)
    np.testing.assert_allclose(float(m_id["loss_vfl"]), float(m_perm["loss_vfl"]),
                               rtol=5e-5)
