"""Per-assigned-architecture smoke tests: instantiate the REDUCED variant
(2 layers, d_model<=512, <=4 experts) and run one forward + one train step
+ one decode step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import ARCH_IDS, get_config
from repro.models import backbone as bb


def _batch(cfg, b=2, s=16, with_labels=True, seed=0):
    rng = np.random.default_rng(seed)
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if with_labels:
        out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.frontend == "vision_stub":
        out["patches"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.vision_tokens, cfg.frontend_dim)), jnp.float32)
    if cfg.is_encdec:
        out["frames"] = jnp.asarray(rng.normal(0, 1, (b, 8, cfg.frontend_dim)),
                                    jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2 or cfg.block_type == "xlstm_pair"
    assert cfg.d_model <= 512 and cfg.n_experts <= 4
    params = bb.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    batch = _batch(cfg, b, s)

    logits, aux = bb.forward(params, cfg, batch)
    s_out = s + (cfg.vision_tokens if cfg.frontend == "vision_stub" else 0)
    assert logits.shape == (b, s_out, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    assert np.isfinite(float(aux))

    opt = optim.adamw(1e-3)
    step = jax.jit(bb.make_train_step(cfg, opt))
    p2, o2, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = sum(float(jnp.sum(jnp.abs(a - b_))) for a, b_ in
                zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = bb.init_params(jax.random.PRNGKey(0), cfg)
    b = 2
    cache = bb.init_cache(cfg, b, max_len=32, enc_len=8)
    tok = jnp.ones((b, 1), jnp.int32)
    logits, new_cache = jax.jit(bb.make_serve_step(cfg))(params, tok, cache,
                                                         jnp.asarray(3))
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ["phi4_mini_3p8b", "starcoder2_7b",
                                  "hymba_1p5b", "xlstm_350m", "stablelm_3b"])
@pytest.mark.slow
def test_prefill_matches_forward_and_decode_consistent(arch):
    """prefill last-token logits == forward last-token logits, AND a decode
    step after prefill == forward on the extended sequence.

    MoE archs are excluded: capacity-based routing drops tokens as a
    function of the WHOLE batch, so a single-token decode legitimately
    differs from the full-sequence forward (expert queue pressure differs).
    """
    cfg = get_config(arch).reduced()
    params = bb.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
    lg, cache, idx = bb.prefill(params, cfg, {"tokens": toks}, max_len=32)
    full, _ = bb.forward(params, cfg, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-4, rtol=2e-4)
    nt = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 1)), jnp.int32)
    lg2, _ = bb.decode_step(params, cfg, nt, cache, jnp.asarray(12))
    full2, _ = bb.forward(params, cfg, {"tokens": jnp.concatenate([toks, nt], 1)})
    np.testing.assert_allclose(np.asarray(lg2[:, 0]), np.asarray(full2[:, -1]),
                               atol=5e-4, rtol=5e-4)


@pytest.mark.slow
def test_sliding_window_ring_buffer_decode():
    """Decode past the window: ring cache must equal full-context SWA."""
    cfg = get_config("phi4_mini_3p8b").reduced().replace(
        attn_kind="sliding", window=8)
    params = bb.init_params(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 20)), jnp.int32)
    _, cache, idx = bb.prefill(params, cfg, {"tokens": toks}, max_len=64)
    assert cache["k"].shape[2] == 8  # ring buffer is window-sized
    cur = toks
    for i in range(4):
        nt = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 1)), jnp.int32)
        lg, cache = bb.decode_step(params, cfg, nt, cache, jnp.asarray(20 + i))
        cur = jnp.concatenate([cur, nt], axis=1)
        full, _ = bb.forward(params, cfg, {"tokens": cur})
        np.testing.assert_allclose(np.asarray(lg[:, 0]), np.asarray(full[:, -1]),
                                   atol=5e-4, rtol=5e-4)


def test_moe_capacity_and_aux_loss():
    cfg = get_config("deepseek_moe_16b").reduced()
    params = bb.init_params(jax.random.PRNGKey(3), cfg)
    batch = _batch(cfg, 2, 16)
    _, aux = bb.forward(params, cfg, batch)
    # Switch aux loss is ~1 for balanced routing; must be positive & finite
    assert 0.0 < float(aux) < 100.0


def test_vlm_loss_only_on_text():
    cfg = get_config("qwen2_vl_2b").reduced()
    params = bb.init_params(jax.random.PRNGKey(4), cfg)
    batch = _batch(cfg, 2, 16)
    total, metrics = bb.loss_fn(params, cfg, batch)
    assert np.isfinite(float(total))
