"""Property-test shim: real hypothesis when installed, else a tiny
fixed-seed fallback so `pytest -x -q` still reaches every test module.

The fallback implements just the subset this repo's tests use
(`given`, `settings`, `strategies.{integers,floats,booleans,sampled_from,
lists}`): each decorated test runs a deterministic, seeded sample of
examples instead of hypothesis' adaptive search. Weaker shrinking/coverage,
same assertions — a missing optional dependency must not mask real tests.
"""
try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401
except ImportError:

    from types import SimpleNamespace

    import numpy as np

    _FALLBACK_MAX_EXAMPLES = 20  # cap: fixed-seed sweep, not a search

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(lo, hi):
        return _Strategy(lambda r: int(r.integers(lo, hi + 1)))

    def _floats(lo, hi, allow_nan=False, **_kw):
        del allow_nan  # uniform draws are never NaN
        return _Strategy(lambda r: float(r.uniform(lo, hi)))

    def _booleans():
        return _Strategy(lambda r: bool(r.integers(0, 2)))

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda r: seq[int(r.integers(len(seq)))])

    def _lists(elem, min_size=0, max_size=10, **_kw):
        return _Strategy(
            lambda r: [elem.draw(r)
                       for _ in range(int(r.integers(min_size, max_size + 1)))])

    strategies = SimpleNamespace(integers=_integers, floats=_floats,
                                 booleans=_booleans, sampled_from=_sampled_from,
                                 lists=_lists)

    def settings(max_examples=_FALLBACK_MAX_EXAMPLES, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            n = min(getattr(fn, "_max_examples", _FALLBACK_MAX_EXAMPLES),
                    _FALLBACK_MAX_EXAMPLES)

            # No functools.wraps: the wrapper must expose a ZERO-arg
            # signature or pytest would treat the strategy params as
            # fixtures. (These property tests use no fixtures.)
            def wrapper():
                rng = np.random.default_rng(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
