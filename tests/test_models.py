"""Model-layer unit tests: attention paths, RoPE, MoE, recurrent cells."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.models.attention import (
    causal_mask,
    chunked_gqa_sdpa,
    gqa_sdpa,
)
from repro.models.recurrent import (
    gated_linear_scan,
    gated_linear_scan_ref,
    gated_linear_step,
    slstm_init,
    slstm_scan,
    slstm_step,
)
from repro.models.rope import apply_rope, mrope_positions, rope_angles, text_positions


# ---------------------------------------------------------------- attention --

@pytest.mark.slow
@given(sq=st.integers(8, 80), skx=st.integers(0, 40), hkv=st.sampled_from([1, 2, 4]),
       g=st.sampled_from([1, 2, 3]), window=st.sampled_from([0, 7, 16]),
       seed=st.integers(0, 100))
@settings(max_examples=40, deadline=None)
def test_chunked_attention_equals_einsum(sq, skx, hkv, g, window, seed):
    sk = sq + skx
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, sq, hkv * g, 16))
    k = jax.random.normal(ks[1], (1, sk, hkv, 16))
    v = jax.random.normal(ks[2], (1, sk, hkv, 16))
    mask = causal_mask(sq, sk, window, q_offset=sk - sq)
    ref = gqa_sdpa(q, k, v, mask)
    out = chunked_gqa_sdpa(q, k, v, causal=True, window=window, q_offset=sk - sq,
                           block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-4)


@pytest.mark.slow
def test_chunked_attention_gradients_match():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 64, 6, 16))
    k = jax.random.normal(ks[1], (2, 64, 2, 16))
    v = jax.random.normal(ks[2], (2, 64, 2, 16))

    def f_chunk(q, k, v):
        return jnp.sum(chunked_gqa_sdpa(q, k, v, causal=True, block_q=16,
                                        block_k=16) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(gqa_sdpa(q, k, v, causal_mask(64, 64)) ** 2)

    g1 = jax.grad(f_chunk, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4, rtol=1e-3)


@pytest.mark.slow
def test_gqa_grouping_matches_repeated_heads():
    """GQA-grouped einsum == materializing repeated KV heads."""
    from repro.models.attention import _repeat_kv

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 32, 8, 16))
    k = jax.random.normal(ks[1], (2, 32, 2, 16))
    v = jax.random.normal(ks[2], (2, 32, 2, 16))
    mask = causal_mask(32, 32)
    out = gqa_sdpa(q, k, v, mask)
    ref = gqa_sdpa(q, _repeat_kv(k, 4), _repeat_kv(v, 4), mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------- rope --

def test_rope_preserves_norm_and_relativity():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 32))
    pos = text_positions(1, 8)
    ang = rope_angles(pos, 32, 10000.0)
    y = apply_rope(x, ang)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # relativity: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))
    dots = []
    for p in (0, 5, 11):
        aq = rope_angles(jnp.array([[p]]), 32, 10000.0)
        ak = rope_angles(jnp.array([[p + 3]]), 32, 10000.0)
        dots.append(float(jnp.sum(apply_rope(q, aq) * apply_rope(k, ak))))
    np.testing.assert_allclose(dots, dots[0], rtol=1e-4)


def test_mrope_text_rows_reduce_to_1d_rope():
    """Text tokens use t=h=w so M-RoPE must equal standard RoPE."""
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 6, 2, 32))
    pos1d = text_positions(1, 6, offset=4)
    pos3d = jnp.stack([pos1d, pos1d, pos1d], axis=-1)
    a1 = rope_angles(pos1d, 32, 1e4)
    a3 = rope_angles(pos3d, 32, 1e4, sections=(6, 5, 5))
    np.testing.assert_allclose(np.asarray(apply_rope(x, a1)),
                               np.asarray(apply_rope(x, a3)), rtol=1e-5, atol=1e-6)


def test_mrope_positions_layout():
    pos = mrope_positions(2, 9, 4)
    assert pos.shape == (2, 13, 3)
    assert (np.asarray(pos[0, :9, 0]) == 0).all()  # vision t=0
    txt = np.asarray(pos[0, 9:])
    assert (txt[:, 0] == txt[:, 1]).all() and (txt[:, 1] == txt[:, 2]).all()


# ---------------------------------------------------------------- recurrent --

@pytest.mark.slow
@given(s=st.integers(4, 96), chunk=st.sampled_from([4, 16, 64]),
       normalize=st.booleans(), seed=st.integers(0, 50))
@settings(max_examples=30, deadline=None)
def test_gated_linear_scan_chunkwise_equals_sequential(s, chunk, normalize, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (1, 2, s, 8))
    k = jax.random.normal(ks[1], (1, 2, s, 8)) * 0.5
    v = jax.random.normal(ks[2], (1, 2, s, 8))
    lf = -jnp.abs(jax.random.normal(ks[3], (1, 2, s))) * 0.3
    out = gated_linear_scan(q, k, v, lf, chunk=chunk, normalize=normalize)
    ref = gated_linear_scan_ref(q, k, v, lf, normalize=normalize)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-3)


def test_gated_linear_state_handoff():
    """scan(return_state) + step must continue the sequence exactly."""
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    s = 33
    q = jax.random.normal(ks[0], (1, 2, s, 8))
    k = jax.random.normal(ks[1], (1, 2, s, 8)) * 0.5
    v = jax.random.normal(ks[2], (1, 2, s, 8))
    lf = -jnp.abs(jax.random.normal(ks[3], (1, 2, s))) * 0.2
    full = gated_linear_scan_ref(q, k, v, lf)
    _, state = gated_linear_scan(q[:, :, :-1], k[:, :, :-1], v[:, :, :-1],
                                 lf[:, :, :-1], chunk=8, return_state=True)
    h_last, _ = gated_linear_step(q[:, :, -1], k[:, :, -1], v[:, :, -1],
                                  lf[:, :, -1], state)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(full[:, :, -1]),
                               atol=1e-4, rtol=1e-3)


@pytest.mark.slow
def test_slstm_step_equals_scan():
    p = slstm_init(jax.random.PRNGKey(0), 32, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 10, 32))
    full, _ = slstm_scan(p, x, 4)
    zero = jnp.zeros((2, 4, 8))
    state = (zero, zero, zero - 1e30, zero)  # c, n, m, h_prev
    outs = []
    for t in range(10):
        h, state = slstm_step(p, x[:, t], 4, state)
        outs.append(h)
    step_out = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step_out), np.asarray(full),
                               atol=1e-5, rtol=1e-4)


def test_moe_all_tokens_routed_with_ample_capacity():
    """With capacity >= T*k/E tokens nothing is dropped: MoE output must
    equal the dense mixture-of-selected-experts reference."""
    from repro.models.config import ArchConfig
    from repro.models.moe import moe_apply, moe_init
    from repro.models.mlp import mlp

    cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
                     n_kv_heads=2, d_ff=32, vocab_size=64, n_experts=4, top_k=2,
                     capacity_factor=8.0, act="swiglu")
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    out, aux = moe_apply(p, cfg, x)

    # dense reference: route every token through its top-k experts
    xf = np.asarray(x.reshape(12, 16))
    logits = xf @ np.asarray(p["router"]["w"])
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    gate, idx = jax.lax.top_k(probs, 2)
    gate = gate / gate.sum(-1, keepdims=True)
    ref = np.zeros((12, 16), np.float32)
    for t in range(12):
        for j in range(2):
            e = int(idx[t, j])
            ep = jax.tree.map(lambda w, e=e: w[e], p["experts"])
            ref[t] += float(gate[t, j]) * np.asarray(
                mlp(ep, jnp.asarray(xf[t:t+1]), "swiglu"))[0]
    np.testing.assert_allclose(np.asarray(out).reshape(12, 16), ref,
                               atol=1e-4, rtol=1e-3)
    assert float(aux) > 0


@pytest.mark.slow
def test_moe_grouped_equals_flat():
    """GShard-style grouped dispatch (§Perf B.2) must match the flat path
    when capacity is ample (per-group capacity changes drop behavior only
    under overflow)."""
    from repro.models.config import ArchConfig
    from repro.models.moe import _moe_flat, _moe_grouped, moe_init

    cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
                     n_kv_heads=2, d_ff=32, vocab_size=64, n_experts=4, top_k=2,
                     capacity_factor=8.0, act="swiglu", moe_groups=4)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 16))
    o1, a1 = _moe_flat(p, cfg, x)
    o2, a2 = _moe_grouped(p, cfg, x)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-6)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)
