"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracle,
swept over shapes and dtypes, per the assignment."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.blendavg.blendavg import blend_params_pallas
from repro.kernels.blendavg.ref import blend_params_ref
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mlstm_scan.mlstm_scan import mlstm_scan_pallas
from repro.kernels.mlstm_scan.ref import mlstm_scan_ref


# ------------------------------------------------------- flash attention ----

@pytest.mark.parametrize("b,hq,hkv,sq,sk,d", [
    (1, 4, 4, 64, 64, 32),    # MHA square
    (2, 8, 2, 128, 128, 64),  # GQA 4x
    (1, 6, 2, 96, 96, 32),    # non-pow2 heads
    (2, 4, 1, 64, 192, 32),   # MQA, decode-style suffix queries
    (1, 4, 4, 40, 72, 16),    # ragged (padding path)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(b, hq, hkv, sq, sk, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=32, block_k=32,
                                 interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [8, 32, 127])
def test_flash_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    out = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=32, block_k=32, interpret=True)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_flash_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 4, 64, 32))
    k = jax.random.normal(ks[1], (2, 4, 64, 32))
    v = jax.random.normal(ks[2], (2, 4, 64, 32))
    out = flash_attention_pallas(q, k, v, causal=False, block_q=32, block_k=32,
                                 interpret=True)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------- blendavg ----

@pytest.mark.parametrize("l,n,block", [(3, 1000, 256), (5, 2048, 2048),
                                       (2, 33, 16), (7, 4097, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_blendavg_vs_ref(l, n, block, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    stacked = jax.random.normal(ks[0], (l, n), dtype)
    omega = jax.nn.softmax(jax.random.normal(ks[1], (l,)))
    out = blend_params_pallas(stacked, omega, block_n=block, interpret=True)
    ref = blend_params_ref(stacked, omega)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-6
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_blendavg_masked_weights_drop_models():
    """omega=0 rows must not contribute (discarded models, Eq. 10)."""
    stacked = jnp.stack([jnp.ones(64), 100.0 * jnp.ones(64), 3.0 * jnp.ones(64)])
    omega = jnp.array([0.5, 0.0, 0.5])
    out = blend_params_pallas(stacked, omega, block_n=32, interpret=True)
    np.testing.assert_allclose(np.asarray(out), 2.0 * np.ones(64), rtol=1e-6)


# ------------------------------------------------------------- mlstm scan ----

@pytest.mark.parametrize("b,h,s,dk,dv,chunk", [
    (1, 2, 64, 16, 16, 16),
    (2, 3, 100, 32, 16, 32),   # ragged length
    (1, 1, 128, 64, 64, 128),  # single chunk
])
@pytest.mark.slow
@pytest.mark.parametrize("normalize", [True, False])
def test_mlstm_scan_vs_sequential_ref(b, h, s, dk, dv, chunk, normalize):
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    q = jax.random.normal(ks[0], (b, h, s, dk))
    k = jax.random.normal(ks[1], (b, h, s, dk)) * 0.5
    v = jax.random.normal(ks[2], (b, h, s, dv))
    log_f = -jnp.abs(jax.random.normal(ks[3], (b, h, s))) * 0.2
    out = mlstm_scan_pallas(q, k, v, log_f, chunk=chunk, normalize=normalize,
                            interpret=True)
    ref = mlstm_scan_ref(q, k, v, log_f, normalize=normalize)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-4, rtol=5e-3)


@pytest.mark.slow
def test_chunked_scan_matches_chunk_free():
    """Chunk size must not change the math (associativity of the scan)."""
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    q = jax.random.normal(ks[0], (1, 2, 96, 16))
    k = jax.random.normal(ks[1], (1, 2, 96, 16))
    v = jax.random.normal(ks[2], (1, 2, 96, 16))
    lf = -jnp.abs(jax.random.normal(ks[3], (1, 2, 96))) * 0.1
    outs = [np.asarray(mlstm_scan_pallas(q, k, v, lf, chunk=c, interpret=True))
            for c in (16, 32, 96)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-4, rtol=1e-4)


# -------------------------------------------------------------- slstm cell ----

@pytest.mark.parametrize("b,h,s,hd,chunk", [
    (1, 2, 32, 16, 16),
    (2, 4, 50, 8, 32),    # ragged length (padding path)
    (1, 1, 64, 32, 64),   # single chunk
])
def test_slstm_cell_vs_ref(b, h, s, hd, chunk):
    from repro.kernels.slstm_cell.ref import slstm_cell_ref
    from repro.kernels.slstm_cell.slstm_cell import slstm_cell_pallas

    ks = jax.random.split(jax.random.PRNGKey(6), 2)
    pre = jax.random.normal(ks[0], (b, h, s, 4, hd)) * 0.5
    r = jax.random.normal(ks[1], (h, hd, 4 * hd)) / np.sqrt(hd)
    out = slstm_cell_pallas(pre, r, chunk=chunk, interpret=True)
    ref = slstm_cell_ref(pre, r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-4)


def test_slstm_cell_matches_model_cell():
    """The fused kernel implements the same recurrence as the model's
    slstm_scan (given the same pre-activations and weights)."""
    from repro.kernels.slstm_cell.ref import slstm_cell_ref
    from repro.models.recurrent import slstm_init, slstm_scan

    d, n_heads = 32, 4
    hd = d // n_heads
    p = slstm_init(jax.random.PRNGKey(0), d, n_heads, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, d))
    want, _ = slstm_scan(p, x, n_heads)  # (B, S, d)

    pre = (x @ p["wx"] + p["b"]).reshape(2, 12, 4, n_heads, hd)
    pre = pre.transpose(0, 3, 1, 2, 4)  # (B, H, S, 4, hd)
    got = slstm_cell_ref(pre, p["r"])  # (B, H, S, hd)
    got = got.transpose(0, 2, 1, 3).reshape(2, 12, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


# -------------------------------------------------------------- wire codec ----

def _codec_inputs(key, l, n, frac=None):
    from repro.kernels.wire_codec.ops import _EPS

    x = jax.random.normal(key, (l, n)) * jax.random.uniform(
        jax.random.split(key)[0], (l, 1), minval=0.1, maxval=10.0)
    mags = jnp.sort(jnp.abs(x), axis=1)[:, ::-1]
    scale = jnp.maximum(mags[:, :1], _EPS)
    if frac is None:
        thresh = jnp.zeros_like(scale)
    else:
        k = max(1, int(np.ceil(frac * n)))
        thresh = mags[:, k - 1:k]
    return x, jnp.concatenate([scale, thresh], axis=1)


@pytest.mark.parametrize("l,n,block,quantize,frac", [
    (1, 64, 64, False, 0.25),
    (3, 333, 128, True, 0.25),    # ragged N (padding path)
    (5, 2048, 512, True, None),   # dense int8 (thresh=0)
    (2, 100, 256, False, 0.01),   # k=1 extreme sparsity
    (4, 512, 128, True, 1.0),     # keep-all + quantize
])
def test_wire_codec_vs_ref(l, n, block, quantize, frac):
    from repro.kernels.wire_codec.ref import wire_codec_ref
    from repro.kernels.wire_codec.wire_codec import wire_codec_pallas

    x, st = _codec_inputs(jax.random.PRNGKey(7), l, n, frac)
    out = wire_codec_pallas(x, st, quantize=quantize, block_n=block,
                            interpret=True)
    ref = wire_codec_ref(x, st, quantize=quantize)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_wire_codec_roundtrip_bounds():
    """The public roundtrip keeps exactly k entries per row and its
    quantization error is bounded by scale/254."""
    from repro.kernels.wire_codec.ops import wire_codec_roundtrip

    x, _ = _codec_inputs(jax.random.PRNGKey(8), 4, 400)
    dec = np.asarray(wire_codec_roundtrip(x, k=100, quantize=True))
    xn = np.asarray(x)
    assert ((dec != 0).sum(axis=1) <= 100).all()
    keep = dec != 0
    scale = np.abs(xn).max(axis=1, keepdims=True)
    assert (np.abs(dec - xn)[keep] <= (scale / 254 + 1e-7).repeat(
        400, axis=1)[keep]).all()
    # dense float path (k=None, quantize=False) is exact identity
    ident = wire_codec_roundtrip(x)
    np.testing.assert_array_equal(np.asarray(ident), xn)
