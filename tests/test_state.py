"""``repro.core.state`` — the round-state block registry + elastic cohorts.

The registry is the single source of state-block layout for BOTH
drivers, so these tests pin its two contracts directly on a real
sharded round state carrying every optional block (int8_topk codec
residuals, SCAFFOLD control variates, server-Adam moments):

- **Round-trip identity** (property-style, via ``_hypothesis_compat``):
  for every registered block and any sampled id set, gathering the K
  rows and scattering them back unchanged reproduces the full state
  bit-exactly — the invariant that makes the drivers' shared
  sample/scatter path a refactor rather than a behavior change.
- **Elastic capacity**: ``grow`` pads to a bucket without touching
  existing rows (new model rows adopt the current globals, moments /
  residuals / variates zero, ``last_round`` -1), shrinking is refused,
  ``retire_clients`` resets exactly the named slots, and a
  smaller-capacity checkpoint migrates into a bigger federation through
  ``train_federated.init_or_restore`` (restore bit-exact, then grow).
- **K > C is a loud error** in both drivers' entry points.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, strategies as st

from repro.core import state as rstate


def _spec(C: int, **kw):
    from repro.core.federation_sharded import ShardedFedSpec

    base = dict(n_clients=C, d_hidden=8, n_layers=2, seq_a=4, feat_a=3,
                seq_b=4, feat_b=3, out_dim=3, kind="multiclass", n_partial=4,
                n_frag=4, n_paired=4, n_val=8, n_sampled=min(2, C),
                codec="int8_topk", strategy="scaffold", server_opt="adam",
                optimizer="adamw")
    base.update(kw)
    return ShardedFedSpec(**base)


@functools.lru_cache(maxsize=None)
def _state(C: int) -> dict:
    """A real sharded round state at capacity C with EVERY optional
    block present (codec + strat, incl. server moments)."""
    from repro.core.federation_sharded import init_round_state

    return init_round_state(jax.random.PRNGKey(0), _spec(C))


def _tree_equal(a, b) -> bool:
    leaves_a, treedef_a = jax.tree.flatten(a)
    leaves_b, treedef_b = jax.tree.flatten(b)
    return treedef_a == treedef_b and all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(leaves_a, leaves_b))


def test_registry_covers_real_state():
    state = _state(4)
    assert set(state) == {b.name for b in rstate.REGISTRY}
    optional = {b.name for b in rstate.REGISTRY if b.optional}
    assert optional == {"codec", "strat"}


@settings(max_examples=20)
@given(c=st.sampled_from([2, 4, 8, 11]), k=st.integers(1, 8),
       seed=st.integers(0, 10**6))
def test_sample_scatter_roundtrip(c, k, seed):
    """scatter(state, sample(state, idx), idx) == state, bit-exact, for
    every registered block, across (C, K) grids and arbitrary id sets."""
    state = _state(c)
    k = min(k, c)
    idx = np.random.default_rng(seed).choice(c, size=k, replace=False)
    sub = rstate.sample(state, idx)
    # the gather really is K rows for stacked blocks
    assert sub["last_round"].shape == (k,)
    assert all(x.shape[0] == k
               for x in jax.tree.leaves(sub["models"]["f_A"]))
    back = rstate.scatter(state, sub, idx)
    assert _tree_equal(back, state)


def test_full_participation_passthrough():
    """idx=None (full participation) samples to the identity and
    scatters wholesale — the no-sampling drivers' path."""
    state = _state(4)
    assert rstate.sample(state, None) is not state  # new dict, same leaves
    assert _tree_equal(rstate.sample(state, None), state)
    assert _tree_equal(rstate.scatter(state, dict(state), None), state)


def test_unregistered_block_raises():
    with pytest.raises(KeyError, match="unregistered round-state block"):
        rstate.sample({"bogus": jnp.zeros((4,))}, np.array([0, 1]))


def test_capacity_for_buckets():
    assert [rstate.capacity_for(n) for n in (1, 7, 8, 9, 16, 17)] == \
        [8, 8, 8, 16, 16, 24]
    with pytest.raises(ValueError, match="must be >= 1"):
        rstate.capacity_for(0)


def test_grow_is_bit_exact_on_existing_rows():
    state = _state(8)
    grown = rstate.grow(state, 16)
    assert rstate.state_capacity(grown) == 16
    # every stacked leaf keeps its first 8 rows bit-exactly; "none"
    # blocks are untouched
    sub = rstate.sample(grown, np.arange(8))
    assert _tree_equal(sub, state)


def test_grow_fills_new_rows_by_block():
    state = _state(8)
    grown = rstate.grow(state, 16)
    new = rstate.sample(grown, np.arange(8, 16))
    # joiners' models adopt the current globals (Algorithm 1 shared init)
    for g in rstate.CLIENT_GROUPS:
        jax.tree.map(
            lambda x, glob: np.testing.assert_array_equal(
                np.asarray(x), np.broadcast_to(np.asarray(glob), x.shape)),
            new["models"][g], state["global_models"][g])
    # moments / residuals / control variates start at zero
    for mk in rstate.OPT_MOMENT_KEYS:
        if mk in new["opt"]:
            assert all(not np.asarray(x).any()
                       for x in jax.tree.leaves(new["opt"][mk]))
    assert all(not np.asarray(x).any()
               for x in jax.tree.leaves(new["codec"]["resid_up"]))
    assert all(not np.asarray(x).any()
               for x in jax.tree.leaves(new["strat"]["c_local"]))
    # async/sched bookkeeping starts like a fresh federation
    assert np.all(np.asarray(new["last_round"]) == -1)
    assert np.all(np.asarray(new["sched"]["last_round"]) == -1)
    assert not np.asarray(new["sched"]["part_count"]).any()
    assert not np.asarray(new["sched"]["omega_ema"]).any()
    # unstacked halves replace nothing: c_global / srv / resid_down and
    # the global blocks are the same values
    assert _tree_equal(grown["strat"]["c_global"], state["strat"]["c_global"])
    assert _tree_equal(grown["codec"]["resid_down"],
                       state["codec"]["resid_down"])
    assert _tree_equal(grown["global_models"], state["global_models"])


def test_grow_same_capacity_is_identity_and_shrink_raises():
    state = _state(8)
    assert rstate.grow(state, 8) is state
    with pytest.raises(ValueError, match="cannot shrink"):
        rstate.grow(state, 4)


def test_retire_clients_resets_only_named_slots():
    state = _state(8)
    retired = rstate.retire_clients(state, [1, 3])
    keep = np.array([0, 2, 4, 5, 6, 7])
    assert _tree_equal(rstate.sample(retired, keep),
                       rstate.sample(state, keep))
    gone = rstate.sample(retired, np.array([1, 3]))
    for g in rstate.CLIENT_GROUPS:
        jax.tree.map(
            lambda x, glob: np.testing.assert_array_equal(
                np.asarray(x), np.broadcast_to(np.asarray(glob), x.shape)),
            gone["models"][g], state["global_models"][g])
    assert np.all(np.asarray(gone["last_round"]) == -1)
    assert all(not np.asarray(x).any()
               for x in jax.tree.leaves(gone["strat"]["c_local"]))


def test_checkpoint_migration_grows_smaller_capacity(tmp_path):
    """A capacity-8 checkpoint resumes into a capacity-16 federation:
    bit-exact restore of the old rows, declared fills for the new ones —
    and shrinking in place is refused with the migration hint."""
    import argparse

    from repro.checkpoint import read_manifest, save_checkpoint
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train_federated import init_or_restore

    state = _state(8)
    ckpt = tmp_path / "ck"
    save_checkpoint(str(ckpt), 3, state, {"round": 3})
    manifest = read_manifest(str(ckpt), 3)
    assert rstate.manifest_capacity(manifest) == 8

    mesh = make_host_mesh()
    args = argparse.Namespace(seed=0, ckpt_dir=str(ckpt))
    start, migrated = init_or_restore(args, _spec(16), mesh)
    assert start == 3
    assert rstate.state_capacity(migrated) == 16
    assert _tree_equal(jax.device_get(migrated),
                       jax.device_get(rstate.grow(state, 16)))
    with pytest.raises(ValueError, match="shrinking a cohort in place"):
        init_or_restore(argparse.Namespace(seed=0, ckpt_dir=str(ckpt)),
                        _spec(4, n_sampled=2), mesh)


def test_manifest_capacity_requires_round_state():
    with pytest.raises(KeyError, match="not a round-state checkpoint"):
        rstate.manifest_capacity({"shapes": {}, "dtypes": {}, "keys": []})


def test_k_greater_than_c_raises_sharded():
    with pytest.raises(ValueError, match="n_sampled=9"):
        _spec(4, n_sampled=9)


def test_k_greater_than_c_raises_in_host():
    from repro.core.federation import FedConfig, Federation

    with pytest.raises(ValueError, match="n_sampled=9"):
        Federation.init(jax.random.PRNGKey(0),
                        FedConfig(n_clients=4, n_sampled=9),
                        None, None, [], None)
