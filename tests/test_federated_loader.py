"""Federated batch loader + round-state checkpointing.

Covers the ragged-client data subsystem (``FederatedBatcher``): stateless
per-round determinism, static shapes with real 0/1 masks, id-based VFL
alignment, zero-row-modality exclusion semantics (the engine's
``_where_clients`` contract), prefetch equivalence — and the full
round-state save/restore path: a federation checkpointed mid-run and
resumed must produce bit-identical round metrics to an uninterrupted run
(full participation and K-of-C sampled/async)."""
import argparse

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.core.engine import make_phase_fns
from repro.core.federation_sharded import (
    ShardedFedSpec,
    batch_specs,
    init_round_state,
    make_blendfl_round,
)
from repro.data.pipeline import FederatedBatcher


def _ragged_clients(spec, rng, zero_b_client=None, n_rows=None):
    """C ragged synthetic client datasets with disjoint frag id spaces
    split so every a-side id also exists at some b-side client."""
    out = []
    next_id = 0
    for c in range(spec.n_clients):
        n = {k: int(rng.integers(1, cap + 4)) for k, cap in
             (("pa", spec.n_partial), ("pb", spec.n_partial),
              ("fr", spec.n_frag), ("pr", spec.n_paired))}
        if n_rows:
            n.update(n_rows.get(c, {}))
        ids = np.arange(next_id, next_id + n["fr"], dtype=np.int64)
        next_id += n["fr"]
        ds = {
            "partial_a": rng.normal(0, 1, (n["pa"], spec.seq_a, spec.feat_a)).astype(np.float32),
            "partial_ya": (rng.random((n["pa"], spec.out_dim)) < 0.3).astype(np.float32),
            "partial_b": rng.normal(0, 1, (n["pb"], spec.seq_b, spec.feat_b)).astype(np.float32),
            "partial_yb": (rng.random((n["pb"], spec.out_dim)) < 0.3).astype(np.float32),
            "frag_a": rng.normal(0, 1, (n["fr"], spec.seq_a, spec.feat_a)).astype(np.float32),
            "frag_y": (rng.random((n["fr"], spec.out_dim)) < 0.3).astype(np.float32),
            "frag_ids_a": ids,
            "paired_a": rng.normal(0, 1, (n["pr"], spec.seq_a, spec.feat_a)).astype(np.float32),
            "paired_b": rng.normal(0, 1, (n["pr"], spec.seq_b, spec.feat_b)).astype(np.float32),
            "paired_y": (rng.random((n["pr"], spec.out_dim)) < 0.3).astype(np.float32),
        }
        if zero_b_client == c:
            ds["partial_b"] = np.zeros((0, spec.seq_b, spec.feat_b), np.float32)
            ds["partial_yb"] = np.zeros((0, spec.out_dim), np.float32)
        out.append(ds)
    # b-sides of the fragmented rows live at the NEXT client (ragged VFL)
    for c, ds in enumerate(out):
        src = out[(c + 1) % spec.n_clients]
        na = len(src["frag_ids_a"])
        ds["frag_b"] = rng.normal(0, 1, (na, spec.seq_b, spec.feat_b)).astype(np.float32)
        ds["frag_ids_b"] = src["frag_ids_a"].copy()
    return out


def _val(spec, rng):
    return {"val_a": rng.normal(0, 1, (spec.n_val, spec.seq_a, spec.feat_a)).astype(np.float32),
            "val_b": rng.normal(0, 1, (spec.n_val, spec.seq_b, spec.feat_b)).astype(np.float32),
            "val_y": (rng.random((spec.n_val, spec.out_dim)) < 0.3).astype(np.float32)}


def _spec(**kw):
    base = dict(n_clients=4, d_hidden=16, n_layers=1, seq_a=4, feat_a=3,
                seq_b=4, feat_b=3, out_dim=2, n_partial=8, n_frag=8,
                n_paired=8, n_val=16, lr=5e-2, optimizer="adamw")
    base.update(kw)
    return ShardedFedSpec(**base)


@pytest.fixture(scope="module")
def loader():
    spec = _spec()
    rng = np.random.default_rng(0)
    clients = _ragged_clients(spec, rng)
    return spec, FederatedBatcher(clients, spec, _val(spec, rng), seed=3)


# ------------------------------------------------------------ batch layout --

def test_batch_matches_specs_with_masks(loader):
    spec, b = loader
    batch = b.build(0)
    want = b.batch_specs()  # the loader's own contract accessor …
    # … which must agree with the sharded round's ragged spec set
    assert want == batch_specs(spec, ragged=True)
    for k, sd in want.items():
        if k.startswith("val_"):
            continue  # val rides in via put(), not build()
        assert k in batch, f"missing batch key {k}"
        assert batch[k].shape == sd.shape, k
        assert batch[k].dtype == sd.dtype, k
    assert set(batch) == {k for k in want if not k.startswith("val_")}
    dev = b.put(batch)
    for k in ("val_a", "val_b", "val_y"):
        assert dev[k].shape == want[k].shape
    # masks are genuinely ragged 0/1 (not the all-ones uniform layout)
    for mk in ("partial_ma", "partial_mb", "paired_m"):
        m = batch[mk]
        assert set(np.unique(m)) <= {0.0, 1.0}
        assert 0 < m.sum() < m.size
        # live rows are packed at the front of each client's slab
        assert (np.diff(m, axis=1) <= 0).all()


def test_builds_are_deterministic_per_round(loader):
    _, b = loader
    b1, b2 = b.build(5), b.build(5)
    for k in b1:
        np.testing.assert_array_equal(b1[k], np.asarray(b2[k]), err_msg=k)
    b3 = b.build(6)
    assert any(not np.array_equal(b1[k], b3[k]) for k in b1), \
        "different rounds must draw different row subsets"


def test_prefetch_stream_matches_sync_stream(loader):
    _, b = loader
    sync = {r: batch for r, batch in b.rounds(0, 4, prefetch=0)}
    pref = {r: batch for r, batch in b.rounds(0, 4, prefetch=2)}
    assert sorted(sync) == sorted(pref) == [0, 1, 2, 3]
    for r in sync:
        for k in sync[r]:
            np.testing.assert_array_equal(np.asarray(sync[r][k]),
                                          np.asarray(pref[r][k]), err_msg=k)


def test_vfl_alignment_pairs_matching_ids(loader):
    spec, b = loader
    batch = b.build(1)
    nf = spec.n_frag
    w = batch["frag_w"]
    assert w.sum() > 0, "some aligned rows must survive"
    # reconstruct the drawn id layout: weight-1 rows must pair a/b sides
    # of the SAME global sample; padded rows carry no label
    assert set(np.unique(w)) <= {0.0, 1.0}
    fy = batch["frag_y"].reshape(spec.k_round * nf, -1)
    assert (fy[w == 0] == 0).all()
    assert batch["frag_part_a"].any() and batch["frag_part_b"].any()
    assert batch["perm_b"].max() < spec.k_round * nf


def test_mismatched_client_arrays_raise_at_init(loader):
    spec, _ = loader
    rng = np.random.default_rng(2)
    clients = _ragged_clients(spec, rng)
    clients[1]["partial_ya"] = clients[1]["partial_ya"][:-1]  # ragged vs x
    with pytest.raises(ValueError, match="partial_a"):
        FederatedBatcher(clients, spec, _val(spec, rng))


def test_prefetch_worker_error_propagates(loader, monkeypatch):
    """A build() failure on the prefetch worker must raise in the
    consumer, not hang it forever on the queue."""
    spec, _ = loader
    rng = np.random.default_rng(4)
    b = FederatedBatcher(_ragged_clients(spec, rng), spec, _val(spec, rng))
    monkeypatch.setattr(b, "build",
                        lambda r: (_ for _ in ()).throw(RuntimeError("boom")))
    with pytest.raises(RuntimeError, match="boom"):
        for _ in b.rounds(0, 2, prefetch=1):
            pass


def test_zero_row_modality_excluded_from_update(loader):
    """A client with a zero-row modality must keep that modality's params
    AND optimizer moments bit-identical through the phase update — the
    engine's ``_where_clients`` contract, now driven by real loader masks
    instead of synthetic ones."""
    spec = _spec()
    rng = np.random.default_rng(1)
    clients = _ragged_clients(spec, rng, zero_b_client=2)
    b = FederatedBatcher(clients, spec, _val(spec, rng), seed=0)
    batch = b.build(0)
    assert batch["partial_mb"][2].sum() == 0  # zero-row modality -> empty mask

    fns = make_phase_fns(spec.engine_cfg)
    state = init_round_state(jax.random.PRNGKey(0), spec)
    p1 = {"xa": jnp.asarray(batch["partial_a"]), "ya": jnp.asarray(batch["partial_ya"]),
          "ma": jnp.asarray(batch["partial_ma"]),
          "xb": jnp.asarray(batch["partial_b"]), "yb": jnp.asarray(batch["partial_yb"]),
          "mb": jnp.asarray(batch["partial_mb"])}
    models, opt, info = fns.unimodal_step(state["models"], state["opt"], p1)
    assert int(info["n_b"][2]) == 0
    for grp in ("f_B", "g_B"):
        for new, old in zip(jax.tree.leaves(models[grp]),
                            jax.tree.leaves(state["models"][grp])):
            np.testing.assert_array_equal(np.asarray(new[2]), np.asarray(old[2]))
            # clients WITH rows did move
            assert not np.array_equal(np.asarray(new[0]), np.asarray(old[0]))
        for new, old in zip(jax.tree.leaves(opt["mu"][grp]),
                            jax.tree.leaves(state["opt"]["mu"][grp])):
            np.testing.assert_array_equal(np.asarray(new[2]), np.asarray(old[2]))


def test_zero_live_vfl_rows_skip_server_head_update(loader):
    """An all-zero ``frag_w`` round (no a-row's PSI partner drawn) has
    exactly-zero VFL grads — the server head's params, moments, and
    schedule step must stay untouched, like every empty-batch client."""
    spec, b = loader
    batch = b.build(0)
    fns = make_phase_fns(spec.engine_cfg)
    state = init_round_state(jax.random.PRNGKey(0), spec)
    K = spec.k_round
    p2 = {"xa": jnp.asarray(batch["frag_a"]), "xb": jnp.asarray(batch["frag_b"]),
          "gather_a": jnp.arange(K * spec.n_frag, dtype=jnp.int32),
          "gather_b": jnp.asarray(batch["perm_b"]),
          "y": jnp.asarray(batch["frag_y"].reshape(K * spec.n_frag, -1)),
          "w": jnp.zeros(K * spec.n_frag, jnp.float32),
          "part_a": jnp.zeros(K, bool), "part_b": jnp.zeros(K, bool)}
    models, gmv, opt, srv, loss = fns.vfl_step(
        state["models"], state["server_gmv"], state["opt"], state["srv_opt"], p2)
    assert float(loss) == 0.0
    for n, o in zip(jax.tree.leaves((gmv, srv)),
                    jax.tree.leaves((state["server_gmv"], state["srv_opt"]))):
        np.testing.assert_array_equal(np.asarray(n), np.asarray(o))
    assert int(srv["step"]) == 0
    for n, o in zip(jax.tree.leaves(models), jax.tree.leaves(state["models"])):
        np.testing.assert_array_equal(np.asarray(n), np.asarray(o))


def test_ragged_round_runs_and_improves(loader):
    spec, b = loader
    state = init_round_state(jax.random.PRNGKey(0), spec)
    rf = jax.jit(make_blendfl_round(spec))
    losses = []
    for r, batch in b.rounds(0, 3):
        state, m = rf(state, batch)
        losses.append(float(m["loss_uni"]) + float(m["loss_paired"]))
        assert np.isfinite(losses[-1])
    assert int(rf._cache_size()) == 1  # masks/ids are data, not shape


# ------------------------------------------- round-state resume parity -----


def _loader_args(**kw):
    base = dict(task="smnist", clients=4, n_sampled=0, rounds=4, n_train=384,
                n_val=64, rows_cap=16, d_hidden=16, n_layers=1, lr=1e-2,
                optimizer="adamw", dirichlet_alpha=None, seed=0, data_seed=0,
                prefetch=1, ckpt_dir=None, ckpt_every=2, log_every=0)
    base.update(kw)
    return argparse.Namespace(**base)


@pytest.mark.slow
def test_resume_parity_full_participation(tmp_path):
    from repro.launch.train_federated import selftest_resume

    selftest_resume(_loader_args())


@pytest.mark.slow
def test_resume_parity_sampled_async(tmp_path):
    from repro.launch.train_federated import selftest_resume

    selftest_resume(_loader_args(clients=6, n_sampled=3))


def test_round_state_checkpoint_bit_exact(tmp_path, loader):
    """The full ``init_round_state`` pytree — stacked models, AdamW
    moments, srv_opt, last_round, round — survives save/restore
    bit-for-bit, including the int32 bookkeeping leaves."""
    spec, b = loader
    state = init_round_state(jax.random.PRNGKey(0), spec)
    rf = jax.jit(make_blendfl_round(spec))
    for _, batch in b.rounds(0, 2):
        state, _ = rf(state, batch)
    save_checkpoint(str(tmp_path), 2, state, {"round": 2})
    target = init_round_state(jax.random.PRNGKey(1), spec)
    restored = restore_checkpoint(str(tmp_path), target)
    assert (jax.tree.structure(restored) == jax.tree.structure(state))
    for a, c in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        assert np.asarray(a).dtype == np.asarray(c).dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    assert int(restored["round"]) == 2
    assert restored["round"].dtype == np.int32
