"""Out-of-core client store + docs tooling.

Covers ``repro.data.store``: bit-exact shard round-trips (including
zero-row modalities), the manifest-as-index contract (no file IO for row
counts), ``FederatedBatcher.from_store`` batch streams bit-identical to
the in-memory loader, the ``rows_for_clients`` multi-host seam, the
checkpoint store-fingerprint guard, store-backed resume parity, and the
``make docs-check`` reference checker."""
import argparse
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.data.pipeline import FederatedBatcher
from repro.data.store import ClientStore, write_store

from test_federated_loader import _ragged_clients, _spec, _val

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _make_store(tmp_path, spec, rng, **kw):
    clients = _ragged_clients(spec, rng, **kw)
    val = _val(spec, rng)
    store = write_store(str(tmp_path / "store"), clients, val)
    return clients, val, store


# ---------------------------------------------------------- shard round-trip

def test_store_round_trip_bit_exact(tmp_path):
    spec = _spec()
    rng = np.random.default_rng(0)
    clients, val, store = _make_store(tmp_path, spec, rng, zero_b_client=2)
    assert store.n_clients == spec.n_clients
    for cid, src in enumerate(clients):
        view = store.client(cid)
        assert sorted(view.keys()) == sorted(src.keys())
        for key, arr in src.items():
            assert store.rows(cid, key) == len(arr)
            got = view[key][np.arange(len(arr))] if len(arr) else view[key].read()
            np.testing.assert_array_equal(got, arr, err_msg=f"{cid}/{key}")
            assert got.dtype == arr.dtype, f"{cid}/{key}"
    # zero-row modality survives with shape/dtype intact, no mmap needed
    z = store.client(2)["partial_b"]
    assert len(z) == 0 and z.read().shape[1:] == (spec.seq_b, spec.feat_b)
    for k, v in val.items():
        np.testing.assert_array_equal(store.val()[k], v, err_msg=k)


def test_store_subset_reads_only_selected_rows(tmp_path):
    spec = _spec()
    rng = np.random.default_rng(1)
    clients, _, store = _make_store(tmp_path, spec, rng)
    n = len(clients[1]["partial_a"])
    sel = rng.permutation(n)[: max(1, n // 2)]
    np.testing.assert_array_equal(store.client(1)["partial_a"][sel],
                                  clients[1]["partial_a"][sel])


def test_rows_for_clients_mesh_seam(tmp_path):
    spec = _spec()
    rng = np.random.default_rng(2)
    clients, _, store = _make_store(tmp_path, spec, rng)
    ids = [3, 1]
    sels = [np.arange(min(2, len(clients[i]["frag_a"]))) for i in ids]
    out = store.rows_for_clients(ids, {"frag_a": sels, "frag_ids_a": sels})
    for j, cid in enumerate(ids):
        np.testing.assert_array_equal(out["frag_a"][j],
                                      clients[cid]["frag_a"][sels[j]])
        np.testing.assert_array_equal(out["frag_ids_a"][j],
                                      clients[cid]["frag_ids_a"][sels[j]])
    with pytest.raises(ValueError, match="selections for"):
        store.rows_for_clients([0], {"frag_a": sels})


def test_write_store_refuses_silent_overwrite(tmp_path):
    spec = _spec()
    rng = np.random.default_rng(3)
    clients = _ragged_clients(spec, rng)
    val = _val(spec, rng)
    write_store(str(tmp_path / "s"), clients, val)
    with pytest.raises(FileExistsError):
        write_store(str(tmp_path / "s"), clients, val)
    write_store(str(tmp_path / "s"), clients, val, overwrite=True)  # explicit ok
    assert ClientStore(str(tmp_path / "s")).n_clients == spec.n_clients


def test_store_old_fallback_after_crashed_swap(tmp_path):
    """A crash between an overwrite swap's two renames leaves the
    complete previous store only at <dir>.old — reads must fall back to
    it, and the next import must sweep it."""
    spec = _spec()
    rng = np.random.default_rng(7)
    clients, val, store = _make_store(tmp_path, spec, rng)
    fp = store.fingerprint()
    os.rename(str(tmp_path / "store"), str(tmp_path / "store.old"))
    recovered = ClientStore(str(tmp_path / "store"))
    assert recovered.fingerprint() == fp
    np.testing.assert_array_equal(
        recovered.client(0)["partial_a"].read(), clients[0]["partial_a"])
    write_store(str(tmp_path / "store"), clients, val)
    assert not os.path.exists(str(tmp_path / "store.old"))
    assert ClientStore(str(tmp_path / "store")).fingerprint() == fp


def test_fingerprint_identifies_contents(tmp_path):
    spec = _spec()
    rng = np.random.default_rng(4)
    clients, val, store = _make_store(tmp_path, spec, rng)
    fp = store.fingerprint()
    assert ClientStore(store.store_dir).fingerprint() == fp  # stable reopen
    clients[0]["partial_a"] = clients[0]["partial_a"] + 1.0
    store2 = write_store(str(tmp_path / "other"), clients, val)
    assert store2.fingerprint() != fp  # per-shard sha256 in the manifest


# ------------------------------------------------- from_store batch parity --

@pytest.mark.parametrize("spec_kw", [{}, {"n_clients": 6, "n_sampled": 3}])
def test_from_store_batches_bit_identical(tmp_path, spec_kw):
    spec = _spec(**spec_kw)
    rng = np.random.default_rng(5)
    clients, val, store = _make_store(tmp_path, spec, rng)
    mem = FederatedBatcher(clients, spec, val, seed=7)
    sto = FederatedBatcher.from_store(store, spec, seed=7)
    assert sto.store is store and mem.store is None
    for r in (0, 1, 9):
        bm, bs = mem.build(r), sto.build(r)
        assert set(bm) == set(bs)
        for k in bm:
            np.testing.assert_array_equal(bm[k], bs[k],
                                          err_msg=f"round {r} key {k}")
            assert bm[k].dtype == np.asarray(bs[k]).dtype, k
    if spec.n_sampled:
        assert "sampled" in sto.build(0)
    for k in ("val_a", "val_b", "val_y"):  # store-recorded val rides put()
        np.testing.assert_array_equal(np.asarray(mem._val[k]),
                                      np.asarray(sto._val[k]), err_msg=k)


def test_from_store_round_runs(tmp_path):
    import jax

    from repro.core.federation_sharded import init_round_state, make_blendfl_round

    spec = _spec()
    rng = np.random.default_rng(6)
    _, _, store = _make_store(tmp_path, spec, rng)
    b = FederatedBatcher.from_store(store, spec, seed=0)
    state = init_round_state(jax.random.PRNGKey(0), spec)
    rf = jax.jit(make_blendfl_round(spec))
    for _, batch in b.rounds(0, 2):
        state, m = rf(state, batch)
        assert np.isfinite(float(m["loss_uni"]))
    assert int(rf._cache_size()) == 1


# ------------------------------------------- fingerprint-guarded resume -----

def _driver_args(**kw):
    base = dict(task="smnist", clients=4, n_sampled=0, rounds=4, n_train=384,
                n_val=64, rows_cap=16, d_hidden=16, n_layers=1, lr=1e-2,
                optimizer="adamw", dirichlet_alpha=None, seed=0, data_seed=0,
                prefetch=1, ckpt_dir=None, ckpt_every=2, log_every=0,
                store_dir=None, overwrite=False, command=None)
    base.update(kw)
    return argparse.Namespace(**base)


def test_resume_refuses_foreign_store_fingerprint(tmp_path):
    import jax

    from repro.checkpoint import read_metadata, save_checkpoint
    from repro.core.federation_sharded import init_round_state
    from repro.launch.mesh import make_host_mesh
    from repro.launch.train_federated import init_or_restore

    spec = _spec()
    state = init_round_state(jax.random.PRNGKey(0), spec)
    ckpt = str(tmp_path / "ckpt")
    save_checkpoint(ckpt, 2, state, {"round": 2, "store_fingerprint": "a" * 64})
    assert read_metadata(ckpt)["store_fingerprint"] == "a" * 64
    args = _driver_args(ckpt_dir=ckpt)
    mesh = make_host_mesh()
    with pytest.raises(ValueError, match="different client store"):
        init_or_restore(args, spec, mesh, store_fingerprint="b" * 64)
    with pytest.raises(ValueError, match="store-backed run"):
        init_or_restore(args, spec, mesh, store_fingerprint=None)
    # matching fingerprint restores fine
    start, _ = init_or_restore(args, spec, mesh, store_fingerprint="a" * 64)
    assert start == 2


@pytest.mark.slow
def test_resume_parity_store_backed(tmp_path):
    """The bit-exact killed-and-resumed guarantee holds when every batch
    is served from shard files instead of host RAM."""
    from repro.launch.train_federated import import_store, selftest_resume

    args = _driver_args(store_dir=str(tmp_path / "store"))
    import_store(args)
    selftest_resume(args)


# --------------------------------------------------------------- docs-check

def _docs_check(*extra):
    return subprocess.run(
        [sys.executable, os.path.join(REPO_ROOT, "tools", "docs_check.py"),
         *extra], capture_output=True, text=True)


def test_docs_check_passes_on_repo_docs():
    r = _docs_check()
    assert r.returncode == 0, r.stdout + r.stderr


def test_docs_check_flags_broken_refs(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see `src/repro/nope_missing.py`, `repro.not.a.module`, "
                   "[link](gone.md), and run `make not-a-target`\n")
    r = _docs_check(str(bad))
    assert r.returncode == 1
    for frag in ("nope_missing", "repro.not.a.module", "gone.md",
                 "not-a-target"):
        assert frag in r.stdout, (frag, r.stdout)
