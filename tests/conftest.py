"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the real single
CPU device; only launch/dryrun.py forces the 512-device placeholder."""
import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
