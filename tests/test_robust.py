"""Byzantine-robust aggregation (``repro.core.aggregate`` ROBUST family).

Pins the robust reducers against pure-numpy references, then their
statistical contracts as property tests (via ``tests/_hypothesis_compat``
— real hypothesis when installed, a fixed-seed sweep otherwise):

- coordinate median / trimmed mean recover the honest mean within the
  honest spread whenever f < C/2 clients upload sign-flipped or
  100x-scaled updates — and median demonstrably BREAKS at f >= C/2 (the
  breakdown point is tight, not conservative);
- Krum's distance scores match the Blanchard et al. definition exactly,
  and multi-Krum keeps only honest candidates whenever f < (C-2)/2;
- the degenerate cases that make the defenses safe defaults: krum_mask
  at f = 0 is all-ones, median of identical candidates is that
  candidate, trimmed mean refuses n <= 2*trim.

Then the driver-level parity contract: with zero assumed attackers the
robust strategies ARE fedavg — krum bit-for-bit on the whole round
state, trimmed_mean bit-for-bit on the per-modality heads (its M head
documents uniform weighting instead of volume weighting) — and a robust
round keeps the stateless layout (no new state keys) and exactly one
compiled program.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregate
from repro.core.aggregate import StrategyConfig, make_strategy

from tests._hypothesis_compat import given, settings, strategies as st


# --------------------------------------------------------- numpy references --

def np_median(stack: np.ndarray) -> np.ndarray:
    return np.median(stack.astype(np.float32), axis=0)


def np_trimmed_mean(stack: np.ndarray, trim: int) -> np.ndarray:
    s = np.sort(stack.astype(np.float32), axis=0)
    return np.mean(s[trim:len(stack) - trim], axis=0)


def np_krum_scores(flat: np.ndarray, f: int) -> np.ndarray:
    """Blanchard et al. 2017: score(i) = sum of squared distances to
    candidate i's n - f - 2 nearest peers."""
    n = len(flat)
    d2 = np.sum((flat[:, None, :] - flat[None, :, :]) ** 2, axis=-1)
    np.fill_diagonal(d2, np.inf)
    k = max(n - f - 2, 1)
    return np.sort(d2, axis=1)[:, :k].sum(axis=1)


def _attacked_cohort(rng, n, f, dim, attack: str):
    """n candidates around a common honest mean; the last f are
    adversarial (sign-flipped or 100x-scaled). Returns (stack, honest)."""
    honest_mean = rng.normal(0, 1, dim).astype(np.float32)
    honest = honest_mean[None] + rng.normal(0, 0.1, (n, dim)).astype(np.float32)
    stack = honest.copy()
    bad = -honest[n - f:] if attack == "sign_flip" else 100.0 * honest[n - f:]
    stack[n - f:] = bad
    return stack, honest[: n - f]


# ------------------------------------------------- reducers vs references --

def test_median_tree_matches_numpy():
    rng = np.random.default_rng(0)
    tree = {"f": {"w": rng.normal(0, 1, (5, 3, 2)).astype(np.float32)},
            "g": {"b": rng.normal(0, 1, (5, 4)).astype(np.float32)}}
    out = aggregate.coordinate_median_tree(jax.tree.map(jnp.asarray, tree))
    for path in (("f", "w"), ("g", "b")):
        ref = np_median(tree[path[0]][path[1]])
        np.testing.assert_allclose(
            np.asarray(out[path[0]][path[1]]), ref, rtol=1e-6)


def test_trimmed_mean_tree_matches_numpy():
    rng = np.random.default_rng(1)
    x = rng.normal(0, 1, (7, 4, 3)).astype(np.float32)
    out = aggregate.trimmed_mean_tree({"w": jnp.asarray(x)}, trim=2)
    np.testing.assert_allclose(np.asarray(out["w"]), np_trimmed_mean(x, 2),
                               rtol=1e-5)


def test_trimmed_mean_refuses_overtrim():
    x = {"w": jnp.ones((4, 2))}
    with pytest.raises(ValueError, match="2\\*trim"):
        aggregate.trimmed_mean_tree(x, trim=2)


def test_krum_scores_match_numpy_reference():
    rng = np.random.default_rng(2)
    tree = {"f": {"w": rng.normal(0, 1, (6, 3)).astype(np.float32)},
            "g": rng.normal(0, 1, (6, 2, 2)).astype(np.float32)}
    flat = np.concatenate([tree["f"]["w"].reshape(6, -1),
                           tree["g"].reshape(6, -1)], axis=1)
    for f in (0, 1):
        got = np.asarray(aggregate.krum_scores(
            jax.tree.map(jnp.asarray, tree), f))
        np.testing.assert_allclose(got, np_krum_scores(flat, f),
                                   rtol=1e-4, atol=1e-4)


def test_krum_mask_zero_f_is_identity():
    """f = 0 must short-circuit to all-ones without consulting scores —
    the bit-parity contract's foundation."""
    rng = np.random.default_rng(3)
    tree = {"w": jnp.asarray(rng.normal(0, 1, (4, 5)).astype(np.float32))}
    np.testing.assert_array_equal(np.asarray(aggregate.krum_mask(tree, 0)),
                                  np.ones(4, np.float32))


def test_median_of_identical_candidates_is_that_candidate():
    """All-honest degenerate case: when every client uploads the same
    model, the order statistic returns it exactly (= what fedavg would)."""
    row = np.random.default_rng(4).normal(0, 1, (3, 2)).astype(np.float32)
    stack = {"w": jnp.asarray(np.stack([row] * 5))}
    np.testing.assert_array_equal(
        np.asarray(aggregate.coordinate_median_tree(stack)["w"]), row)


# ---------------------------------------------------------- property tests --

@settings(max_examples=20, deadline=None)
@given(c=st.integers(5, 12), f_frac=st.floats(0.0, 0.99),
       attack=st.sampled_from(["sign_flip", "scale"]),
       seed=st.integers(0, 10_000))
def test_median_recovers_honest_mean_below_breakdown(c, f_frac, attack, seed):
    """f < C/2 arbitrary candidates cannot drag any coordinate of the
    median outside the honest envelope — so it stays within the honest
    spread of the honest mean."""
    f = int(f_frac * ((c - 1) // 2 + 1))  # 0 <= f <= floor((c-1)/2) < c/2
    stack, honest = _attacked_cohort(np.random.default_rng(seed), c, f, 6,
                                     attack)
    med = np.asarray(aggregate.coordinate_median_tree(
        {"w": jnp.asarray(stack)})["w"])
    assert np.all(med >= honest.min(axis=0) - 1e-6)
    assert np.all(med <= honest.max(axis=0) + 1e-6)
    tol = np.abs(honest - honest.mean(axis=0)).max() + 1e-6
    assert np.all(np.abs(med - honest.mean(axis=0)) <= tol)


@settings(max_examples=20, deadline=None)
@given(c=st.integers(4, 12), seed=st.integers(0, 10_000))
def test_median_breakdown_point_is_tight(c, seed):
    """At f = ceil(C/2) colluding candidates the median IS corrupted —
    the f < C/2 guarantee is the breakdown point, not slack."""
    f = (c + 1) // 2
    rng = np.random.default_rng(seed)
    stack = rng.normal(0, 1, (c, 4)).astype(np.float32)
    honest_max = stack[: c - f].max()
    stack[c - f:] = 1e6
    med = np.asarray(aggregate.coordinate_median_tree(
        {"w": jnp.asarray(stack)})["w"])
    assert np.all(med > honest_max)


@settings(max_examples=20, deadline=None)
@given(c=st.integers(5, 12), f_frac=st.floats(0.0, 0.99),
       attack=st.sampled_from(["sign_flip", "scale"]),
       seed=st.integers(0, 10_000))
def test_trimmed_mean_recovers_honest_mean(c, f_frac, attack, seed):
    """Trimming f per side with f malicious candidates leaves only
    honest values per coordinate, so the result lands in the honest
    envelope, within the honest spread of the honest mean."""
    f = int(f_frac * (((c - 1) // 2 - 1) + 1))  # n >= 2f + 1 and f < c/2
    stack, honest = _attacked_cohort(np.random.default_rng(seed), c, f, 6,
                                     attack)
    if f == 0:  # drivers route trim 0 to fedavg; reducer still defined
        return
    tm = np.asarray(aggregate.trimmed_mean_tree(
        {"w": jnp.asarray(stack)}, trim=f)["w"])
    assert np.all(tm >= honest.min(axis=0) - 1e-5)
    assert np.all(tm <= honest.max(axis=0) + 1e-5)
    tol = np.abs(honest - honest.mean(axis=0)).max() + 1e-5
    assert np.all(np.abs(tm - honest.mean(axis=0)) <= tol)


@settings(max_examples=20, deadline=None)
@given(c=st.integers(5, 14), f_frac=st.floats(0.0, 0.99),
       seed=st.integers(0, 10_000))
def test_krum_excludes_outliers_below_breakdown(c, f_frac, seed):
    """f < (C-2)/2 far-away candidates always score worst: multi-Krum's
    n - f survivors are exactly the honest candidates, and the Krum
    pick (argmin score) is honest."""
    f_max = (c - 3) // 2  # largest f with f < (c-2)/2
    f = int(f_frac * (f_max + 1))
    if f == 0:
        return
    rng = np.random.default_rng(seed)
    stack, _ = _attacked_cohort(rng, c, 0, 6, "scale")
    # distinct large offsets: colluding-but-not-identical attackers
    stack[c - f:] += 50.0 * (1.0 + np.arange(f, dtype=np.float32))[:, None]
    tree = {"w": jnp.asarray(stack)}
    scores = np.asarray(aggregate.krum_scores(tree, f))
    assert int(np.argmin(scores)) < c - f
    mask = np.asarray(aggregate.krum_mask(tree, f))
    np.testing.assert_array_equal(mask[c - f:], np.zeros(f, np.float32))
    np.testing.assert_array_equal(mask[: c - f], np.ones(c - f, np.float32))


# ----------------------------------------------- config + driver contracts --

def test_robust_config_flags_and_validation():
    for name in aggregate.ROBUST:
        scfg = make_strategy(name, n_malicious=2)
        assert scfg.robust and not scfg.stateful and not scfg.client_active
        assert scfg.n_malicious == 2
    assert not make_strategy("fedavg").robust
    with pytest.raises(ValueError, match=">= 0"):
        StrategyConfig(name="krum", n_malicious=-1)


def test_sharded_spec_validates_robust_cohort_floor():
    from repro.core.federation_sharded import ShardedFedSpec

    kw = dict(n_clients=8, d_hidden=16, n_layers=1, seq_a=4, feat_a=3,
              seq_b=4, feat_b=3, out_dim=2, n_partial=8, n_frag=8,
              n_paired=8, n_val=16)
    with pytest.raises(ValueError, match="krum"):
        ShardedFedSpec(strategy="krum", n_malicious=1, n_sampled=3, **kw)
    with pytest.raises(ValueError, match="trimmed_mean"):
        ShardedFedSpec(strategy="trimmed_mean", n_malicious=2, n_sampled=4,
                       **kw)
    # at the floor both construct
    ShardedFedSpec(strategy="krum", n_malicious=1, n_sampled=4, **kw)
    ShardedFedSpec(strategy="trimmed_mean", n_malicious=2, n_sampled=5, **kw)


def _tiny_spec(**overrides):
    from repro.core.federation_sharded import ShardedFedSpec

    kw = dict(n_clients=4, d_hidden=16, n_layers=1, seq_a=4, feat_a=3,
              seq_b=4, feat_b=3, out_dim=2, n_partial=8, n_frag=8,
              n_paired=8, n_val=16)
    kw.update(overrides)
    return ShardedFedSpec(**kw)


def _tiny_batch(spec, rng):
    from repro.core.federation_sharded import batch_specs

    batch = {}
    for k, sd in batch_specs(spec).items():
        if k == "perm_b":
            batch[k] = jnp.asarray(rng.permutation(
                spec.n_clients * spec.n_frag).astype(np.int32))
        elif k.endswith("_y") or k.startswith("partial_y") or k == "val_y":
            batch[k] = jnp.asarray(
                (rng.random(sd.shape) < 0.3).astype(np.float32))
        elif k in ("partial_ma", "partial_mb", "paired_m", "frag_w"):
            # full rows everywhere: equal volumes, so fedavg's weights
            # normalize to exactly 1/K (the trimmed-parity premise)
            batch[k] = jnp.ones(sd.shape, jnp.float32)
        else:
            batch[k] = jnp.asarray(rng.normal(0, 1, sd.shape).astype(np.float32))
    return batch


def _run_rounds(spec, n=2):
    from repro.core.federation_sharded import (
        init_round_state, make_blendfl_round)

    rf = jax.jit(make_blendfl_round(spec))
    state = init_round_state(jax.random.PRNGKey(0), spec)
    for r in range(n):
        state, _ = rf(state, _tiny_batch(spec, np.random.default_rng(r)))
    return state, rf


def test_robust_rounds_are_stateless_single_program():
    """No new state keys (old checkpoints stay loadable) and one
    compiled program across rounds — robustness is static structure."""
    from repro.core.federation_sharded import init_round_state

    for name in aggregate.ROBUST:
        spec = _tiny_spec(strategy=name, n_malicious=1)
        assert "strat" not in init_round_state(jax.random.PRNGKey(0), spec)
        state, rf = _run_rounds(spec)
        assert "strat" not in state
        assert rf._cache_size() == 1


def test_krum_zero_malicious_is_fedavg_bitexact():
    """n_malicious = 0: the survivor mask is all-ones, so the entire
    round state (every head, both optimizers) matches fedavg bit-for-bit."""
    a, _ = _run_rounds(_tiny_spec(strategy="fedavg"))
    b, _ = _run_rounds(_tiny_spec(strategy="krum", n_malicious=0))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_trimmed_mean_zero_malicious_matches_fedavg_heads():
    """trim 0 delegates to fedavg with uniform weights; on this
    equal-volume cohort the per-modality heads of one round are
    bit-identical to fedavg. The M head documents uniform weighting over
    the K+1 candidates where fedavg volume-weights the server candidate,
    so it is excluded — and since the multimodal phase couples every
    head to g_M from round 2 on, the bit claim is a one-round claim."""
    a, _ = _run_rounds(_tiny_spec(strategy="fedavg"), n=1)
    b, _ = _run_rounds(_tiny_spec(strategy="trimmed_mean", n_malicious=0), n=1)
    for head in ("f_A", "f_B", "g_A", "g_B"):
        for x, y in zip(jax.tree.leaves(a["global_models"][head]),
                        jax.tree.leaves(b["global_models"][head])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_robust_round_survives_attacked_uplink():
    """End-to-end sanity: with spec.attacks on and one sign-flipping
    candidate in the coef vector, a robust round still produces finite
    global models, and the honest-coef round differs from the attacked
    one (the hook is live, not a no-op)."""
    spec = _tiny_spec(strategy="median", n_sampled=4, attacks=True)
    from repro.core.federation_sharded import (
        init_round_state, make_blendfl_round)

    rf = jax.jit(make_blendfl_round(spec))
    batch = _tiny_batch(spec, np.random.default_rng(0))
    batch["sampled"] = jnp.arange(4, dtype=jnp.int32)
    state = init_round_state(jax.random.PRNGKey(0), spec)
    honest = dict(batch, attack_coef=jnp.ones(4, jnp.float32))
    flipped = dict(batch,
                   attack_coef=jnp.asarray([-1.0, 1.0, 1.0, 1.0], jnp.float32))
    sa, _ = rf(state, honest)
    sb, _ = rf(state, flipped)
    assert rf._cache_size() == 1  # the coef is data, not structure
    leaves_a = jax.tree.leaves(sa["global_models"])
    leaves_b = jax.tree.leaves(sb["global_models"])
    assert all(np.isfinite(np.asarray(l)).all() for l in leaves_b)
    assert any(not np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(leaves_a, leaves_b))
