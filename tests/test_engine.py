"""Stacked round engine: parity with the legacy per-client loop, optimizer
pluggability, compile-cache behavior, and aggregation edge cases.

The parity test re-implements Algorithm 1 exactly the way the pre-engine
``federation.py`` did — Python loops over clients, inline ``p - lr*g``
SGD, per-owner VFL scatter — and asserts the stacked engine reproduces
its losses, omegas, and global-model leaves on a small federation where
batching is full-batch (so shuffling cannot reorder the math)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import vfl
from repro.core.blendavg import blendavg
from repro.core.encoders import (
    EncoderConfig,
    encoder_apply,
    fusion_apply,
    init_client_models,
    task_loss,
)
from repro.core.engine import EngineConfig, make_phase_fns
from repro.core.federation import (
    FedConfig,
    Federation,
    eval_multimodal,
    eval_unimodal,
)
from repro.core.partitioner import partition
from repro.data.synthetic import make_task, train_val_test
from repro.models.common import dense


@pytest.fixture(scope="module")
def small_fed():
    spec = make_task("smnist")
    tr, va, te = train_val_test(spec, 240, 200, 100, seed=3)
    # high paired fraction so every client holds every candidate role
    clients = partition(tr, 2, frac_paired=0.6, frac_fragmented=0.3,
                        frac_partial=0.1, seed=4)
    ecfg = EncoderConfig(d_hidden=32, n_layers=1, enc_type="mlp")
    return spec, tr, va, te, clients, ecfg


# ------------------------------------------------------- legacy reference --

def _sgd(tree, grads, lr):
    return jax.tree.map(lambda p, g: p - lr * g, tree, grads)


def _legacy_round(models, global_models, server_gmv, clients, val, ecfg, kind,
                  lr, metric="auroc"):
    """The seed repo's Algorithm 1 loop, full-batch, verbatim semantics."""
    logs = {}

    # phase 1: per-client, per-modality unimodal SGD
    losses = []
    for k, cd in enumerate(clients):
        for mod, view in (("A", cd.all_a()), ("B", cd.all_b())):
            if len(view) == 0:
                continue
            f, g = models[k][f"f_{mod}"], models[k][f"g_{mod}"]
            x, y = jnp.asarray(view.x), jnp.asarray(view.y)

            def loss_fn(f_, g_):
                return task_loss(dense(g_, encoder_apply(f_, x, ecfg)), y, kind)

            loss, (gf, gg) = jax.value_and_grad(loss_fn, argnums=(0, 1))(f, g)
            models[k][f"f_{mod}"] = _sgd(f, gf, lr)
            models[k][f"g_{mod}"] = _sgd(g, gg, lr)
            losses.append(float(loss))
    logs["loss_partial"] = float(np.mean(losses))

    # phase 2: full-batch split exchange with per-owner scatter
    batches = vfl.build_vfl_batches(clients, 10**9, np.random.default_rng(0))
    losses = []
    for batch in batches:
        x_a, x_b = jnp.asarray(batch.x_a), jnp.asarray(batch.x_b)
        n = len(batch.y)
        h_a = jnp.zeros((n, ecfg.d_hidden), jnp.float32)
        h_b = jnp.zeros((n, ecfg.d_hidden), jnp.float32)
        for k in range(len(clients)):
            ra = np.nonzero(batch.owner_a == k)[0]
            rb = np.nonzero(batch.owner_b == k)[0]
            if len(ra):
                h_a = h_a.at[ra].set(vfl.client_forward(models[k]["f_A"], x_a[ra], ecfg))
            if len(rb):
                h_b = h_b.at[rb].set(vfl.client_forward(models[k]["f_B"], x_b[rb], ecfg))
        loss, g_srv, g_ha, g_hb = vfl.server_forward_backward(
            server_gmv, h_a, h_b, jnp.asarray(batch.y), kind)
        server_gmv = _sgd(server_gmv, g_srv, lr)
        for k in range(len(clients)):
            ra = np.nonzero(batch.owner_a == k)[0]
            rb = np.nonzero(batch.owner_b == k)[0]
            if len(ra):
                g_enc = vfl.client_backward(models[k]["f_A"], x_a[ra], g_ha[ra], ecfg)
                models[k]["f_A"] = _sgd(models[k]["f_A"], g_enc, lr)
            if len(rb):
                g_enc = vfl.client_backward(models[k]["f_B"], x_b[rb], g_hb[rb], ecfg)
                models[k]["f_B"] = _sgd(models[k]["f_B"], g_enc, lr)
        losses.append(float(loss))
    logs["loss_vfl"] = float(np.mean(losses))

    # phase 3: per-client paired SGD
    losses = []
    for k, cd in enumerate(clients):
        if not cd.has_paired:
            continue
        x_a = jnp.asarray(cd.paired_a.x)
        x_b = jnp.asarray(cd.paired_b.x)
        y = jnp.asarray(cd.paired_a.y)
        f_a, f_b, g_m = models[k]["f_A"], models[k]["f_B"], models[k]["g_M"]

        def loss_fn(fa, fb, gm):
            h_a = encoder_apply(fa, x_a, ecfg)
            h_b = encoder_apply(fb, x_b, ecfg)
            return task_loss(fusion_apply(gm, h_a, h_b), y, kind)

        loss, (gfa, gfb, ggm) = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(
            f_a, f_b, g_m)
        models[k]["f_A"] = _sgd(f_a, gfa, lr)
        models[k]["f_B"] = _sgd(f_b, gfb, lr)
        models[k]["g_M"] = _sgd(g_m, ggm, lr)
        losses.append(float(loss))
    logs["loss_paired"] = float(np.mean(losses))

    # phase 4: BlendAvg with real AUROC scoring (seed federation._aggregate)
    for mod in ("A", "B"):
        x_val = val.x_a if mod == "A" else val.x_b
        cands = [{"f": m[f"f_{mod}"], "g": m[f"g_{mod}"]} for m in models]
        glob = {"f": global_models[f"f_{mod}"], "g": global_models[f"g_{mod}"]}
        ev = lambda m: eval_unimodal(m["f"], m["g"], x_val, val.y, ecfg, kind, metric)
        blended, inf = blendavg(glob, cands, ev)
        logs[f"omega_{mod}"] = inf["omega"]
        global_models[f"f_{mod}"] = blended["f"]
        global_models[f"g_{mod}"] = blended["g"]
    cands = [m["g_M"] for m in models] + [server_gmv]
    f_a, f_b = global_models["f_A"], global_models["f_B"]
    ev = lambda gm: eval_multimodal(f_a, f_b, gm, val.x_a, val.x_b, val.y,
                                    ecfg, kind, metric)
    blended, inf = blendavg(global_models["g_M"], cands, ev)
    logs["omega_M"] = inf["omega"]
    global_models["g_M"] = blended
    for k in range(len(clients)):
        for grp in ("f_A", "g_A", "f_B", "g_B", "g_M"):
            models[k][grp] = jax.tree.map(jnp.copy, global_models[grp])
    server_gmv = jax.tree.map(jnp.copy, global_models["g_M"])
    return models, global_models, server_gmv, logs


@pytest.mark.slow
def test_engine_matches_legacy_loop(small_fed):
    spec, tr, va, te, clients, ecfg = small_fed
    lr = 5e-2
    # batch_size > any client's row count -> exactly one full batch per
    # phase, so shuffling cannot reorder the legacy/engine math
    cfg = FedConfig(n_clients=2, rounds=1, lr=lr, batch_size=512, seed=0)
    fed = Federation.init(jax.random.PRNGKey(7), cfg, spec, ecfg, clients, va)

    base = init_client_models(jax.random.PRNGKey(7), spec, ecfg)
    ref_models = [jax.tree.map(jnp.copy, base) for _ in clients]
    ref_global = jax.tree.map(jnp.copy, base)
    ref_gmv = jax.tree.map(jnp.copy, base["g_M"])

    logs = fed.round()
    ref_models, ref_global, ref_gmv, ref_logs = _legacy_round(
        ref_models, ref_global, ref_gmv, clients, va, ecfg, spec.kind, lr)

    for k in ("loss_partial", "loss_vfl", "loss_paired"):
        np.testing.assert_allclose(logs[k], ref_logs[k], rtol=2e-4, atol=1e-5)
    for mod in ("A", "B", "M"):
        np.testing.assert_allclose(np.asarray(logs[f"omega_{mod}"]),
                                   np.asarray(ref_logs[f"omega_{mod}"]),
                                   rtol=1e-3, atol=1e-4)
    for grp in ("f_A", "g_A", "f_B", "g_B", "g_M"):
        for a, b in zip(jax.tree.leaves(fed.global_models[grp]),
                        jax.tree.leaves(ref_global[grp])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=5e-4, atol=5e-5)


# ------------------------------------------------------ optimizer + cache --

@pytest.mark.slow
def test_adamw_rounds_converge(small_fed):
    spec, tr, va, te, clients, ecfg = small_fed
    cfg = FedConfig(n_clients=2, rounds=5, lr=3e-3, batch_size=64,
                    optimizer="adamw", weight_decay=1e-4, seed=0)
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)
    # stacked AdamW moments thread through rounds, one row per client
    assert "mu" in fed.opt_state
    for leaf in jax.tree.leaves(fed.opt_state["mu"]):
        assert leaf.shape[0] == cfg.n_clients
    hist = fed.fit()
    first = hist[0]["loss_partial"] + hist[0]["loss_paired"]
    last = hist[-1]["loss_partial"] + hist[-1]["loss_paired"]
    assert np.isfinite(last)
    assert last < first


@pytest.mark.slow
def test_cosine_schedule_runs(small_fed):
    spec, tr, va, te, clients, ecfg = small_fed
    cfg = FedConfig(n_clients=2, rounds=2, lr=1e-2, batch_size=64,
                    schedule="cosine", seed=0)
    fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)
    hist = fed.fit()
    assert np.isfinite(hist[-1]["loss_partial"])


@pytest.mark.slow
def test_one_compile_per_phase_regardless_of_client_count(small_fed):
    """The acceptance criterion: the unimodal step compiles ONCE per
    federation — cache entries don't grow with n_clients (stacked C axis),
    with modality (both trained in the same program), or with rounds
    (per-batch work lives inside a lax.scan, no per-batch retraces)."""
    spec, tr, va, te, clients2, ecfg = small_fed
    clients4 = partition(tr, 4, frac_paired=0.6, frac_fragmented=0.3,
                         frac_partial=0.1, seed=4)
    for n_clients, clients in ((2, clients2), (4, clients4)):
        cfg = FedConfig(n_clients=n_clients, rounds=2, lr=1e-2, batch_size=32,
                        seed=0)
        fed = Federation.init(jax.random.PRNGKey(0), cfg, spec, ecfg, clients, va)
        fed.fit()
        assert fed.engine.unimodal_phase._cache_size() == 1
        assert fed.engine.paired_phase._cache_size() == 1
        assert fed.engine.vfl_phase._cache_size() == 1


# ------------------------------------------------- aggregation edge cases --

@pytest.mark.slow
def test_fedavg_zero_overlap_excludes_server_head(small_fed):
    """No fragmented overlap -> the untrained server head must get weight
    ZERO (the seed code silently floored it to 1 sample)."""
    spec, tr, va, te, _, ecfg = small_fed
    clients = partition(tr, 2, frac_paired=0.7, frac_fragmented=0.0,
                        frac_partial=0.3, seed=5)
    cfg = FedConfig(n_clients=2, rounds=1, lr=1e-2, batch_size=512,
                    aggregator="fedavg", seed=0)
    fed = Federation.init(jax.random.PRNGKey(1), cfg, spec, ecfg, clients, va)
    # snapshot client g_M heads right before aggregation
    fed._unimodal_phase()
    fed._vfl_phase()
    fed._paired_phase()
    pre = [jax.tree.map(jnp.copy, m["g_M"]) for m in fed.models]
    fed._aggregate()
    ns = np.array([len(cd.paired_a) for cd in clients], np.float64)
    w = ns / ns.sum()
    expected = jax.tree.map(lambda a, b: w[0] * a + w[1] * b, pre[0], pre[1])
    for got, want in zip(jax.tree.leaves(fed.global_models["g_M"]),
                         jax.tree.leaves(expected)):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


def test_blend_impls_agree():
    """The Pallas kernel (in-host) and the all-reduce-lowerable reduction
    (SPMD) must compute the same Eq. 11 blend."""
    ecfg = EncoderConfig(d_hidden=8, n_layers=1)
    rng = np.random.default_rng(0)
    stacked = {"w": jnp.asarray(rng.normal(0, 1, (5, 17)).astype(np.float32)),
               "b": jnp.asarray(rng.normal(0, 1, (5, 3, 4)).astype(np.float32))}
    omega = jnp.asarray([0.1, 0.0, 0.4, 0.5, 0.0])
    outs = {}
    for impl in ("pallas", "reduce"):
        fns = make_phase_fns(EngineConfig(ecfg=ecfg, kind="binary", blend=impl))
        outs[impl] = fns.blend_stacked(stacked, omega)
    for k in stacked:
        np.testing.assert_allclose(np.asarray(outs["pallas"][k]),
                                   np.asarray(outs["reduce"][k]),
                                   rtol=1e-6, atol=1e-7)


def test_fedavg_all_zero_weights_keeps_global():
    """Engine-level: zero total weight must keep the previous global model
    instead of dividing by a silent floor."""
    cfg = EngineConfig(ecfg=EncoderConfig(d_hidden=8, n_layers=1), kind="binary")
    fns = make_phase_fns(cfg)
    glob = {"w": jnp.full((4,), 7.0)}
    cands = {"w": jnp.stack([jnp.zeros(4), jnp.ones(4)])}
    out = fns.fedavg_update(glob, cands, jnp.zeros(2))
    np.testing.assert_array_equal(np.asarray(out["w"]), np.full(4, 7.0))
    out2 = fns.fedavg_update(glob, cands, jnp.asarray([0.0, 2.0]))
    np.testing.assert_allclose(np.asarray(out2["w"]), np.ones(4), rtol=1e-6)
