"""Data-fragmentation invariants (paper §III-A) — property-based."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.partitioner import fragmented_overlap, partition
from repro.data.synthetic import generate, make_task


@given(n=st.integers(30, 300), n_clients=st.integers(1, 8),
       fp=st.floats(0, 1), ff=st.floats(0, 1), seed=st.integers(0, 99))
@settings(max_examples=40, deadline=None)
def test_partition_invariants(n, n_clients, fp, ff, seed):
    # normalize fractions to a simplex point
    rest = max(1e-9, fp + ff)
    if rest > 1:
        fp, ff = fp / rest, ff / rest
    fpart = 1.0 - fp - ff
    spec = make_task("smnist")
    data = generate(spec, n, seed=seed)
    clients = partition(data, n_clients, frac_paired=fp, frac_fragmented=ff,
                        frac_partial=fpart, seed=seed)
    assert len(clients) == n_clients

    # 1. paired rows align within a client
    for c in clients:
        np.testing.assert_array_equal(c.paired_a.ids, c.paired_b.ids)

    # 2. conservation: every sample id appears exactly once per modality it has
    ids_a = np.concatenate([np.concatenate([c.partial_a.ids, c.frag_a.ids,
                                            c.paired_a.ids]) for c in clients])
    ids_b = np.concatenate([np.concatenate([c.partial_b.ids, c.frag_b.ids,
                                            c.paired_b.ids]) for c in clients])
    assert len(ids_a) == len(set(ids_a))  # no duplicates within a modality
    assert len(ids_b) == len(set(ids_b))
    all_ids = set(ids_a) | set(ids_b)
    assert all_ids == set(data.ids)  # every sample placed somewhere

    # 3. partial samples exist in exactly one modality anywhere
    part_ids = set()
    for c in clients:
        part_ids |= set(c.partial_a.ids) | set(c.partial_b.ids)
    both = set(ids_a) & set(ids_b)
    assert not (part_ids & both)

    # 4. fragmented rows: A-side and B-side live on DIFFERENT clients
    if n_clients > 1:
        for k, c in enumerate(clients):
            for other in clients[:k] + clients[k + 1:]:
                pass  # ownership split is checked via overlap below
        ov = fragmented_overlap(clients)
        for c in clients:
            # no client holds both halves of the same fragmented sample
            assert not (set(c.frag_a.ids) & set(c.frag_b.ids))
        # every fragmented id with both halves somewhere is in the overlap
        fa = set().union(*[set(c.frag_a.ids) for c in clients])
        fb = set().union(*[set(c.frag_b.ids) for c in clients])
        assert set(ov) == (fa & fb)

    # 5. features/labels travel with their ids
    for c in clients:
        for view in (c.partial_a, c.frag_a, c.paired_a):
            for row, gid in enumerate(view.ids):
                src = np.where(data.ids == gid)[0][0]
                np.testing.assert_array_equal(view.x[row], data.x_a[src])
                np.testing.assert_array_equal(view.y[row], data.y[src])


def test_single_client_fragmented_degenerates_to_self():
    spec = make_task("smnist")
    data = generate(spec, 50, seed=1)
    clients = partition(data, 1, frac_paired=0.2, frac_fragmented=0.6,
                        frac_partial=0.2, seed=1)
    # with one client, "fragmented" rows live on the same client by force
    assert len(clients) == 1
