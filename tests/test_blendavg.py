"""BlendAvg (Eq. 9-11) unit + property tests."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.blendavg import blend_trees, blendavg, blendavg_weights, fedavg


# ------------------------------------------------------------- unit tests --

def test_weights_discard_nonimproving():
    w = blendavg_weights([0.7, 0.5, 0.9], global_score=0.6)
    assert w[1] == 0.0  # 0.5 <= 0.6 discarded
    assert w[0] > 0 and w[2] > 0
    assert w[2] > w[0]  # bigger improvement -> bigger weight
    np.testing.assert_allclose(w.sum(), 1.0)


def test_weights_all_worse_gives_zero_vector():
    w = blendavg_weights([0.1, 0.2], global_score=0.5)
    assert w.sum() == 0.0


def test_blendavg_keeps_global_when_no_improvement():
    glob = {"w": jnp.ones(8)}
    cands = [{"w": jnp.zeros(8)}, {"w": 2 * jnp.ones(8)}]
    scores = {id(cands[0]): 0.1, id(cands[1]): 0.2}
    blended, info = blendavg(glob, cands, lambda m: scores.get(id(m), 0.9))
    assert info["kept_global"]
    np.testing.assert_array_equal(np.asarray(blended["w"]), np.ones(8))


def test_blendavg_proportional_blend():
    glob = {"w": jnp.zeros(4)}
    cands = [{"w": jnp.ones(4)}, {"w": 3 * jnp.ones(4)}]
    # improvements 0.1 and 0.3 -> weights 0.25 / 0.75 -> blend = 2.5
    ev = {id(glob): 0.5, id(cands[0]): 0.6, id(cands[1]): 0.8}
    blended, info = blendavg(glob, cands, lambda m: ev[id(m)])
    np.testing.assert_allclose(np.asarray(blended["w"]), 2.5 * np.ones(4), rtol=1e-6)
    assert not info["kept_global"]


def test_fedavg_volume_weights():
    cands = [{"w": jnp.ones(4)}, {"w": 5 * jnp.ones(4)}]
    out = fedavg(cands, n_samples=[3, 1])
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0 * np.ones(4), rtol=1e-6)


def test_fedavg_all_zero_samples_raises():
    """Regression: the silent max(tot, 1.0) floor used to blend all-zero
    weights into an all-zero model. Zero total volume is now an explicit
    error (the engine path keeps the previous global model instead)."""
    cands = [{"w": jnp.ones(4)}, {"w": 5 * jnp.ones(4)}]
    with pytest.raises(ValueError, match="zero"):
        fedavg(cands, n_samples=[0, 0])


def test_nonfinite_global_score_raises():
    """Regression: a NaN/-inf global score used to silently keep the
    global model forever (every delta masked / NaN omegas). Broken server
    scoring is now an explicit error, not a frozen federation."""
    for bad in (float("nan"), float("-inf"), float("inf")):
        with pytest.raises(ValueError, match="global_score"):
            blendavg_weights([0.7, 0.9], global_score=bad)
    # candidate-side non-finite scores stay legal: they mask that
    # candidate only (a client that never finished reports -inf)
    w = blendavg_weights([float("nan"), 0.9], global_score=0.5)
    assert w[0] == 0.0 and w[1] == 1.0


def test_blendavg_weights_staleness_damping():
    """Async Eq. 9-10: staleness damps, renormalizes, and never resurrects
    a non-improver."""
    w = blendavg_weights([0.9, 0.9, 0.1], 0.5, staleness=[0, 8, 0],
                         staleness_exp=0.5)
    assert w[2] == 0.0  # still discarded
    np.testing.assert_allclose(w[1] / w[0], 3.0 ** -1, rtol=1e-12)
    np.testing.assert_allclose(w.sum(), 1.0)


# --------------------------------------------------------------- property --

@given(scores=st.lists(st.floats(-1, 1, allow_nan=False), min_size=1, max_size=16),
       gscore=st.floats(-1, 1, allow_nan=False))
@settings(max_examples=200, deadline=None)
def test_weights_properties(scores, gscore):
    w = blendavg_weights(scores, gscore)
    assert (w >= 0).all()
    # normalized iff any model improves
    if any(s > gscore for s in scores):
        np.testing.assert_allclose(w.sum(), 1.0, rtol=1e-9)
        # discarding: w_i == 0 exactly for non-improving models
        for wi, si in zip(w, scores):
            assert (wi > 0) == (si > gscore)
        # order preservation: bigger delta -> bigger weight
        deltas = [s - gscore for s in scores]
        order = np.argsort(deltas)
        ws = w[order]
        assert (np.diff(ws) >= -1e-12).all()
    else:
        assert w.sum() == 0.0


@given(n=st.integers(1, 6), dim=st.integers(1, 32), seed=st.integers(0, 1000))
@settings(max_examples=50, deadline=None)
def test_blend_trees_is_convex_combination(n, dim, seed):
    """Blended leaf must stay inside the convex hull of candidate leaves."""
    rng = np.random.default_rng(seed)
    trees = [{"a": jnp.asarray(rng.normal(0, 1, dim).astype(np.float32))}
             for _ in range(n)]
    deltas = rng.random(n) + 1e-3
    omega = deltas / deltas.sum()
    out = np.asarray(blend_trees(trees, omega)["a"])
    stack = np.stack([np.asarray(t["a"]) for t in trees])
    assert (out <= stack.max(0) + 1e-5).all()
    assert (out >= stack.min(0) - 1e-5).all()


@given(seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_blendavg_never_degrades_on_val(seed):
    """The defining invariant: post-aggregation val score >= global score
    when eval is exact (here: score = -||w - target||)."""
    rng = np.random.default_rng(seed)
    target = rng.normal(0, 1, 16).astype(np.float32)

    def ev(m):
        return -float(np.linalg.norm(np.asarray(m["w"]) - target))

    glob = {"w": jnp.asarray(rng.normal(0, 1, 16).astype(np.float32))}
    cands = [{"w": jnp.asarray(rng.normal(0, 1, 16).astype(np.float32))}
             for _ in range(4)]
    blended, info = blendavg(glob, cands, ev)
    # kept-global case trivially holds; blended case: convexity of the norm
    # guarantees the blend of improving models also improves
    assert ev(blended) >= ev(glob) - 1e-5
