#!/usr/bin/env python
"""Bench-record schema checker — fails fast on emission regressions.

Validates every ``benchmarks/results/BENCH_*.json`` against the record
schema documented in ``docs/benchmarks.md``:

- the file parses as a JSON object (a truncated/interleaved write is the
  exact failure ``benchmarks.common.write_bench_json`` exists to prevent
  — this checker is its backstop);
- required envelope keys: ``bench`` (snake_case id) and ``backend``
  (string, ``jax.default_backend()`` at run time);
- exactly one of ``record`` (non-empty object) / ``records`` (non-empty
  list of objects);
- every number anywhere in the payload is finite — a NaN/Infinity
  measurement is a broken measurement, and ``json.dump`` happily emits
  non-RFC ``NaN`` literals that would poison cross-PR comparisons;
- ``compile_cache`` / ``caches`` values (the retrace regression signal)
  are integers >= 1;
- compression fields (the wire-codec regression signal, wherever they
  appear — ``BENCH_comm.json`` today): ``compression_ratio`` is a
  number >= 1 (a "compressed" payload larger than dense means the byte
  accounting broke) and ``bytes_per_round`` / ``bytes_to_target`` /
  ``bytes_per_message`` are numbers > 0 (zero wire bytes means the
  accounting saw an empty model tree);
- convergence fields (the rounds-to-target signal of
  ``BENCH_participation.json`` / ``BENCH_aggregation.json``):
  ``rounds_to_target`` is null ("never reached" is a valid outcome) or
  an integer >= 1, and ``target_auroc`` / ``final_auroc`` /
  ``best_auroc`` are numbers in [0, 1] (an AUROC outside the unit
  interval means the metric plumbing broke);
- scenario event counts (the churn accounting of
  ``BENCH_scenario.json``): ``n_join`` / ``n_leave`` / ``n_corrupt``
  are integers >= 0 (a negative or non-integer event count means the
  scenario bookkeeping broke);
- attack accounting (``BENCH_attack.json``): ``backdoor_success_rate``
  is a number in [0, 1] (a rate outside the unit interval means the
  triggered-eval bookkeeping broke);
- serving accounting (``BENCH_serve.json``): ``p50_ms`` / ``p99_ms``
  are numbers >= 0 with ``p50_ms <= p99_ms`` wherever both appear in
  one record (inverted percentiles mean the latency bookkeeping broke),
  ``rps`` / ``rows_per_s`` are numbers > 0, and ``bytes_per_request``
  is a number >= 0 (an all-local mix legitimately moves zero bytes).

``benchmarks/results/`` is gitignored, so a fresh checkout has nothing
to validate — that's a pass (the checker guards whatever records the
current machine has produced, e.g. the benches CI or a dev ran earlier
in the same job). Exit status 1 lists every violation with file:path.

    python tools/bench_check.py [results_dir]
"""
from __future__ import annotations

import glob
import json
import math
import os
import re
import sys

DEFAULT_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "benchmarks", "results")

_BENCH_ID = re.compile(r"^[a-z][a-z0-9_]*$")
_CACHE_KEYS = ("compile_cache", "caches")
# wire-codec accounting fields: ratio >= 1, byte counts > 0 (None is
# allowed for *_to_target fields — "never reached" is a valid outcome)
_RATIO_KEYS = ("compression_ratio",)
_BYTES_KEYS = ("bytes_per_round", "bytes_to_target", "bytes_per_message")
# convergence accounting: rounds null-or-int>=1, AUROCs in the unit interval
_ROUNDS_KEYS = ("rounds_to_target",)
_AUROC_KEYS = ("target_auroc", "final_auroc", "best_auroc")
# churn accounting: scenario event counts are non-negative integers
_EVENT_KEYS = ("n_join", "n_leave", "n_corrupt")
# attack accounting (BENCH_attack.json): a success rate is a fraction
_RATE_KEYS = ("backdoor_success_rate",)
# serving accounting (BENCH_serve.json): latencies are non-negative
# milliseconds with p50 <= p99 wherever both appear in one record,
# throughputs are strictly positive, and bytes/request is >= 0 (an
# all-local request mix legitimately moves zero wire bytes — unlike the
# _BYTES_KEYS round traffic, where zero means broken accounting)
_LATENCY_KEYS = ("p50_ms", "p99_ms")
_THROUGHPUT_KEYS = ("rps", "rows_per_s")
_FREE_BYTES_KEYS = ("bytes_per_request",)


def _walk_numbers(node, path, errors):
    """Recursive finiteness check; bools are not numbers."""
    if isinstance(node, bool) or node is None or isinstance(node, str):
        return
    if isinstance(node, (int, float)):
        if not math.isfinite(node):
            errors.append(f"{path}: non-finite number {node!r}")
        return
    if isinstance(node, dict):
        for k, v in node.items():
            _walk_numbers(v, f"{path}.{k}", errors)
        return
    if isinstance(node, list):
        for i, v in enumerate(node):
            _walk_numbers(v, f"{path}[{i}]", errors)


def _is_number(v):
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _check_caches(node, path, errors):
    if isinstance(node, dict):
        p50, p99 = node.get("p50_ms"), node.get("p99_ms")
        if _is_number(p50) and _is_number(p99) and p50 > p99:
            errors.append(f"{path}: p50_ms {p50!r} exceeds p99_ms {p99!r} "
                          "— percentile accounting broke")
        for k, v in node.items():
            p = f"{path}.{k}"
            if k in _CACHE_KEYS:
                vals = v if isinstance(v, list) else [v]
                for c in vals:
                    if isinstance(c, bool) or not isinstance(c, int) or c < 1:
                        errors.append(
                            f"{p}: cache count must be an int >= 1, got {c!r}")
            elif k in _RATIO_KEYS:
                if not (_is_number(v) and v >= 1):
                    errors.append(f"{p}: compression ratio must be a number "
                                  f">= 1, got {v!r}")
            elif k in _BYTES_KEYS:
                if v is not None and not (_is_number(v) and v > 0):
                    errors.append(f"{p}: byte count must be a number > 0 "
                                  f"(or null), got {v!r}")
            elif k in _ROUNDS_KEYS:
                if v is not None and (isinstance(v, bool)
                                      or not isinstance(v, int) or v < 1):
                    errors.append(f"{p}: rounds-to-target must be an int "
                                  f">= 1 (or null), got {v!r}")
            elif k in _AUROC_KEYS:
                if not (_is_number(v) and 0.0 <= v <= 1.0):
                    errors.append(f"{p}: AUROC must be a number in [0, 1], "
                                  f"got {v!r}")
            elif k in _EVENT_KEYS:
                if isinstance(v, bool) or not isinstance(v, int) or v < 0:
                    errors.append(f"{p}: scenario event count must be an "
                                  f"int >= 0, got {v!r}")
            elif k in _RATE_KEYS:
                if not (_is_number(v) and 0.0 <= v <= 1.0):
                    errors.append(f"{p}: attack success rate must be a "
                                  f"number in [0, 1], got {v!r}")
            elif k in _LATENCY_KEYS:
                if not (_is_number(v) and v >= 0):
                    errors.append(f"{p}: latency must be a number >= 0 ms, "
                                  f"got {v!r}")
            elif k in _THROUGHPUT_KEYS:
                if not (_is_number(v) and v > 0):
                    errors.append(f"{p}: throughput must be a number > 0, "
                                  f"got {v!r}")
            elif k in _FREE_BYTES_KEYS:
                if not (_is_number(v) and v >= 0):
                    errors.append(f"{p}: byte count must be a number >= 0, "
                                  f"got {v!r}")
            else:
                _check_caches(v, p, errors)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            _check_caches(v, f"{path}[{i}]", errors)


def check_payload(payload, name: str) -> list:
    """Schema violations for one parsed BENCH_*.json payload."""
    errors = []
    if not isinstance(payload, dict):
        return [f"{name}: top level must be a JSON object"]
    bench = payload.get("bench")
    if not (isinstance(bench, str) and _BENCH_ID.match(bench)):
        errors.append(f"{name}.bench: missing or not a snake_case id "
                      f"({bench!r})")
    if not isinstance(payload.get("backend"), str):
        errors.append(f"{name}.backend: missing or not a string")
    has_rec = "record" in payload
    has_recs = "records" in payload
    if has_rec == has_recs:
        errors.append(f"{name}: need exactly one of 'record'/'records'")
    if has_rec and not (isinstance(payload["record"], dict)
                        and payload["record"]):
        errors.append(f"{name}.record: must be a non-empty object")
    if has_recs and not (isinstance(payload["records"], list)
                         and payload["records"]
                         and all(isinstance(r, dict)
                                 for r in payload["records"])):
        errors.append(f"{name}.records: must be a non-empty list of objects")
    _walk_numbers(payload, name, errors)
    _check_caches(payload, name, errors)
    return errors


def check_file(path: str) -> list:
    name = os.path.basename(path)
    try:
        with open(path) as f:
            # json.loads accepts NaN/Infinity literals by default; we
            # want them flagged, so parse them into floats and let the
            # finiteness walk report the path
            payload = json.load(f)
    except ValueError as e:
        return [f"{name}: unparseable JSON ({e})"]
    return check_payload(payload, name)


def main(argv: list) -> int:
    results_dir = argv[0] if argv else DEFAULT_DIR
    files = sorted(glob.glob(os.path.join(results_dir, "BENCH_*.json")))
    if not files:
        print(f"bench-check: no BENCH_*.json under {results_dir} "
              "(nothing to validate — OK)")
        return 0
    errors = []
    for f in files:
        errors.extend(check_file(f))
    if errors:
        print(f"bench-check: {len(errors)} schema violation(s):")
        for e in errors:
            print("  " + e)
        return 1
    print(f"bench-check: OK ({len(files)} record file(s) conform)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
