#!/usr/bin/env python
"""Docs reference checker — keeps README.md and docs/ from rotting.

Scans the given markdown files (default: README.md + docs/**/*.md) and
verifies that everything they point at still exists in the tree:

- markdown links ``[text](path)`` (non-URL): the path must exist,
  resolved against the repo root or the doc's own directory;
- inline-code file paths (``src/repro/data/store.py``, ``docs/...``,
  ``benchmarks/...``): must exist; tried against the repo root, ``src/``,
  ``src/repro/`` and the doc's directory so layer-relative mentions work;
- inline-code module dotpaths (``repro.data.store``,
  ``benchmarks.run``) and ``python -m <module>`` invocations inside
  fenced blocks: must resolve to a module file or package; a trailing
  attribute is allowed if its name appears in the module source;
- ``make <target>`` mentions (inline or fenced): the target must be
  defined in the Makefile.

Paths under ``benchmarks/results/`` (gitignored run artifacts) and
tokens containing glob wildcards are exempt. Exit status 1 lists every
broken reference with file:line.

    python tools/docs_check.py [files...]
"""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# bare (slash-less) filenames worth checking when mentioned
ROOT_FILES = {"README.md", "ROADMAP.md", "CHANGES.md", "PAPER.md",
              "PAPERS.md", "SNIPPETS.md", "ISSUE.md", "Makefile",
              "pytest.ini"}
# run artifacts / scratch paths that legitimately may not exist
EXEMPT_PREFIXES = ("benchmarks/results/", "/tmp/")

_FENCE = re.compile(r"^(```|~~~)")
_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)[^)]*\)")
_CODE = re.compile(r"`([^`\n]+)`")
_PATHISH = re.compile(r"^[A-Za-z0-9_./-]+$")
_DOTPATH = re.compile(r"^(repro|benchmarks)(\.[A-Za-z_][A-Za-z0-9_]*)+$")
_MAKE = re.compile(r"\bmake\s+([A-Za-z][A-Za-z0-9_-]*)")
_PYMOD = re.compile(r"-m\s+([A-Za-z_][A-Za-z0-9_.]*)")


def _exists_any(token: str, doc_dir: str) -> bool:
    for base in (ROOT, os.path.join(ROOT, "src"),
                 os.path.join(ROOT, "src", "repro"), doc_dir):
        if os.path.exists(os.path.join(base, token)):
            return True
    return False


def _module_ok(dotpath: str) -> tuple[bool, str]:
    """Resolve a dotted module path, tolerating one trailing attribute."""
    parts = dotpath.split(".")
    base = os.path.join(ROOT, "src") if parts[0] == "repro" else ROOT

    def _file_for(comps):
        p = os.path.join(base, *comps)
        if os.path.isfile(p + ".py"):
            return p + ".py"
        if os.path.isdir(p) and os.path.isfile(os.path.join(p, "__init__.py")):
            return os.path.join(p, "__init__.py")
        return None

    if _file_for(parts):
        return True, ""
    mod = _file_for(parts[:-1])
    if mod:  # module.attr — require the attr name to appear in the source
        attr = parts[-1]
        with open(mod) as f:
            if re.search(rf"\b{re.escape(attr)}\b", f.read()):
                return True, ""
        return False, f"module {'.'.join(parts[:-1])} has no {attr!r}"
    return False, "no such module"


def _make_targets() -> set:
    targets = set()
    mk = os.path.join(ROOT, "Makefile")
    if os.path.isfile(mk):
        for line in open(mk):
            m = re.match(r"^([A-Za-z0-9_.-]+)\s*:", line)
            if m:
                targets.add(m.group(1))
    return targets


def check_file(path: str, make_targets: set) -> list:
    doc_dir = os.path.dirname(os.path.abspath(path))
    errors = []
    in_fence = False
    for lineno, line in enumerate(open(path), start=1):
        if _FENCE.match(line.strip()):
            in_fence = not in_fence
            continue

        def err(msg):
            errors.append(f"{os.path.relpath(path, ROOT)}:{lineno}: {msg}")

        # make targets + `python -m module` are checked in *command
        # contexts only — fenced non-comment lines (the quickstart must
        # run) and inline code spans — so prose like "make sure", in
        # text or in a shell comment, never trips
        if in_fence:
            commands = [] if line.lstrip().startswith("#") else [line]
        else:
            commands = [m.group(1) for m in _CODE.finditer(line)]
        for text in commands:
            for m in _MAKE.finditer(text):
                if m.group(1) not in make_targets:
                    err(f"no Makefile target {m.group(1)!r}")
            for m in _PYMOD.finditer(text):
                ok, why = _module_ok(m.group(1))
                if not ok:
                    err(f"unresolvable module {m.group(1)!r} ({why})")
        if in_fence:
            continue

        for m in _LINK.finditer(line):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if not _exists_any(target, doc_dir):
                err(f"broken link target {target!r}")
        for m in _CODE.finditer(line):
            tok = m.group(0)[1:-1].strip()
            if "*" in tok or not _PATHISH.match(tok):
                continue
            if tok.startswith(EXEMPT_PREFIXES) or tok.rstrip("/") == "":
                continue
            if _DOTPATH.match(tok):
                ok, why = _module_ok(tok)
                if not ok:
                    err(f"unresolvable module {tok!r} ({why})")
            elif "/" in tok:
                if not _exists_any(tok.rstrip("/"), doc_dir):
                    err(f"missing path {tok!r}")
            elif tok in ROOT_FILES:
                if not os.path.isfile(os.path.join(ROOT, tok)):
                    err(f"missing root file {tok!r}")
    return errors


def main(argv: list) -> int:
    files = argv or ([os.path.join(ROOT, "README.md")] +
                     sorted(glob.glob(os.path.join(ROOT, "docs", "**", "*.md"),
                                      recursive=True)))
    missing = [f for f in files if not os.path.isfile(f)]
    if missing:
        print("docs-check: missing input files: " + ", ".join(missing))
        return 1
    make_targets = _make_targets()
    errors = []
    for f in files:
        errors.extend(check_file(f, make_targets))
    if errors:
        print(f"docs-check: {len(errors)} broken reference(s):")
        for e in errors:
            print("  " + e)
        return 1
    print(f"docs-check: OK ({len(files)} files, "
          f"{len(make_targets)} make targets known)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
