"""Checkpoint inspector — the debugging surface for state-block migrations.

Prints, for one checkpoint step (latest by default), what a resume would
see BEFORE committing to a target tree: the round it was saved at, its
metadata (store fingerprint included), its client capacity, and the
round-state block layout — every leaf grouped under its registered
block (``repro.core.state.REGISTRY``) with shape and dtype. Top-level
keys that no registered block claims print under a ``?`` prefix: that
is layout drift, the exact thing to look at when a restore or a
capacity migration fails.

    PYTHONPATH=src python tools/ckpt_inspect.py /tmp/fedckpt
    PYTHONPATH=src python tools/ckpt_inspect.py /tmp/fedckpt --step 4
    make ckpt-inspect CKPT_DIR=/tmp/fedckpt
"""
from __future__ import annotations

import argparse
import sys


def inspect(ckpt_dir: str, step: int | None = None, out=sys.stdout) -> int:
    from repro.checkpoint import latest_step, read_manifest
    from repro.core.state import manifest_capacity, manifest_layout

    resolved = step if step is not None else latest_step(ckpt_dir)
    if resolved is None:
        print(f"no checkpoints under {ckpt_dir}", file=out)
        return 1
    manifest = read_manifest(ckpt_dir, resolved)
    meta = manifest.get("metadata", {})
    print(f"checkpoint {ckpt_dir} step {resolved}", file=out)
    print(f"  round:       {meta.get('round', manifest.get('step'))}", file=out)
    fp = meta.get("store_fingerprint")
    store = f"{fp[:12]}…" if fp else "in-memory (no fingerprint)"
    print(f"  store:       {store}", file=out)
    for k, v in sorted(meta.items()):
        if k not in ("round", "store_fingerprint"):
            print(f"  {k + ':':<12} {v}", file=out)
    try:
        print(f"  capacity:    {manifest_capacity(manifest)} client slots",
              file=out)
    except KeyError as e:
        print(f"  capacity:    ? ({e})", file=out)
    layout = manifest_layout(manifest)
    drift = [n for n in layout if n.startswith("?")]
    print(f"  blocks:      {len(layout)}"
          + (f"  (UNREGISTERED: {', '.join(drift)})" if drift else ""),
          file=out)
    for name, leaves in layout.items():
        tag = " <- NOT IN REGISTRY" if name.startswith("?") else ""
        print(f"\n  {name}  ({len(leaves)} leaves){tag}", file=out)
        for path, shape, dtype in leaves:
            print(f"    {path:<52} {str(tuple(shape)):<20} {dtype}", file=out)
    return 2 if drift else 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("ckpt_dir", help="checkpoint directory (step_N subdirs)")
    ap.add_argument("--step", type=int, default=None,
                    help="step to inspect (default: latest)")
    args = ap.parse_args()
    try:
        sys.exit(inspect(args.ckpt_dir, args.step))
    except BrokenPipeError:  # e.g. piped through `head`
        sys.exit(0)


if __name__ == "__main__":
    main()
