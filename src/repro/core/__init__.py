"""BlendFL — the paper's primary contribution.

    partitioner   paired / fragmented / partial data assignment (§III-A)
    encoders      per-modality f_m, unimodal g_m, fusion g_M
    vfl           split training on fragmented data (Alg. 1 lines 9-23)
    blendavg      performance-weighted aggregation (Eq. 9-11)
    federation    Algorithm 1 round + fit loop (in-host clients)
    federation_sharded  the same round as one SPMD program (clients =
                  mesh slices; aggregation = masked psum) — dry-run entry
    inference     decentralized inference (contribution #2)
    baselines     FedAvg/FedMA/FedProx/FedNova/SplitNN/One-Shot VFL/HFCL/
                  centralized (§IV-C)
"""
from repro.core.blendavg import blendavg, blendavg_weights, fedavg
from repro.core.federation import FedConfig, Federation, evaluate_global
from repro.core.partitioner import ClientData, ModalView, partition

__all__ = [
    "blendavg", "blendavg_weights", "fedavg",
    "FedConfig", "Federation", "evaluate_global",
    "ClientData", "ModalView", "partition",
]
