"""BlendFL — the paper's primary contribution.

    partitioner   paired / fragmented / partial data assignment (§III-A)
    encoders      per-modality f_m, unimodal g_m, fusion g_M
    vfl           split training on fragmented data (Alg. 1 lines 9-23)
    blendavg      performance-weighted aggregation (Eq. 9-11)
    engine        the stacked-client round engine: Algorithm 1's four
                  phases as pure jitted functions over pytrees with a
                  leading client axis (shared by both federation drivers)
    federation    in-host orchestrator over the engine (host AUROC scoring)
    federation_sharded  the same engine phases as one SPMD program
                  (clients = mesh slices; aggregation = masked all-reduce)
                  — dry-run entry
    inference     decentralized inference (contribution #2)
    baselines     FedAvg/FedMA/FedProx/FedNova/SplitNN/One-Shot VFL/HFCL/
                  centralized (§IV-C)
"""
from repro.core.blendavg import blendavg, blendavg_weights, fedavg
from repro.core.engine import EngineConfig, RoundEngine, make_phase_fns
from repro.core.federation import FedConfig, Federation, evaluate_global
from repro.core.partitioner import ClientData, ModalView, partition

__all__ = [
    "blendavg", "blendavg_weights", "fedavg",
    "EngineConfig", "RoundEngine", "make_phase_fns",
    "FedConfig", "Federation", "evaluate_global",
    "ClientData", "ModalView", "partition",
]
