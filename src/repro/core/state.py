"""Round-state block registry — the single source of state-block layout.

Round state accreted one block per feature across the repo's history:
stacked client models, optimizer moments, the async ``last_round``
vector, ``sched`` participation telemetry, ``codec`` error-feedback
residuals, ``strat`` control variates / server moments. Each block used
to carry its own bespoke init / sample-by-ids / scatter / checkpoint
plumbing in BOTH drivers. This module replaces that with one declarative
registry: a ``BlockSpec`` per block states which leaves carry the
leading client axis, how the block gathers/scatters under K-of-C
sampled ids, and how new client rows are filled when the cohort grows —
and every driver routes through the shared operations below.

The registry is also the seam for **elastic cohorts**: the stacked
leading-C axis is a *capacity*, not a membership count. ``grow`` pads
every registered stacked leaf to the next capacity bucket
(``capacity_for``), so a federation whose cohort crosses a bucket
boundary recompiles its round program at most once per bucket and the
compile cache stays 1 within a bucket. Membership itself (who is
active, joined, left) is host-side scenario data
(``repro.data.scenario``) — inactive rows are simply never sampled.

Gather/scatter semantics per block, declared by ``BlockSpec.stacked``:

- ``"all"``    every leaf has a leading client axis (models, last_round,
               sched) — gather/scatter whole-tree by ids.
- ``"none"``   no leaf is per-client (server head, global models, the
               round counter) — sampling passes through, scatter
               replaces wholesale.
- a tuple      only the named top-level sub-keys are stacked (opt
               moments vs. the shared ``step``; ``resid_up`` vs. the
               server-side ``resid_down``; ``c_local`` vs. ``c_global``
               and ``srv``) — listed keys gather/scatter by ids, the
               rest replace wholesale.

Everything here is pure jnp and safe under jit: sampled ids stay data,
never shape.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import aggregate as strategies
from repro.core import codec as wire
from repro.core import schedule

# Model groups of Algorithm 1: per-modality encoders f, unimodal heads
# g, and the multimodal fusion head g_M. (Canonical home; re-exported by
# ``repro.core.engine`` where the phase functions consume it.)
CLIENT_GROUPS = ("f_A", "g_A", "f_B", "g_B", "g_M")

# Optimizer-state pytrees that mirror the params (and therefore carry
# the leading client axis); everything else in an opt state (the shared
# ``step`` counter) is global.
OPT_MOMENT_KEYS = ("mu", "nu", "mom")

# Clients are padded to capacity buckets so cohort growth recompiles at
# most once per bucket: capacity_for(17) == capacity_for(24) == 24.
CAPACITY_BUCKET = 8


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """Declarative description of one top-level round-state block.

    ``stacked``: "all" | "none" | tuple of stacked top-level sub-keys.
    ``fill``: value new client rows take when the cohort grows — a
    scalar, the sentinel ``"global"`` (new rows adopt the current global
    models, i.e. a fresh client joins exactly like Algorithm 1's shared
    init), or a dict of per-sub-key scalars for "all" blocks whose
    sub-trees fill differently (``sched``).
    ``optional``: the block may be absent from a state dict (codec
    "none" / stateless strategies add no keys — the standing checkpoint
    layout contract).
    """

    name: str
    stacked: object = "none"
    fill: object = 0.0
    optional: bool = False


REGISTRY: tuple[BlockSpec, ...] = (
    BlockSpec("models", "all", fill="global"),
    BlockSpec("server_gmv"),
    BlockSpec("global_models"),
    BlockSpec("opt", OPT_MOMENT_KEYS, fill=0.0),
    BlockSpec("srv_opt"),
    BlockSpec("last_round", "all", fill=-1),
    BlockSpec("round"),
    BlockSpec("sched", "all",
              fill={"omega_ema": 0.0, "part_count": 0, "last_round": -1}),
    BlockSpec("codec", ("resid_up",), fill=0.0, optional=True),
    BlockSpec("strat", ("c_local",), fill=0.0, optional=True),
)

BLOCKS = {b.name: b for b in REGISTRY}


def block(name: str) -> BlockSpec:
    try:
        return BLOCKS[name]
    except KeyError:
        raise KeyError(
            f"unregistered round-state block {name!r} — every top-level "
            f"state key must be declared in repro.core.state.REGISTRY "
            f"(known: {sorted(BLOCKS)})") from None


# --------------------------------------------- K-of-C leaf primitives ------

def sample_clients(stacked_tree, idx):
    """Gather the sampled clients' rows of every stacked leaf:
    (C, ...) -> (K, ...). ``idx`` (K,) int is data, not shape — a fixed K
    compiles once across different sampled subsets."""
    idx = jnp.asarray(idx, jnp.int32)
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=0), stacked_tree)


def scatter_clients(stacked_tree, sub_tree, idx):
    """Inverse of ``sample_clients``: write K updated rows back into the
    full stacked tree at the sampled positions."""
    idx = jnp.asarray(idx, jnp.int32)
    return jax.tree.map(lambda full, s: full.at[idx].set(s.astype(full.dtype)),
                        stacked_tree, sub_tree)


# ------------------------------------------------- block-level operations --

def sample_block(name: str, value, idx):
    """Gather one registered block down to the sampled rows. ``idx`` None
    (full participation) is the identity; "none" blocks pass through;
    tuple blocks gather only their stacked sub-keys (absent optional
    sub-keys are skipped)."""
    spec = block(name)
    if idx is None or spec.stacked == "none":
        return value
    if spec.stacked == "all":
        return sample_clients(value, idx)
    out = dict(value)
    for k in spec.stacked:
        if k in value:
            out[k] = sample_clients(value[k], idx)
    return out


def scatter_block(name: str, full, sub, idx):
    """Write one block's per-round update back. ``idx`` None replaces
    wholesale (full participation / global blocks); otherwise stacked
    leaves scatter the K rows to the sampled positions while a tuple
    block's unstacked sub-keys replace. Sub-keys absent from ``sub``
    keep their previous value."""
    spec = block(name)
    if idx is None or spec.stacked == "none":
        return sub
    if spec.stacked == "all":
        return scatter_clients(full, sub, idx)
    out = dict(full)
    for k, v in sub.items():
        out[k] = scatter_clients(full[k], v, idx) if k in spec.stacked else v
    return out


def sample(state: dict, idx) -> dict:
    """Gather a whole round state down to the sampled rows, block by
    registered block (unknown keys raise — register new blocks, don't
    smuggle them)."""
    return {name: sample_block(name, value, idx)
            for name, value in state.items()}


def scatter(state: dict, updates: dict, idx) -> dict:
    """Write a round's per-block updates back into the full state.
    Blocks absent from ``updates`` keep their previous value."""
    out = dict(state)
    for name, value in updates.items():
        out[name] = scatter_block(name, state.get(name), value, idx)
    return out


# opt-state views used directly by the engine/tests (back-compat names)

def sample_opt_state(opt_state, idx):
    """Gather an optimizer state's per-client moment pytrees down to the
    sampled rows; the shared ``step`` counter (and any other non-stacked
    entries) pass through untouched."""
    return sample_block("opt", opt_state, idx)


def scatter_opt_state(opt_state, sub_state, idx):
    """Write a sampled round's optimizer state back: moment rows scatter
    to the sampled positions, the shared ``step`` counter (advanced by the
    sampled round) replaces the old one."""
    return scatter_block("opt", opt_state, sub_state, idx)


# ----------------------------------------------------- state construction --

def build_round_state(stacked, server_gmv, global_models, opt_state,
                      srv_opt_state, n_clients: int, codec_on: bool,
                      scfg) -> dict:
    """Assemble the canonical round-state dict from its model/optimizer
    ingredients — the ONE place the block layout is spelled out. Both
    drivers' ``init_round_state`` delegate here, and the layout is
    byte-identical to pre-registry checkpoints: codec "none" and
    stateless strategies add no keys."""
    state = {
        "models": stacked,
        "server_gmv": server_gmv,
        "global_models": global_models,
        "opt": opt_state,
        "srv_opt": srv_opt_state,
        "last_round": jnp.full((n_clients,), -1, jnp.int32),
        "round": jnp.zeros((), jnp.int32),
        "sched": schedule.sched_state(n_clients),
    }
    if codec_on:
        state["codec"] = {
            "resid_up": wire.zeros_like_tree(stacked),
            "resid_down": wire.zeros_like_tree(global_models),
        }
    if scfg is not None and scfg.stateful:
        state["strat"] = strategies.init_state(
            scfg, {k: stacked[k] for k in CLIENT_GROUPS}, global_models)
    return state


# ------------------------------------------------------- elastic cohorts ---

def capacity_for(n_clients: int, bucket: int = CAPACITY_BUCKET) -> int:
    """Smallest capacity bucket holding ``n_clients`` slots. Buckets
    bound recompiles: every cohort size inside a bucket shares one
    compiled round program."""
    if n_clients < 1:
        raise ValueError(f"n_clients={n_clients} must be >= 1")
    return bucket * ((n_clients + bucket - 1) // bucket)


def state_capacity(state: dict) -> int:
    """Client capacity C a round state was stacked for (the leading axis
    of its ``last_round`` vector — present in every layout)."""
    return int(state["last_round"].shape[0])


def _pad_rows(leaf, n_new: int, fill):
    if n_new <= 0:
        return leaf
    pad = jnp.full((n_new,) + leaf.shape[1:], fill, leaf.dtype)
    return jnp.concatenate([leaf, pad], axis=0)


def _grow_tree(tree, n_new: int, fill):
    return jax.tree.map(lambda x: _pad_rows(x, n_new, fill), tree)


def _global_rows(state, value, n_new: int):
    """New-client model rows: broadcast the current global models, so a
    joining client starts exactly like Algorithm 1's shared init — from
    the blend everyone else currently agrees on."""
    glob = {k: state["global_models"][k] for k in value}
    return jax.tree.map(
        lambda x, g: jnp.concatenate(
            [x, jnp.broadcast_to(g[None], (n_new,) + g.shape).astype(x.dtype)],
            axis=0),
        value, glob)


def grow(state: dict, new_capacity: int) -> dict:
    """Re-stack every registered block to a larger capacity: existing
    rows are untouched (bit-exact), new rows take each block's declared
    fill (models adopt the current globals; moments, residuals, and
    control variates start at zero; ``last_round`` starts at -1 like a
    fresh federation). Shrinking in place is refused — retire slots via
    the scenario's active mask instead (``retire_clients``)."""
    old = state_capacity(state)
    if new_capacity < old:
        raise ValueError(
            f"cannot shrink round state in place: capacity {old} -> "
            f"{new_capacity}; retire clients via the scenario active mask")
    if new_capacity == old:
        return state
    n_new = new_capacity - old
    out = {}
    for name, value in state.items():
        spec = block(name)
        if spec.stacked == "none":
            out[name] = value
        elif spec.stacked == "all":
            if spec.fill == "global":
                out[name] = _global_rows(state, value, n_new)
            elif isinstance(spec.fill, dict):
                out[name] = {k: _grow_tree(v, n_new, spec.fill.get(k, 0))
                             for k, v in value.items()}
            else:
                out[name] = _grow_tree(value, n_new, spec.fill)
        else:
            out[name] = {k: (_grow_tree(v, n_new, spec.fill)
                             if k in spec.stacked else v)
                         for k, v in value.items()}
    return out


def retire_clients(state: dict, ids) -> dict:
    """Reset the given client slots to their fresh-join fill values
    (models back to the current globals, moments/residuals/variates to
    zero, ``last_round`` to -1). Membership removal itself is the
    scenario's active mask — retired slots are never sampled again; this
    just stops a departed client's private state from lingering in
    checkpoints."""
    idx = jnp.asarray(ids, jnp.int32)

    def _reset(leaf, fill):
        rows = jnp.full((idx.shape[0],) + leaf.shape[1:], fill, leaf.dtype)
        return leaf.at[idx].set(rows)

    def _reset_tree(tree, fill):
        return jax.tree.map(lambda x: _reset(x, fill), tree)

    out = {}
    for name, value in state.items():
        spec = block(name)
        if spec.stacked == "none":
            out[name] = value
        elif spec.stacked == "all":
            if spec.fill == "global":
                glob = {k: state["global_models"][k] for k in value}
                out[name] = jax.tree.map(
                    lambda x, g: x.at[idx].set(jnp.broadcast_to(
                        g[None], (idx.shape[0],) + g.shape).astype(x.dtype)),
                    value, glob)
            elif isinstance(spec.fill, dict):
                out[name] = {k: _reset_tree(v, spec.fill.get(k, 0))
                             for k, v in value.items()}
            else:
                out[name] = _reset_tree(value, spec.fill)
        else:
            out[name] = {k: (_reset_tree(v, spec.fill)
                             if k in spec.stacked else v)
                         for k, v in value.items()}
    return out


# --------------------------------------------------- checkpoint inspection --

def manifest_layout(manifest: dict) -> dict:
    """Group a checkpoint manifest's flat ``a/b/c`` leaf keys by their
    top-level state block, in registry order. Returns
    ``{block_name: [(leaf_path, shape, dtype), ...]}`` with any
    UNREGISTERED top-level keys collected under ``"?<key>"`` — the drift
    detector ``tools/ckpt_inspect.py`` prints loudly."""
    shapes, dtypes = manifest["shapes"], manifest["dtypes"]
    grouped: dict[str, list] = {}
    for key in manifest["keys"]:
        top = key.split("/", 1)[0]
        name = top if top in BLOCKS else f"?{top}"
        grouped.setdefault(name, []).append(
            (key, tuple(shapes[key]), dtypes[key]))
    order = [b.name for b in REGISTRY]
    return {name: grouped[name]
            for name in sorted(grouped, key=lambda n: (
                order.index(n) if n in BLOCKS else len(order), n))}


def manifest_capacity(manifest: dict) -> int:
    """Client capacity a checkpointed round state was stacked for, read
    off its ``last_round`` leaf — the migration dispatch key."""
    try:
        return int(manifest["shapes"]["last_round"][0])
    except KeyError:
        raise KeyError(
            "checkpoint manifest has no 'last_round' leaf — not a "
            "round-state checkpoint") from None
