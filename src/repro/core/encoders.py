"""Per-modality encoders and classifiers for the BlendFL federation.

The paper's clinical arch is MedFuse-style (LSTM over EHR + ResNet-34 over
CXR); its S-MNIST arch is two ResNet-18s. Our federation instantiates the
same *roles* with JAX encoders sized for the experiment:

    f_m : (B, S_m, F_m) -> h (B, d)        modality encoder
    g_m : h -> logits                       unimodal classifier
    g_M : (h_A, h_B) -> logits              multimodal (fusion) classifier

``enc_type``: 'mlp' (fast, CPU experiments), 'recurrent' (sLSTM cell — the
LSTM role), 'transformer' (attention block — the ResNet role stand-in for
patch embeddings). Any of the 10 assigned backbones can also serve as f_m
via ``repro.models`` (see configs/blendfl_paper.py); the federation logic
is encoder-agnostic.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.data.synthetic import TaskSpec
from repro.models.common import dense, dense_init, rmsnorm, rmsnorm_init, sigmoid_bce, softmax_cross_entropy
from repro.models.recurrent import slstm_init, slstm_scan


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    d_hidden: int = 64
    n_layers: int = 2
    enc_type: str = "mlp"  # mlp | recurrent | transformer
    n_heads: int = 4


def encoder_init(key, feat_dim: int, ecfg: EncoderConfig, dtype=jnp.float32):
    ks = jax.random.split(key, ecfg.n_layers + 2)
    d = ecfg.d_hidden
    p = {"in": dense_init(ks[0], feat_dim, d, dtype, bias=True)}
    if ecfg.enc_type == "mlp":
        p["hidden"] = [dense_init(ks[i + 1], d, d, dtype, bias=True)
                       for i in range(ecfg.n_layers)]
    elif ecfg.enc_type == "recurrent":
        p["cell"] = slstm_init(ks[1], d, ecfg.n_heads, dtype)
    elif ecfg.enc_type == "transformer":
        p["ln"] = rmsnorm_init(d, dtype)
        p["wq"] = dense_init(ks[1], d, d, dtype)
        p["wk"] = dense_init(ks[2], d, d, dtype)
        p["wv"] = dense_init(ks[3], d, d, dtype)
        p["ff"] = dense_init(ks[4], d, d, dtype, bias=True)
    else:
        raise ValueError(ecfg.enc_type)
    p["norm"] = rmsnorm_init(d, dtype)
    return p


def encoder_apply(p, x, ecfg: EncoderConfig):
    """x (B, S, F) -> h (B, d)."""
    h = jnp.tanh(dense(p["in"], x))
    if ecfg.enc_type == "mlp":
        h = jnp.mean(h, axis=1)
        for layer in p["hidden"]:
            h = h + jax.nn.gelu(dense(layer, h))
    elif ecfg.enc_type == "recurrent":
        seq, _ = slstm_scan(p["cell"], h, ecfg.n_heads)
        h = seq[:, -1]
    elif ecfg.enc_type == "transformer":
        hn = rmsnorm(p["ln"], h)
        b, s, d = hn.shape
        nh = ecfg.n_heads
        hd = d // nh
        q = dense(p["wq"], hn).reshape(b, s, nh, hd)
        k = dense(p["wk"], hn).reshape(b, s, nh, hd)
        v = dense(p["wv"], hn).reshape(b, s, nh, hd)
        att = jax.nn.softmax(
            jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(hd), axis=-1)
        h = h + jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, s, d)
        h = h + jax.nn.gelu(dense(p["ff"], h))
        h = jnp.mean(h, axis=1)
    return rmsnorm(p["norm"], h)


def head_init(key, d_in: int, n_out: int, dtype=jnp.float32):
    return dense_init(key, d_in, n_out, dtype, bias=True)


def fusion_init(key, d: int, n_out: int, dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    return {"mix": dense_init(k1, 2 * d, d, dtype, bias=True),
            "out": dense_init(k2, d, n_out, dtype, bias=True)}


def fusion_apply(p, h_a, h_b):
    h = jax.nn.gelu(dense(p["mix"], jnp.concatenate([h_a, h_b], axis=-1)))
    return dense(p["out"], h)


# ------------------------------------------------------- model container ----

def init_client_models(key, spec: TaskSpec, ecfg: EncoderConfig, dtype=jnp.float32):
    """Full per-client model set {f_A, f_B, g_A, g_B, g_M}."""
    ks = jax.random.split(key, 5)
    d = ecfg.d_hidden
    return {
        "f_A": encoder_init(ks[0], spec.feat_a, ecfg, dtype),
        "f_B": encoder_init(ks[1], spec.feat_b, ecfg, dtype),
        "g_A": head_init(ks[2], d, spec.out_dim, dtype),
        "g_B": head_init(ks[3], d, spec.out_dim, dtype),
        "g_M": fusion_init(ks[4], d, spec.out_dim, dtype),
    }


def predict_unimodal(models, x, modality: str, ecfg: EncoderConfig):
    h = encoder_apply(models[f"f_{modality}"], x, ecfg)
    return dense(models[f"g_{modality}"], h)


def predict_multimodal(models, x_a, x_b, ecfg: EncoderConfig):
    h_a = encoder_apply(models["f_A"], x_a, ecfg)
    h_b = encoder_apply(models["f_B"], x_b, ecfg)
    return fusion_apply(models["g_M"], h_a, h_b)


def task_loss(logits, y, kind: str):
    if kind == "multiclass":
        labels = jnp.argmax(y, axis=-1)
        return jnp.mean(softmax_cross_entropy(logits, labels))
    return jnp.mean(sigmoid_bce(logits, y))  # binary / multilabel


def task_scores(logits, kind: str):
    """Probability scores for AUROC/AUPRC computation."""
    if kind == "multiclass":
        return jax.nn.softmax(logits, axis=-1)
    return jax.nn.sigmoid(logits)
