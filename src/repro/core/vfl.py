"""Vertical (split) training for fragmented data — paper Alg. 1 lines 9-23.

The exchange is SplitNN-shaped but expressed JAX-natively:

    client k:  h_m = f_m(x_m)                     ClientForwardPass
    server:    align h_A, h_B by global sample id ServerAggregateFeatures
               ŷ = g_M^v(h_A, h_B); L(ŷ, y)       ServerForwardPass
               ∂L/∂g_M^v, ∂L/∂h_A, ∂L/∂h_B        ServerBackwardPass
    client k:  ∂L/∂f_m = vjp(f_m, x_m)(∂L/∂h_m)   ReceiveGradients+Backward

Raw data never leaves a client — only latent features go up and feature
cotangents come down. Because the client backward is the exact ``jax.vjp``
of the client forward, the split gradients equal end-to-end autodiff of
the joint model (property-tested in tests/test_vfl.py).

On the TPU mesh, the upload is an all-gather of ``h`` shards over the
client ("data") axis and the gradient return is its transpose — both
produced automatically when the joint loss is differentiated under pjit;
see repro/core/federation_sharded.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoders import EncoderConfig, encoder_apply, fusion_apply, task_loss


# --------------------------------------------------------------- alignment --

def align_by_id(ids_a: np.ndarray, ids_b: np.ndarray):
    """Server-side private-set alignment: row indices (ia, ib) such that
    ids_a[ia] == ids_b[ib], each id used once, sorted by id."""
    common, ia, ib = np.intersect1d(ids_a, ids_b, return_indices=True)
    return common, ia, ib


# ------------------------------------------------------------ split passes --

def client_forward(f_params, x, ecfg: EncoderConfig):
    """ClientForwardPass: latent features h for local fragmented samples."""
    return encoder_apply(f_params, x, ecfg)


def server_loss(gmv_params, h_a, h_b, y, kind: str):
    logits = fusion_apply(gmv_params, h_a, h_b)
    return task_loss(logits, y, kind)


def server_forward_backward(gmv_params, h_a, h_b, y, kind: str):
    """ServerForward+BackwardPass: loss, server-head grads, feature grads."""
    loss, (g_srv, g_ha, g_hb) = jax.value_and_grad(server_loss, argnums=(0, 1, 2))(
        gmv_params, h_a, h_b, y, kind)
    return loss, g_srv, g_ha, g_hb


def client_backward(f_params, x, h_grad, ecfg: EncoderConfig):
    """ReceiveGradientsAndBackwardPass: chain the feature cotangent through
    the local encoder. Exact vjp -> split grads == joint autodiff."""
    _, vjp = jax.vjp(lambda p: encoder_apply(p, x, ecfg), f_params)
    (g_enc,) = vjp(h_grad)
    return g_enc


# ------------------------------------------------------- one VFL iteration --

@dataclasses.dataclass
class VflBatch:
    """Aligned fragmented batch: rows of x_a / x_b refer to the same global
    samples; owner_a[i] / owner_b[i] are the holding clients' indices."""

    x_a: np.ndarray
    x_b: np.ndarray
    y: np.ndarray
    owner_a: np.ndarray
    owner_b: np.ndarray


def build_vfl_batches(clients, batch_size: int, rng: np.random.Generator):
    """Server-side alignment of all fragmented rows (Private Set
    Intersection stand-in, per the paper's assumption)."""
    xa, ia, oa = [], [], []
    xb, ib, ob = [], [], []
    for k, c in enumerate(clients):
        if len(c.frag_a):
            xa.append(c.frag_a.x); ia.append(c.frag_a.ids)
            oa.append(np.full(len(c.frag_a), k))
        if len(c.frag_b):
            xb.append(c.frag_b.x); ib.append(c.frag_b.ids)
            ob.append(np.full(len(c.frag_b), k))
    if not xa or not xb:
        return []
    xa = np.concatenate(xa); ia = np.concatenate(ia); oa = np.concatenate(oa)
    xb = np.concatenate(xb); ib = np.concatenate(ib); ob = np.concatenate(ob)
    _, ra, rb = align_by_id(ia, ib)
    if len(ra) == 0:
        return []
    ya = np.concatenate([c.frag_a.y for c in clients if len(c.frag_a)])
    order = rng.permutation(len(ra))
    ra, rb = ra[order], rb[order]
    batches = []
    for i in range(0, len(ra), batch_size):
        sa, sb = ra[i : i + batch_size], rb[i : i + batch_size]
        batches.append(VflBatch(xa[sa], xb[sb], ya[sa], oa[sa], ob[sb]))
    return batches


def vfl_step(f_a_params, f_b_params, gmv_params, batch: VflBatch, ecfg: EncoderConfig,
             kind: str):
    """One split-training step over an aligned batch, assuming per-client
    encoders have already been gathered into f_a_params/f_b_params *for the
    rows of this batch* (the federation layer slices per-owner params).

    Returns (loss, grads dict). All three grads come from ONE joint vjp —
    definitionally identical to the split exchange (see module docstring),
    while letting XLA fuse the whole round trip.
    """

    def joint(fa, fb, gmv):
        h_a = encoder_apply(fa, jnp.asarray(batch.x_a), ecfg)
        h_b = encoder_apply(fb, jnp.asarray(batch.x_b), ecfg)
        return server_loss(gmv, h_a, h_b, jnp.asarray(batch.y), kind)

    loss, (g_fa, g_fb, g_srv) = jax.value_and_grad(joint, argnums=(0, 1, 2))(
        f_a_params, f_b_params, gmv_params)
    return loss, {"f_A": g_fa, "f_B": g_fb, "g_M_v": g_srv}


def vfl_step_split(f_a_params, f_b_params, gmv_params, batch: VflBatch,
                   ecfg: EncoderConfig, kind: str):
    """The literal wire protocol (forward up / cotangent down), used by the
    gradient-equivalence test and the decentralized-latency benchmark."""
    x_a, x_b, y = jnp.asarray(batch.x_a), jnp.asarray(batch.x_b), jnp.asarray(batch.y)
    h_a = client_forward(f_a_params, x_a, ecfg)
    h_b = client_forward(f_b_params, x_b, ecfg)
    loss, g_srv, g_ha, g_hb = server_forward_backward(gmv_params, h_a, h_b, y, kind)
    g_fa = client_backward(f_a_params, x_a, g_ha, ecfg)
    g_fb = client_backward(f_b_params, x_b, g_hb, ecfg)
    return loss, {"f_A": g_fa, "f_B": g_fb, "g_M_v": g_srv}
