"""BlendFL federation — Algorithm 1, orchestrated over in-host clients.

One ``blendfl_round`` is the paper's training epoch:

    1. local unimodal training on *partial* data        (lines 3-8)
    2. split (VFL) training on *fragmented* data        (lines 9-23)
    3. local multimodal training on *paired* data       (lines 24-29)
    4. BlendAvg aggregation + broadcast                 (lines 30-32)

Architecture: every phase's math lives in ``repro.core.engine`` as pure
jitted functions over **stacked client pytrees** — all client models carry
a leading ``C`` axis, ragged per-client data is padded to static shapes
with per-row masks, and each phase is ONE compiled program (vmap over
clients, ``lax.scan`` over minibatches) regardless of client count or
modality. This class is a thin orchestrator: it builds the padded stacked
batches once at init, threads (models, optimizer state) through the
engine's phases each round, and runs the server-side BlendAvg scoring
(real AUROC/AUPRC on the representative validation set — a host metric,
so scoring sits here rather than in the engine; the weighted blend itself
goes back through the engine's Pallas ``blend_params`` path).

The in-host <-> sharded mapping: ``federation_sharded.make_blendfl_round``
drives the *same* engine phase functions as one SPMD program (client axis
sharded over the mesh, on-device loss surrogate for scoring). The two
files differ only in orchestration — batching+host metrics here, sharding
+surrogate scoring there; no phase math is duplicated.

Optimizers are pluggable via ``FedConfig.optimizer`` ("sgd" | "adamw",
constant or cosine schedule); per-client optimizer state is a stacked
pytree threaded through rounds. On BlendAvg broadcast clients adopt the
blended weights but keep their own moments (exact Algorithm 1 under plain
SGD, standard stateful-FL practice under AdamW).

Partial participation (``FedConfig.n_sampled`` = K > 0): each round a
host-side participation policy (``FedConfig.policy``, see
``repro.core.schedule`` — uniform by default, bit-exact with the
pre-scheduler RNG draw) picks K of the C clients; their rows of the stacked
models/opt-state/batches are gathered to (K, ...) trees (a static-shape
registry gather, ``repro.core.state`` — the sampled *indices* are data, so the
phase programs still compile exactly once), trained, and scattered back.
The VFL alignment keeps its static row count; rows whose owner was not
sampled get row weight 0. With ``FedConfig.async_mode`` the round is the
staleness-weighted async variant: only participants receive the broadcast
(stragglers keep stale weights, tracked by the per-client ``last_round``
vector), and at aggregation a candidate trained from an s-rounds-old base
has its Eq. 9-10 omega damped by (1+s)^-``staleness_exp``. Non-sampled
clients are masked out of the blend entirely — exactly like empty batches
in the training phases.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import tree_unstack
from repro.core import aggregate as strategies
from repro.core import codec as wire
from repro.core import schedule, vfl
from repro.core import state as rstate
from repro.core.blendavg import blendavg_weights
from repro.core.encoders import (
    EncoderConfig,
    encoder_apply,
    fusion_apply,
    init_client_models,
    task_scores,
)
from repro.core.engine import (
    CLIENT_GROUPS,
    EngineConfig,
    RoundEngine,
    sample_clients,
    stack_with,
)
from repro.core.partitioner import ClientData, ModalView, fragmented_overlap
from repro.data.synthetic import SyntheticMultimodal, TaskSpec
from repro.metrics import auprc, auroc


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_clients: int = 3
    rounds: int = 20
    local_epochs: int = 1  # local passes between aggregations (Fig. 2 x-axis)
    batch_size: int = 64
    lr: float = 1e-3
    optimizer: str = "sgd"  # sgd | adamw (repro.optim, threaded per client)
    momentum: float = 0.0  # sgd momentum
    weight_decay: float = 0.0  # adamw decoupled weight decay
    schedule: str = "constant"  # constant | cosine (over all optimizer steps)
    # Aggregation strategy (``repro.core.aggregate``): blendavg (Eq. 9-11
    # scored blend) | fedavg (data-volume weights) | fedprox (volume
    # weights + the mu-scaled proximal pull toward each client's
    # round-start anchor) | scaffold (uniform blend + control-variate
    # gradient corrections threaded through federation state).
    # The Byzantine-robust reducers (median | trimmed_mean | krum) are
    # strategy names too — stateless, weights-free order-statistic /
    # distance-score aggregation (``n_malicious`` = their assumed
    # attacker budget f). ``aggregator`` is the pre-strategy spelling of
    # the same knob, kept as an alias: setting it fills ``strategy``,
    # and the two are always equal after init.
    strategy: str = ""  # "" = follow aggregator (default blendavg)
    aggregator: str = "blendavg"
    fedprox_mu: float = 0.0
    # Server-side optimizer on the blended delta (FedAdam / momentum),
    # applied before broadcast; composes with any strategy.
    server_opt: str = "none"  # none | adam | momentum
    server_lr: float = 1.0
    n_malicious: int = 1
    # Which local rows feed phase-1 unimodal training. "all" (default)
    # reads Alg. 1's "partial data" as "the unimodal portions of D_m" —
    # every locally held x_m row (partial + fragmented + paired), matching
    # the paper's claim that BlendFL "leverages all data available at the
    # clients". "strict" uses only the partial(D_m) subset (the literal
    # line-4 reading); both are benchmarked in EXPERIMENTS.md.
    unimodal_data: str = "all"  # all | partial
    metric: str = "auroc"
    seed: int = 0
    # Partial participation: K-of-C client sampling per round. 0 = full
    # participation (every client trains every round).
    n_sampled: int = 0
    # Async rounds (requires n_sampled): only sampled clients receive the
    # post-aggregation broadcast; the rest keep stale weights and their
    # later candidates get staleness-damped omegas. False = synchronous
    # partial participation (everyone syncs to the new global each round).
    async_mode: bool = False
    staleness_exp: float = 0.5  # omega damping (1+s)^-a; 0 disables
    # Participation policy for sampled rounds (repro.core.schedule):
    # which K of the C clients train, picked host-side from the sched
    # telemetry (omega EMA / participation counts / last_round). The ids
    # are data, so the policy never retraces a phase. "uniform" is the
    # pre-scheduler behavior, bit-exact (same host_rng.choice draw).
    policy: str = "uniform"
    ema_beta: float = 0.9  # omega-EMA telemetry decay
    # Wire codec for the simulated round traffic (candidate uplink +
    # broadcast downlink deltas with error-feedback residuals; see
    # ``repro.core.codec``). "none" = uncompressed fp32.
    codec: str = "none"  # none | int8 | topk | int8_topk
    topk_frac: float = 0.25  # entries kept per leaf by sparsifying codecs

    def __post_init__(self):
        if not self.strategy:
            object.__setattr__(self, "strategy", self.aggregator)
        object.__setattr__(self, "aggregator", self.strategy)
        k = self.n_sampled or self.n_clients
        f = self.n_malicious
        if self.strategy == "krum" and k < f + 3:
            raise ValueError(
                f"krum needs at least n_malicious + 3 = {f + 3} candidates "
                f"per round, got K={k}")
        if self.strategy == "trimmed_mean" and k < 2 * f + 1:
            raise ValueError(
                f"trimmed_mean needs at least 2 * n_malicious + 1 = "
                f"{2 * f + 1} candidates per round, got K={k}")

    @property
    def strategy_cfg(self) -> strategies.StrategyConfig:
        return strategies.make_strategy(self.strategy, self.fedprox_mu,
                                        self.server_opt, self.server_lr,
                                        self.n_malicious)


# ------------------------------------------------------------- evaluation --

@functools.partial(jax.jit, static_argnames=("ecfg",))
def _client_fwd(f, x, *, ecfg):
    """Jitted single-model encoder forward (evaluation / serving helper)."""
    return encoder_apply(f, x, ecfg)


def _metric_fn(name: str) -> Callable:
    return {"auroc": auroc, "auprc": auprc}[name]


def eval_unimodal(f, g, x, y, ecfg: EncoderConfig, kind: str, metric: str = "auroc"):
    from repro.models.common import dense

    h = _client_fwd(f, jnp.asarray(x), ecfg=ecfg)
    scores = task_scores(dense(g, h), kind)
    return float(_metric_fn(metric)(np.asarray(y), np.asarray(scores)))


def eval_multimodal(f_a, f_b, g_m, x_a, x_b, y, ecfg: EncoderConfig, kind: str,
                    metric: str = "auroc"):
    h_a = _client_fwd(f_a, jnp.asarray(x_a), ecfg=ecfg)
    h_b = _client_fwd(f_b, jnp.asarray(x_b), ecfg=ecfg)
    scores = task_scores(fusion_apply(g_m, h_a, h_b), kind)
    return float(_metric_fn(metric)(np.asarray(y), np.asarray(scores)))


# --------------------------------------------- stacked padded data builds --

def _pad_rows(n_max: int, batch_size: int) -> int:
    """Static padded row count: a positive multiple of the batch size."""
    b = max(1, batch_size)
    return max(b, b * math.ceil(max(n_max, 1) / b))


def _stack_views(views: list[ModalView], n_pad: int, seq: int, feat: int,
                 out_dim: int):
    """list of ragged per-client views -> x (C,n_pad,seq,feat), y, mask."""
    c = len(views)
    x = np.zeros((c, n_pad, seq, feat), np.float32)
    y = np.zeros((c, n_pad, out_dim), np.float32)
    m = np.zeros((c, n_pad), np.float32)
    for k, v in enumerate(views):
        n = len(v)
        if n:
            x[k, :n] = v.x
            y[k, :n] = v.y
            m[k, :n] = 1.0
    return jnp.asarray(x), jnp.asarray(y), jnp.asarray(m)


def _build_unimodal_data(clients: list[ClientData], cfg: FedConfig, spec: TaskSpec):
    def view(cd, side):
        if cfg.unimodal_data == "all":
            return cd.all_a() if side == "a" else cd.all_b()
        return cd.partial_a if side == "a" else cd.partial_b

    va = [view(cd, "a") for cd in clients]
    vb = [view(cd, "b") for cd in clients]
    n_pad = _pad_rows(max(max(len(v) for v in va), max(len(v) for v in vb)),
                      cfg.batch_size)
    xa, ya, ma = _stack_views(va, n_pad, spec.seq_a, spec.feat_a, spec.out_dim)
    xb, yb, mb = _stack_views(vb, n_pad, spec.seq_b, spec.feat_b, spec.out_dim)
    return {"xa": xa, "ya": ya, "ma": ma, "xb": xb, "yb": yb, "mb": mb}


def _build_paired_data(clients: list[ClientData], cfg: FedConfig, spec: TaskSpec):
    if not any(cd.has_paired for cd in clients):
        return None
    n_pad = _pad_rows(max(len(cd.paired_a) for cd in clients), cfg.batch_size)
    xa, ya, m = _stack_views([cd.paired_a for cd in clients], n_pad,
                             spec.seq_a, spec.feat_a, spec.out_dim)
    xb, _, _ = _stack_views([cd.paired_b for cd in clients], n_pad,
                            spec.seq_b, spec.feat_b, spec.out_dim)
    return {"xa": xa, "xb": xb, "y": ya, "m": m}


def _build_vfl_data(clients: list[ClientData], spec: TaskSpec):
    """Stack fragmented rows per owner + precompute the server alignment
    (PSI stand-in) as gather indices into the flattened (C*Nf) latent rows.

    Only rows in the cross-client overlap are kept: rows whose partner
    modality never arrived can't train, so encoding them in the VFL phase
    would be pure waste (the padded row count, and with it the phase's
    encoder FLOPs, scales with the overlap instead of the raw frag count).

    Returns (device batch, host alignment metadata) — the metadata (numpy
    gather indices + per-side padded row counts) lets a sampled round
    remap the alignment onto the gathered K-client layout without
    rebuilding or re-padding anything.
    """
    c = len(clients)
    overlap = fragmented_overlap(clients)

    def keep(view):
        sel = np.isin(view.ids, overlap)
        return ModalView(view.x[sel], view.ids[sel], view.y[sel])

    fa = [keep(cd.frag_a) for cd in clients]
    fb = [keep(cd.frag_b) for cd in clients]
    nfa = max(max((len(v) for v in fa), default=0), 1)
    nfb = max(max((len(v) for v in fb), default=0), 1)
    xa, ya, _ = _stack_views(fa, nfa, spec.seq_a, spec.feat_a, spec.out_dim)
    xb, _, _ = _stack_views(fb, nfb, spec.seq_b, spec.feat_b, spec.out_dim)
    ids_a = np.full(c * nfa, -1, np.int64)
    ids_b = np.full(c * nfb, -1, np.int64)
    for k in range(c):
        ids_a[k * nfa : k * nfa + len(fa[k])] = fa[k].ids
        ids_b[k * nfb : k * nfb + len(fb[k])] = fb[k].ids
    pos_a = np.nonzero(ids_a >= 0)[0]
    pos_b = np.nonzero(ids_b >= 0)[0]
    _, ia, ib = vfl.align_by_id(ids_a[pos_a], ids_b[pos_b])
    if len(ia) == 0:
        return None, None
    gather_a = pos_a[ia]
    gather_b = pos_b[ib]
    y = np.asarray(ya).reshape(c * nfa, -1)[gather_a]
    part_a = np.zeros(c, bool)
    part_b = np.zeros(c, bool)
    part_a[np.unique(gather_a // nfa)] = True
    part_b[np.unique(gather_b // nfb)] = True
    batch = {"xa": xa, "xb": xb, "gather_a": jnp.asarray(gather_a, jnp.int32),
             "gather_b": jnp.asarray(gather_b, jnp.int32),
             "y": jnp.asarray(y), "part_a": jnp.asarray(part_a),
             "part_b": jnp.asarray(part_b)}
    host = {"gather_a": gather_a, "gather_b": gather_b, "nfa": nfa, "nfb": nfb}
    return batch, host


# -------------------------------------------------------------- federation --

@dataclasses.dataclass
class Federation:
    """Mutable federation state: stacked clients + the BlendFL server."""

    cfg: FedConfig
    spec: TaskSpec
    ecfg: EncoderConfig
    clients: list  # list[ClientData]
    engine: RoundEngine
    stacked: dict  # stacked client models {f_A, f_B, g_A, g_B, g_M}, leading C
    opt_state: dict  # stacked per-client optimizer state
    global_models: dict  # blended {f_A, f_B, g_A, g_B, g_M}
    server_gmv: dict  # g_M^v split-training head at the server
    srv_opt_state: dict  # server-head optimizer state
    val: SyntheticMultimodal  # server-side representative validation set
    data: dict  # device-resident padded stacked batches per phase
    key: jax.Array  # PRNG for on-device batch shuffling
    # partial-participation round state
    host_rng: np.random.Generator = None  # host-side client-sampling RNG
    last_round: np.ndarray = None  # (C,) round each client last synced
    round_no: int = 0  # index of the NEXT round to run
    # participation-scheduler telemetry (repro.core.schedule): EMA of
    # each client's BlendAvg omega + participation counts, updated every
    # aggregation; the policy reads them (with last_round/round_no/rows)
    # to pick the next round's K ids
    policy_obj: object = None  # schedule.Policy
    omega_ema: np.ndarray = None  # (C,) float64
    part_count: np.ndarray = None  # (C,) int64
    # wire-codec error-feedback residuals (None when cfg.codec == "none"):
    # stacked per-client uplink rows + one server-side downlink tree
    resid_up: dict = None
    resid_down: dict = None
    # aggregation-strategy state (None for stateless strategies):
    # SCAFFOLD's c_global/c_local control variates (c_local stacked,
    # gathered/scattered with the sampled ids like opt moments) and/or
    # the server-optimizer moments under "srv"
    strat_state: dict = None
    # optimizer steps each model group takes per round (SCAFFOLD's
    # Option-II 1/(steps*lr) scaling) — static, from the padded batch
    # counts x local_epochs
    scaffold_steps: dict = None

    @property
    def models(self) -> list[dict]:
        """Per-client model dicts — a read-only SNAPSHOT unstacked from
        ``self.stacked`` on every access. Assigning into it does not
        change federation state; mutate ``self.stacked`` instead."""
        return tree_unstack(self.stacked, self.cfg.n_clients)

    @staticmethod
    def init(key, cfg: FedConfig, spec: TaskSpec, ecfg: EncoderConfig,
             clients: list, val: SyntheticMultimodal) -> "Federation":
        if cfg.n_sampled < 0 or cfg.n_sampled > cfg.n_clients:
            raise ValueError(
                f"n_sampled={cfg.n_sampled} must be in [0, n_clients]")
        if cfg.async_mode and not cfg.n_sampled:
            raise ValueError("async_mode requires n_sampled > 0 (with full "
                             "participation every candidate is fresh)")
        if cfg.policy != "uniform" and not cfg.n_sampled:
            raise ValueError(f"policy={cfg.policy!r} requires n_sampled > 0 "
                             "(full participation has nothing to schedule)")
        # validates the policy name even when n_sampled == 0
        policy_obj = schedule.make_policy(cfg.policy, cfg.n_clients,
                                          cfg.n_sampled or cfg.n_clients)
        base = init_client_models(key, spec, ecfg)
        vfl_batch, vfl_host = _build_vfl_data(clients, spec)
        data = {
            "uni": _build_unimodal_data(clients, cfg, spec),
            "paired": _build_paired_data(clients, cfg, spec),
            "vfl": vfl_batch,
            "vfl_host": vfl_host,
            "val": {"x_a": jnp.asarray(val.x_a), "x_b": jnp.asarray(val.x_b)},
            # constant for the federation's lifetime; the server head's
            # FedAvg weight (Eq. 8 candidate) in _aggregate
            "n_overlap": len(fragmented_overlap(clients)),
        }
        steps_per_epoch = (data["uni"]["ma"].shape[1] // cfg.batch_size
                          + (data["paired"]["m"].shape[1] // cfg.batch_size
                             if data["paired"] is not None else 0)
                          + (1 if data["vfl"] is not None else 0))
        scfg = cfg.strategy_cfg
        engine = RoundEngine(
            EngineConfig(ecfg=ecfg, kind=spec.kind, optimizer=cfg.optimizer,
                         lr=cfg.lr, momentum=cfg.momentum,
                         weight_decay=cfg.weight_decay, schedule=cfg.schedule,
                         total_steps=cfg.rounds * cfg.local_epochs * steps_per_epoch,
                         # the server head steps once per epoch (one
                         # full-batch VFL exchange), not once per minibatch
                         server_total_steps=cfg.rounds * cfg.local_epochs,
                         staleness_exp=cfg.staleness_exp,
                         codec=wire.make_codec(cfg.codec, cfg.topk_frac),
                         strategy=scfg),
            cfg.batch_size)
        # all clients start from the same global init (standard FL practice)
        stacked = engine.fns.broadcast(base, cfg.n_clients)
        codec_on = cfg.codec != "none"
        # SCAFFOLD step counts per group, per round: encoders step in all
        # three phases, unimodal heads only in phase 1, the fusion head
        # only in phase 3 (one optimizer step per scanned minibatch; the
        # VFL exchange is one full-batch step)
        nb_uni = data["uni"]["ma"].shape[1] // cfg.batch_size
        nb_paired = (data["paired"]["m"].shape[1] // cfg.batch_size
                     if data["paired"] is not None else 0)
        nb_vfl = 1 if data["vfl"] is not None else 0
        e = float(cfg.local_epochs)
        scaffold_steps = {
            "f_A": e * (nb_uni + nb_vfl + nb_paired),
            "f_B": e * (nb_uni + nb_vfl + nb_paired),
            "g_A": e * nb_uni, "g_B": e * nb_uni, "g_M": e * nb_paired,
        }
        return Federation(
            cfg=cfg, spec=spec, ecfg=ecfg, clients=clients, engine=engine,
            stacked=stacked, opt_state=engine.init_opt_state(stacked),
            global_models=base,
            server_gmv=jax.tree.map(jnp.copy, base["g_M"]),
            srv_opt_state=engine.init_server_opt_state(base["g_M"]),
            val=val, data=data, key=jax.random.PRNGKey(cfg.seed),
            host_rng=np.random.default_rng(cfg.seed),
            last_round=np.full(cfg.n_clients, -1, np.int64),
            policy_obj=policy_obj,
            omega_ema=np.zeros(cfg.n_clients),
            part_count=np.zeros(cfg.n_clients, np.int64),
            resid_up=wire.zeros_like_tree(stacked) if codec_on else None,
            resid_down=(wire.zeros_like_tree(
                {k: base[k] for k in CLIENT_GROUPS}) if codec_on else None),
            strat_state=(strategies.init_state(
                scfg, {k: stacked[k] for k in CLIENT_GROUPS},
                {k: base[k] for k in CLIENT_GROUPS})
                if scfg.stateful else None),
            scaffold_steps=scaffold_steps,
        )

    def _next_key(self):
        self.key, sub = jax.random.split(self.key)
        return sub

    # ---- phases 1-3: one engine call each ----

    def _strat_block(self, anchor, idxd=None):
        """Per-participant strategy block for the phase functions (None
        for strategies with no client-side term): each participant's
        round-start weights anchor the FedProx pull; SCAFFOLD's c_local
        rows gather with the sampled ids exactly like opt moments."""
        scfg = self.engine.cfg.strategy
        if not scfg.client_active:
            return None
        strat = {}
        if scfg.prox:
            strat["anchor"] = anchor
        if scfg.control:
            sub = strategies.sample_state(self.strat_state, idxd)
            strat["c_global"] = sub["c_global"]
            strat["c_local"] = sub["c_local"]
        return strat

    def _unimodal_phase(self, strat=None) -> float:
        self.stacked, self.opt_state, loss = self.engine.unimodal_phase(
            self.stacked, self.opt_state, self.data["uni"], self._next_key(),
            strat)
        return float(loss)

    def _vfl_phase(self, strat=None) -> float:
        """Full-batch split exchange, exactly as Alg. 1: every aligned
        fragmented row goes through ONE joint forward/backward (static row
        count -> compiles once)."""
        if self.data["vfl"] is None:
            return float("nan")
        (self.stacked, self.server_gmv, self.opt_state, self.srv_opt_state,
         loss) = self.engine.vfl_phase(self.stacked, self.server_gmv,
                                       self.opt_state, self.srv_opt_state,
                                       self.data["vfl"], strat)
        return float(loss)

    def _paired_phase(self, strat=None) -> float:
        if self.data["paired"] is None:
            return float("nan")
        self.stacked, self.opt_state, loss = self.engine.paired_phase(
            self.stacked, self.opt_state, self.data["paired"],
            self._next_key(), strat)
        return float(loss)

    # ---- phase 4: aggregation + broadcast ----

    def _candidate_metrics(self, scores_stacked, present) -> np.ndarray:
        """Host-side AUROC/AUPRC per stacked candidate; absent -> -inf."""
        metric = _metric_fn(self.cfg.metric)
        y = np.asarray(self.val.y)
        snp = np.asarray(scores_stacked)
        out = np.full(len(present), -np.inf)
        for k, p in enumerate(present):
            if p:
                out[k] = metric(y, snp[k])
        return out

    def _blend_group(self, global_tree, stacked_cands, scores, global_score,
                     fedavg_weights, staleness=None):
        """Shared scored/weighted blend dispatch; the blend itself runs
        through the engine's Pallas path. BlendAvg consumes the Eq. 9-10
        scores; every other strategy consumes the precomputed
        ``fedavg_weights`` (data volumes for fedavg/fedprox, uniform
        presence for scaffold). Returns (new_global, omega). ``staleness``
        (per-candidate, rounds the candidate's base global is behind)
        damps the BlendAvg omegas — zero/None for synchronous rounds, and
        a scoring concept the weighted strategies ignore.

        The Byzantine-robust strategies dispatch to the engine's
        ``robust_update`` (median / trimmed-mean order statistics, or
        the multi-Krum survivor mask multiplied into the volume weights
        — which makes krum the fedavg path bit-for-bit at
        n_malicious = 0). They treat every candidate as present: an
        absent client's weight-zero row still occupies a candidate slot
        in the order statistics, so robust runs want full-modality
        cohorts (the bench's straggler cohort is one)."""
        fns = self.engine.fns
        if self.engine.cfg.strategy.score_based:
            omega = blendavg_weights(scores, global_score, staleness=staleness,
                                     staleness_exp=self.cfg.staleness_exp)
            if omega.sum() == 0:  # no improvement anywhere -> keep global
                return global_tree, omega
            return fns.blend_stacked(stacked_cands, omega), omega
        w = np.asarray(fedavg_weights, np.float64)
        if self.engine.cfg.strategy.robust:
            new, omega = fns.robust_update(global_tree, stacked_cands, w)
            return new, np.asarray(omega)
        new = fns.fedavg_update(global_tree, stacked_cands, w)
        tot = w.sum()
        return new, (w / tot if tot > 0 else w)

    def _aggregate(self, cand_stacked=None, idx=None, base=None) -> dict:
        """Phase 4. Full participation: candidates are ``self.stacked``.
        Sampled round: ``cand_stacked`` holds the K trained client trees
        and ``idx`` the sampled client ids — only those clients compete in
        the blend (non-finished clients are masked out entirely), and in
        async mode their omegas are staleness-damped. With a wire codec
        configured, ``base`` is the tree the participants started the
        round from: candidates arrive as decoded uplink deltas (scoring
        and blending see what the server would actually receive), and the
        new global leaves as a decoded downlink delta."""
        cfg, val, fns = self.cfg, self.val, self.engine.fns
        ecfg, kind, metric = self.ecfg, self.spec.kind, self.cfg.metric
        x_a, x_b = self.data["val"]["x_a"], self.data["val"]["x_b"]
        info = {}

        if cand_stacked is None:
            cand_stacked = self.stacked
        scfg = self.engine.cfg.strategy
        codec_on = self.resid_up is not None
        # the pre-round global tree: the codec's downlink reference and
        # the server optimizer's delta base
        prev_glob = {k: self.global_models[k] for k in CLIENT_GROUPS}
        if codec_on:
            assert base is not None, "codec rounds must pass the uplink base"
            idxd = None if idx is None else jnp.asarray(idx, jnp.int32)
            resid = rstate.sample_block(
                "codec", {"resid_up": self.resid_up}, idxd)["resid_up"]
            cand_stacked, resid = self.engine.codec_uplink(cand_stacked, base,
                                                           resid)
            self.resid_up = rstate.scatter_block(
                "codec", {"resid_up": self.resid_up}, {"resid_up": resid},
                idxd)["resid_up"]
        sub_clients = (self.clients if idx is None
                       else [self.clients[i] for i in idx])
        stale = None
        if idx is not None:
            # rounds the candidate's base global model is behind; fresh
            # participants (synced at the end of the previous round) are 0
            stale = np.maximum(self.round_no - 1 - self.last_round[idx], 0)

        blend = scfg.score_based  # the weighted strategies never read scores
        for mod, x_val in (("A", x_a), ("B", x_b)):
            present = [cd.has_a if mod == "A" else cd.has_b for cd in sub_clients]
            if not any(present):
                continue
            cand = {"f": cand_stacked[f"f_{mod}"], "g": cand_stacked[f"g_{mod}"]}
            glob = {"f": self.global_models[f"f_{mod}"],
                    "g": self.global_models[f"g_{mod}"]}
            scores = gscore = None
            if blend:
                scores = self._candidate_metrics(
                    self.engine.uni_scores(cand["f"], cand["g"], x_val), present)
                gscore = eval_unimodal(glob["f"], glob["g"], x_val, val.y, ecfg,
                                       kind, metric)
            # scaffold: uniform over participants (eta_g = 1 server step);
            # fedavg/fedprox: data-volume weights
            ns = None
            if not blend:
                ns = [(1 if scfg.control else cd.n_samples()) if p else 0
                      for cd, p in zip(sub_clients, present)]
            blended, omega = self._blend_group(glob, cand, scores, gscore, ns,
                                               staleness=stale)
            info[f"omega_{mod}"] = omega
            self.global_models[f"f_{mod}"] = blended["f"]
            self.global_models[f"g_{mod}"] = blended["g"]

        # multimodal: participating client g_M heads + the server's g_M^v
        # (Eq. 8); the server head trains every round, so it is never stale
        present = [cd.has_paired for cd in sub_clients] + [True]
        cand = stack_with(cand_stacked["g_M"], self.server_gmv)
        f_a, f_b = self.global_models["f_A"], self.global_models["f_B"]
        scores = gscore = None
        if blend:
            scores = self._candidate_metrics(
                self.engine.multi_scores(f_a, f_b, cand, x_a, x_b), present)
            gscore = eval_multimodal(f_a, f_b, self.global_models["g_M"],
                                     x_a, x_b, val.y, ecfg, kind, metric)
        # Weighted-strategy M-head weights: paired counts per client, the
        # server head carrying the actual VFL overlap size — zero when no
        # rows overlap (no silent floor; all-zero weights keep the
        # previous global model). Scaffold blends present heads uniformly
        # (the server slot present iff any rows overlap).
        ns = None
        if not blend:
            if scfg.control:
                ns = [1 if cd.has_paired else 0 for cd in sub_clients]
                ns.append(1 if self.data["n_overlap"] else 0)
            else:
                ns = [len(cd.paired_a) if cd.has_paired else 0
                      for cd in sub_clients]
                ns.append(self.data["n_overlap"])
        stale_m = None if stale is None else np.append(stale, 0.0)
        blended, omega = self._blend_group(self.global_models["g_M"], cand,
                                           scores, gscore, ns, staleness=stale_m)
        info["omega_M"] = omega
        self.global_models["g_M"] = blended

        # server-side optimizer on the blended delta, before anything is
        # broadcast — clients (and the downlink codec) see the adjusted
        # global, and the server's g_M^v re-seeds from it
        if scfg.server_opt != "none":
            glob = {k: self.global_models[k] for k in CLIENT_GROUPS}
            glob, self.strat_state["srv"] = self.engine.server_update(
                self.strat_state["srv"], glob, prev_glob)
            self.global_models.update(glob)
        # the server's split-training head re-seeds from the TRUE blend
        # (it never crosses a wire), codec or not
        gmv_true = self.global_models["g_M"]

        # wire codec, downlink leg: what the clients adopt is the blend
        # as decoded from the broadcast delta vs. the global they held
        if codec_on:
            glob = {k: self.global_models[k] for k in CLIENT_GROUPS}
            glob, self.resid_down = self.engine.codec_downlink(
                glob, prev_glob, self.resid_down)
            self.global_models.update(glob)

        # LocalUpdate: broadcast blended models back (line 32). Clients keep
        # their optimizer moments; only the weights are replaced. Async
        # rounds broadcast to the participants only — stragglers keep their
        # stale weights until they are next sampled.
        glob_groups = {k: self.global_models[k] for k in CLIENT_GROUPS}
        if idx is not None and cfg.async_mode:
            self.stacked = dict(rstate.scatter_block(
                "models", self.stacked, fns.broadcast(glob_groups, len(idx)),
                idx))
            self.last_round[np.asarray(idx)] = self.round_no
        else:
            self.stacked = dict(fns.broadcast(glob_groups, cfg.n_clients))
            self.last_round[:] = self.round_no
        self.server_gmv = jax.tree.map(jnp.asarray, gmv_true)

        # scheduler telemetry: fold this round's per-client omega (mean
        # over the heads that competed; omega_M's server slot excluded)
        # into the EMA at the participants' slots, count participation
        heads = [np.asarray(info[k], np.float64)
                 for k in ("omega_A", "omega_B") if k in info]
        heads.append(np.asarray(info["omega_M"], np.float64)[: len(sub_clients)])
        cli_omega = np.mean(np.stack(heads), axis=0)
        sel = np.arange(cfg.n_clients) if idx is None else np.asarray(idx)
        b = cfg.ema_beta
        self.omega_ema[sel] = b * self.omega_ema[sel] + (1 - b) * cli_omega
        self.part_count[sel] += 1
        return info

    def _scaffold_update(self, anchor, trained, idxd=None):
        """SCAFFOLD Option-II control-variate update on the TRUE trained
        weights (before any lossy uplink codec touches the candidates).
        Participants' c_local rows move by (anchor - trained)/(steps*lr);
        c_global absorbs the K/C-weighted mean shift."""
        scfg = self.engine.cfg.strategy
        if not scfg.control:
            return
        st = self.strat_state
        cl = strategies.sample_state(st, idxd)["c_local"]
        k = self.cfg.n_clients if idxd is None else int(idxd.shape[0])
        new_cg, new_cl = self.engine.scaffold_round(
            st["c_global"], cl, anchor, trained, self.scaffold_steps,
            k / self.cfg.n_clients)
        self.strat_state = strategies.scatter_state(
            st, {**st, "c_global": new_cg, "c_local": new_cl}, idxd)

    # ---- K-of-C sampled round ----

    def _sampled_vfl_batch(self, idx: np.ndarray):
        """Remap the precomputed VFL alignment onto the gathered K-client
        layout. The aligned row count stays STATIC — rows whose a- or
        b-side owner was not sampled keep their slot with row weight 0
        (and a harmless index 0), so the phase never retraces across
        subsets. Returns None when no aligned row survives."""
        if self.data["vfl"] is None:
            return None
        host, full = self.data["vfl_host"], self.data["vfl"]
        nfa, nfb = host["nfa"], host["nfb"]
        ga, gb = host["gather_a"], host["gather_b"]
        k = len(idx)
        pos = np.full(self.cfg.n_clients, -1)
        pos[idx] = np.arange(k)
        oa, ob = ga // nfa, gb // nfb
        keep = (pos[oa] >= 0) & (pos[ob] >= 0)
        if not keep.any():
            return None
        return {
            "xa": sample_clients(full["xa"], idx),
            "xb": sample_clients(full["xb"], idx),
            "gather_a": jnp.asarray(np.where(keep, pos[oa] * nfa + ga % nfa, 0),
                                    jnp.int32),
            "gather_b": jnp.asarray(np.where(keep, pos[ob] * nfb + gb % nfb, 0),
                                    jnp.int32),
            "y": full["y"],
            "w": jnp.asarray(keep.astype(np.float32)),
            "part_a": jnp.asarray(np.bincount(pos[oa[keep]], minlength=k) > 0),
            "part_b": jnp.asarray(np.bincount(pos[ob[keep]], minlength=k) > 0),
        }

    def _sched_telemetry(self) -> dict:
        """What the participation policy sees (``repro.core.schedule``
        telemetry contract): round index, the sched block (omega EMA,
        participation counts, last_round), and static data volumes."""
        return {"round": self.round_no, "last_round": self.last_round,
                "omega_ema": self.omega_ema, "part_count": self.part_count,
                "rows": np.asarray([cd.n_samples() for cd in self.clients],
                                   np.float64)}

    def _sampled_round(self) -> dict:
        """Partial-participation round: the policy picks the K ids from
        the sched telemetry, then the round gathers those clients' stacked
        rows, runs the same compiled phase programs at leading axis K,
        scatters optimizer state back, and aggregates over the K
        candidates. The sampled indices are data — fixed K means no
        retraces, whatever the policy. ``policy="uniform"`` consumes the
        host_rng identically to the pre-scheduler code (bit-exact)."""
        idx = self.policy_obj.select(self.host_rng, self._sched_telemetry())
        idxd = jnp.asarray(idx, jnp.int32)
        sub = rstate.sample_block("models", self.stacked, idxd)
        # codec uplink base AND strategy anchor: the weights each
        # participant starts the round from
        base = sub
        strat = self._strat_block(base, idxd)
        sub_opt = rstate.sample_block("opt", self.opt_state, idxd)
        uni = sample_clients(self.data["uni"], idxd)
        paired = (sample_clients(self.data["paired"], idxd)
                  if self.data["paired"] is not None else None)
        vfl_batch = self._sampled_vfl_batch(idx)

        logs = {"sampled": idx}
        for _ in range(self.cfg.local_epochs):
            sub, sub_opt, loss = self.engine.unimodal_phase(
                sub, sub_opt, uni, self._next_key(), strat)
            logs["loss_partial"] = float(loss)
            if vfl_batch is not None:
                (sub, self.server_gmv, sub_opt, self.srv_opt_state,
                 loss) = self.engine.vfl_phase(sub, self.server_gmv, sub_opt,
                                               self.srv_opt_state, vfl_batch,
                                               strat)
                logs["loss_vfl"] = float(loss)
            else:
                logs["loss_vfl"] = float("nan")
            if paired is not None:
                sub, sub_opt, loss = self.engine.paired_phase(
                    sub, sub_opt, paired, self._next_key(), strat)
                logs["loss_paired"] = float(loss)
            else:
                logs["loss_paired"] = float("nan")
        # moments ride home with their clients; the trained weights only
        # matter as aggregation candidates (broadcast decides what sticks)
        self.opt_state = rstate.scatter_block("opt", self.opt_state, sub_opt,
                                              idxd)
        self._scaffold_update(base, sub, idxd)
        logs.update(self._aggregate(cand_stacked=sub, idx=idx, base=base))
        return logs

    # ---- round / fit ----

    def round(self) -> dict:
        """One global training epoch (Algorithm 1 body; the K-of-C sampled
        variant when ``cfg.n_sampled`` is set)."""
        if self.cfg.n_sampled:
            logs = self._sampled_round()
            self.round_no += 1
            return logs
        logs = {}
        # codec uplink base AND strategy anchor (pre-round weights)
        base = self.stacked
        strat = self._strat_block(base)
        for _ in range(self.cfg.local_epochs):
            logs["loss_partial"] = self._unimodal_phase(strat)
            logs["loss_vfl"] = self._vfl_phase(strat)
            logs["loss_paired"] = self._paired_phase(strat)
        self._scaffold_update(base, self.stacked)
        logs.update(self._aggregate(base=base))
        self.round_no += 1
        return logs

    def fit(self, eval_every: int = 0, eval_fn: Callable | None = None) -> list[dict]:
        history = []
        for r in range(self.cfg.rounds):
            logs = self.round()
            logs["round"] = r
            if eval_every and eval_fn and (r + 1) % eval_every == 0:
                logs.update(eval_fn(self))
            history.append(logs)
        return history


def evaluate_global(fed: Federation, test: SyntheticMultimodal) -> dict:
    """Paper-style test metrics of the blended global models: multimodal +
    both unimodal heads, AUROC and AUPRC."""
    g, ecfg, kind = fed.global_models, fed.ecfg, fed.spec.kind
    out = {}
    for metric in ("auroc", "auprc"):
        out[f"multimodal_{metric}"] = eval_multimodal(
            g["f_A"], g["f_B"], g["g_M"], test.x_a, test.x_b, test.y, ecfg, kind, metric)
        out[f"uni_a_{metric}"] = eval_unimodal(
            g["f_A"], g["g_A"], test.x_a, test.y, ecfg, kind, metric)
        out[f"uni_b_{metric}"] = eval_unimodal(
            g["f_B"], g["g_B"], test.x_b, test.y, ecfg, kind, metric)
    return out
