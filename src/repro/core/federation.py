"""BlendFL federation — Algorithm 1, orchestrated over in-host clients.

One ``blendfl_round`` is the paper's training epoch:

    1. local unimodal training on *partial* data        (lines 3-8)
    2. split (VFL) training on *fragmented* data        (lines 9-23)
    3. local multimodal training on *paired* data       (lines 24-29)
    4. BlendAvg aggregation + broadcast                 (lines 30-32)

Clients are plain Python objects holding model pytrees; every numeric
step is jitted. The TPU-sharded expression of the same round (clients =
mesh slices, aggregation = masked psum) lives in federation_sharded.py
and is what the multi-pod dry-run lowers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import vfl
from repro.core.blendavg import blendavg, fedavg
from repro.core.encoders import (
    EncoderConfig,
    encoder_apply,
    fusion_apply,
    init_client_models,
    task_loss,
    task_scores,
)
from repro.core.partitioner import ClientData, ModalView
from repro.data.synthetic import SyntheticMultimodal, TaskSpec
from repro.metrics import auprc, auroc


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_clients: int = 3
    rounds: int = 20
    local_epochs: int = 1  # local passes between aggregations (Fig. 2 x-axis)
    batch_size: int = 64
    lr: float = 1e-3
    aggregator: str = "blendavg"  # blendavg | fedavg
    # Which local rows feed phase-1 unimodal training. "all" (default)
    # reads Alg. 1's "partial data" as "the unimodal portions of D_m" —
    # every locally held x_m row (partial + fragmented + paired), matching
    # the paper's claim that BlendFL "leverages all data available at the
    # clients". "strict" uses only the partial(D_m) subset (the literal
    # line-4 reading); both are benchmarked in EXPERIMENTS.md.
    unimodal_data: str = "all"  # all | partial
    metric: str = "auroc"
    seed: int = 0


# ------------------------------------------------------------ jitted steps --

@functools.partial(jax.jit, static_argnames=("ecfg", "kind", "lr", "modality"))
def _unimodal_sgd_step(f, g, x, y, *, ecfg, kind, lr, modality):
    del modality  # static arg only to keep per-modality cache entries separate

    def loss_fn(f_, g_):
        h = encoder_apply(f_, x, ecfg)
        from repro.models.common import dense

        return task_loss(dense(g_, h), y, kind)

    loss, (gf, gg) = jax.value_and_grad(loss_fn, argnums=(0, 1))(f, g)
    f = jax.tree.map(lambda p, gr: p - lr * gr, f, gf)
    g = jax.tree.map(lambda p, gr: p - lr * gr, g, gg)
    return f, g, loss


@functools.partial(jax.jit, static_argnames=("ecfg", "kind", "lr"))
def _paired_sgd_step(f_a, f_b, g_m, x_a, x_b, y, *, ecfg, kind, lr):
    def loss_fn(fa, fb, gm):
        h_a = encoder_apply(fa, x_a, ecfg)
        h_b = encoder_apply(fb, x_b, ecfg)
        return task_loss(fusion_apply(gm, h_a, h_b), y, kind)

    loss, (gfa, gfb, ggm) = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(f_a, f_b, g_m)
    upd = lambda p, gr: jax.tree.map(lambda a, b: a - lr * b, p, gr)
    return upd(f_a, gfa), upd(f_b, gfb), upd(g_m, ggm), loss


@functools.partial(jax.jit, static_argnames=("ecfg",))
def _client_fwd(f, x, *, ecfg):
    return encoder_apply(f, x, ecfg)


@functools.partial(jax.jit, static_argnames=("kind",))
def _server_fwd_bwd(gmv, h_a, h_b, y, *, kind):
    return vfl.server_forward_backward(gmv, h_a, h_b, y, kind)


@functools.partial(jax.jit, static_argnames=("ecfg", "lr"))
def _client_bwd_update(f, x, h_grad, *, ecfg, lr):
    g_enc = vfl.client_backward(f, x, h_grad, ecfg)
    return jax.tree.map(lambda p, gr: p - lr * gr, f, g_enc)


# ------------------------------------------------------------- evaluation --

def _metric_fn(name: str) -> Callable:
    return {"auroc": auroc, "auprc": auprc}[name]


def eval_unimodal(f, g, x, y, ecfg: EncoderConfig, kind: str, metric: str = "auroc"):
    from repro.models.common import dense

    h = _client_fwd(f, jnp.asarray(x), ecfg=ecfg)
    scores = task_scores(dense(g, h), kind)
    return float(_metric_fn(metric)(np.asarray(y), np.asarray(scores)))


def eval_multimodal(f_a, f_b, g_m, x_a, x_b, y, ecfg: EncoderConfig, kind: str,
                    metric: str = "auroc"):
    h_a = _client_fwd(f_a, jnp.asarray(x_a), ecfg=ecfg)
    h_b = _client_fwd(f_b, jnp.asarray(x_b), ecfg=ecfg)
    scores = task_scores(fusion_apply(g_m, h_a, h_b), kind)
    return float(_metric_fn(metric)(np.asarray(y), np.asarray(scores)))


# -------------------------------------------------------------- federation --

@dataclasses.dataclass
class Federation:
    """Mutable federation state: N clients + the BlendFL server."""

    cfg: FedConfig
    spec: TaskSpec
    ecfg: EncoderConfig
    clients: list  # list[ClientData]
    models: list  # per-client {f_A, f_B, g_A, g_B, g_M}
    global_models: dict  # blended {f_A, f_B, g_A, g_B, g_M}
    server_gmv: dict  # g_M^v split-training head at the server
    val: SyntheticMultimodal  # server-side representative validation set
    rng: np.random.Generator

    @staticmethod
    def init(key, cfg: FedConfig, spec: TaskSpec, ecfg: EncoderConfig,
             clients: list, val: SyntheticMultimodal) -> "Federation":
        base = init_client_models(key, spec, ecfg)
        # all clients start from the same global init (standard FL practice)
        models = [jax.tree.map(jnp.copy, base) for _ in clients]
        return Federation(
            cfg=cfg, spec=spec, ecfg=ecfg, clients=clients, models=models,
            global_models=jax.tree.map(jnp.copy, base),
            server_gmv=jax.tree.map(jnp.copy, base["g_M"]),
            val=val, rng=np.random.default_rng(cfg.seed),
        )

    # ---- phase 1: local unimodal training (partial data) ----

    def _unimodal_phase(self) -> float:
        cfg, ecfg, kind = self.cfg, self.ecfg, self.spec.kind
        losses = []
        for k, cd in enumerate(self.clients):
            for mod, view in (("A", self._uni_view(cd, "a")), ("B", self._uni_view(cd, "b"))):
                if len(view) == 0:
                    continue
                f, g = self.models[k][f"f_{mod}"], self.models[k][f"g_{mod}"]
                for x, y in self._batches(view):
                    f, g, loss = _unimodal_sgd_step(
                        f, g, x, y, ecfg=ecfg, kind=kind, lr=cfg.lr, modality=mod)
                    losses.append(float(loss))
                self.models[k][f"f_{mod}"], self.models[k][f"g_{mod}"] = f, g
        return float(np.mean(losses)) if losses else float("nan")

    def _uni_view(self, cd: ClientData, side: str) -> ModalView:
        if self.cfg.unimodal_data == "all":
            return cd.all_a() if side == "a" else cd.all_b()
        return cd.partial_a if side == "a" else cd.partial_b

    # ---- phase 2: split (VFL) training on fragmented data ----

    def _vfl_phase(self) -> float:
        """One full-batch split exchange per epoch, exactly as Alg. 1: each
        client uploads features for ALL its fragmented rows once, the server
        aligns + does one forward/backward of g_M^v, and the decoupled
        feature gradients come back in a single message. (Full-batch also
        keeps row counts static, so every jit here compiles once.)"""
        cfg, ecfg, kind = self.cfg, self.ecfg, self.spec.kind
        batches = vfl.build_vfl_batches(self.clients, 10**9, self.rng)
        losses = []
        for batch in batches:
            x_a, x_b = jnp.asarray(batch.x_a), jnp.asarray(batch.x_b)
            n = len(batch.y)
            # ClientForwardPass, per owning client
            h_a = jnp.zeros((n, ecfg.d_hidden), jnp.float32)
            h_b = jnp.zeros((n, ecfg.d_hidden), jnp.float32)
            for k in range(cfg.n_clients):
                ra = np.nonzero(batch.owner_a == k)[0]
                rb = np.nonzero(batch.owner_b == k)[0]
                if len(ra):
                    h_a = h_a.at[ra].set(_client_fwd(self.models[k]["f_A"], x_a[ra], ecfg=ecfg))
                if len(rb):
                    h_b = h_b.at[rb].set(_client_fwd(self.models[k]["f_B"], x_b[rb], ecfg=ecfg))
            # ServerForward/BackwardPass on the aligned features
            loss, g_srv, g_ha, g_hb = _server_fwd_bwd(
                self.server_gmv, h_a, h_b, jnp.asarray(batch.y), kind=kind)
            self.server_gmv = jax.tree.map(
                lambda p, gr: p - cfg.lr * gr, self.server_gmv, g_srv)
            # ServerSendGradientsToClients -> client encoder updates
            for k in range(cfg.n_clients):
                ra = np.nonzero(batch.owner_a == k)[0]
                rb = np.nonzero(batch.owner_b == k)[0]
                if len(ra):
                    self.models[k]["f_A"] = _client_bwd_update(
                        self.models[k]["f_A"], x_a[ra], g_ha[ra], ecfg=ecfg, lr=cfg.lr)
                if len(rb):
                    self.models[k]["f_B"] = _client_bwd_update(
                        self.models[k]["f_B"], x_b[rb], g_hb[rb], ecfg=ecfg, lr=cfg.lr)
            losses.append(float(loss))
        return float(np.mean(losses)) if losses else float("nan")

    # ---- phase 3: local multimodal training on paired data ----

    def _paired_phase(self) -> float:
        cfg, ecfg, kind = self.cfg, self.ecfg, self.spec.kind
        losses = []
        for k, cd in enumerate(self.clients):
            if not cd.has_paired:
                continue
            m = self.models[k]
            f_a, f_b, g_m = m["f_A"], m["f_B"], m["g_M"]
            for (x_a, x_b, y) in self._paired_batches(cd):
                f_a, f_b, g_m, loss = _paired_sgd_step(
                    f_a, f_b, g_m, x_a, x_b, y, ecfg=ecfg, kind=kind, lr=cfg.lr)
                losses.append(float(loss))
            m["f_A"], m["f_B"], m["g_M"] = f_a, f_b, g_m
        return float(np.mean(losses)) if losses else float("nan")

    # ---- phase 4: aggregation + broadcast ----

    def _aggregate(self) -> dict:
        cfg, ecfg, kind, metric = self.cfg, self.ecfg, self.spec.kind, self.cfg.metric
        val = self.val
        info = {}

        def agg_unimodal(mod: str, x_val):
            has = [k for k, cd in enumerate(self.clients)
                   if (cd.has_a if mod == "A" else cd.has_b)]
            if not has:
                return
            cands = [{"f": self.models[k][f"f_{mod}"], "g": self.models[k][f"g_{mod}"]}
                     for k in has]
            glob = {"f": self.global_models[f"f_{mod}"], "g": self.global_models[f"g_{mod}"]}
            ev = lambda m: eval_unimodal(m["f"], m["g"], x_val, val.y, ecfg, kind, metric)
            if cfg.aggregator == "blendavg":
                blended, inf = blendavg(glob, cands, ev)
                info[f"omega_{mod}"] = inf["omega"]
            else:
                ns = [self.clients[k].n_samples() for k in has]
                blended = fedavg(cands, ns)
            self.global_models[f"f_{mod}"] = blended["f"]
            self.global_models[f"g_{mod}"] = blended["g"]

        agg_unimodal("A", val.x_a)
        agg_unimodal("B", val.x_b)

        # multimodal: local g_M^k (paired clients) + the server's g_M^v (Eq. 8)
        has_m = [k for k, cd in enumerate(self.clients) if cd.has_paired]
        cands = [self.models[k]["g_M"] for k in has_m] + [self.server_gmv]
        f_a, f_b = self.global_models["f_A"], self.global_models["f_B"]
        ev = lambda gm: eval_multimodal(f_a, f_b, gm, val.x_a, val.x_b, val.y,
                                        ecfg, kind, metric)
        if cfg.aggregator == "blendavg":
            blended, inf = blendavg(self.global_models["g_M"], cands, ev)
            info["omega_M"] = inf["omega"]
        else:
            from repro.core.partitioner import fragmented_overlap

            ns = [len(self.clients[k].paired_a) for k in has_m]
            ns.append(max(1, len(fragmented_overlap(self.clients))))
            blended = fedavg(cands, ns)
        self.global_models["g_M"] = blended

        # LocalUpdate: broadcast blended models back (line 32)
        for k in range(cfg.n_clients):
            for grp in ("f_A", "g_A", "f_B", "g_B", "g_M"):
                self.models[k][grp] = jax.tree.map(jnp.copy, self.global_models[grp])
        self.server_gmv = jax.tree.map(jnp.copy, self.global_models["g_M"])
        return info

    # ---- round / fit ----

    def round(self) -> dict:
        """One global training epoch (Algorithm 1 body)."""
        logs = {}
        for _ in range(self.cfg.local_epochs):
            logs["loss_partial"] = self._unimodal_phase()
            logs["loss_vfl"] = self._vfl_phase()
            logs["loss_paired"] = self._paired_phase()
        logs.update(self._aggregate())
        return logs

    def fit(self, eval_every: int = 0, eval_fn: Callable | None = None) -> list[dict]:
        history = []
        for r in range(self.cfg.rounds):
            logs = self.round()
            logs["round"] = r
            if eval_every and eval_fn and (r + 1) % eval_every == 0:
                logs.update(eval_fn(self))
            history.append(logs)
        return history

    # ---- helpers ----

    def _batches(self, view: ModalView):
        idx = self.rng.permutation(len(view))
        bs = self.cfg.batch_size
        for i in range(0, len(idx), bs):
            sel = idx[i : i + bs]
            yield jnp.asarray(view.x[sel]), jnp.asarray(view.y[sel])

    def _paired_batches(self, cd: ClientData):
        n = len(cd.paired_a)
        idx = self.rng.permutation(n)
        bs = self.cfg.batch_size
        for i in range(0, n, bs):
            sel = idx[i : i + bs]
            yield (jnp.asarray(cd.paired_a.x[sel]), jnp.asarray(cd.paired_b.x[sel]),
                   jnp.asarray(cd.paired_a.y[sel]))


def evaluate_global(fed: Federation, test: SyntheticMultimodal) -> dict:
    """Paper-style test metrics of the blended global models: multimodal +
    both unimodal heads, AUROC and AUPRC."""
    g, ecfg, kind = fed.global_models, fed.ecfg, fed.spec.kind
    out = {}
    for metric in ("auroc", "auprc"):
        out[f"multimodal_{metric}"] = eval_multimodal(
            g["f_A"], g["f_B"], g["g_M"], test.x_a, test.x_b, test.y, ecfg, kind, metric)
        out[f"uni_a_{metric}"] = eval_unimodal(
            g["f_A"], g["g_A"], test.x_a, test.y, ecfg, kind, metric)
        out[f"uni_b_{metric}"] = eval_unimodal(
            g["f_B"], g["g_B"], test.x_b, test.y, ecfg, kind, metric)
    return out
