"""Decentralized inference (paper contribution #2) — the typed request API.

After BlendFL training every client holds the blended ``f_A, f_B, g_A,
g_B, g_M`` — so it can serve predictions with whatever modalities a local
sample has, with ZERO server round-trips:

    both modalities present  -> g_M(f_A(x_A), f_B(x_B))     Route.MULTIMODAL
    only A                   -> g_A(f_A(x_A))               Route.UNIMODAL_A
    only B                   -> g_B(f_B(x_B))               Route.UNIMODAL_B

``Route.VFL_FALLBACK`` is the conventional-VFL comparison path (SplitNN
style): features go up to the server head ``g_M^v``, predictions come
down — per-request network messages, and unavailable when the peer
holding the other modality is offline. A request opts into it with
``InferenceRequest(vfl=True)`` (it models a client that holds encoders
but no blended heads).

``predict`` is the single typed entry point: it routes the request,
runs the forward through a per-(route, shape) compiled program, and
returns a ``PredictResult`` carrying the scores, the chosen ``Route``,
and the network cost (messages / bytes) the exchange incurred. The VFL
route prices — and, when a codec is given, lossily round-trips — its
feature/score messages through ``repro.core.codec``, one wire message
per sample row (the same per-row message convention as the training
codec's ``encode_decode_stacked``).

``local_predict`` / ``vfl_server_inference`` are the pre-``predict``
surface, kept as thin deprecated wrappers. The batched many-request
engine over the same forward path is ``repro.core.serving``.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec as wire
from repro.core.encoders import EncoderConfig, encoder_apply, fusion_apply, task_scores
from repro.models.common import dense


class Route(enum.Enum):
    """How a request is served, chosen from its available modalities."""

    MULTIMODAL = "multimodal"
    UNIMODAL_A = "unimodal_A"
    UNIMODAL_B = "unimodal_B"
    VFL_FALLBACK = "vfl_fallback"


# deterministic ordering for engines that bucket requests by route
ROUTES = (Route.MULTIMODAL, Route.UNIMODAL_A, Route.UNIMODAL_B,
          Route.VFL_FALLBACK)


@dataclasses.dataclass
class InferenceRequest:
    x_a: np.ndarray | None  # (B, S_a, F_a) or None if modality missing
    x_b: np.ndarray | None
    # vfl=True asks for conventional server-mediated (SplitNN) serving —
    # the fallback for a client that holds no blended heads. Needs both
    # modalities and a live server head.
    vfl: bool = False


@dataclasses.dataclass
class PredictResult:
    """One served request: scores plus how (and at what cost) it ran.

    ``messages``/``bytes`` are the network cost of THIS request served
    alone (0 for the local routes; 2 feature uploads + 1 score download
    for ``VFL_FALLBACK``, priced per sample row through the wire codec).
    """

    scores: jnp.ndarray  # (B, out_dim) probability scores
    route: Route
    messages: int
    bytes: int


def request_rows(req: InferenceRequest) -> int:
    """Sample rows a request carries (its present modalities must agree)."""
    na = None if req.x_a is None else len(req.x_a)
    nb = None if req.x_b is None else len(req.x_b)
    if na is not None and nb is not None and na != nb:
        raise ValueError(f"request modalities disagree on rows: x_a has "
                         f"{na}, x_b has {nb}")
    n = na if na is not None else nb
    if n is None:
        raise ValueError("request carries no modality")
    return n


def route_for(req: InferenceRequest) -> Route:
    """Route selection: VFL when asked for (and possible), else local by
    modality presence. Raises ``ValueError`` on an unservable request."""
    request_rows(req)  # raises on the no-modality / ragged cases
    if req.vfl:
        if req.x_a is None or req.x_b is None:
            raise ValueError(
                "VFL serving needs both parties: the server head fuses "
                "h_A and h_B, so a request missing a modality can only be "
                "served by the decentralized unimodal routes")
        return Route.VFL_FALLBACK
    if req.x_a is not None and req.x_b is not None:
        return Route.MULTIMODAL
    return Route.UNIMODAL_A if req.x_a is not None else Route.UNIMODAL_B


def route_scores(models: dict, route: Route, x_a, x_b, ecfg: EncoderConfig,
                 kind: str, *, server_gmv=None, codec: wire.CodecConfig | None = None):
    """Pure forward for one route (jit-safe jnp ops only).

    This is THE forward both ``predict`` and the batched
    ``repro.core.serving`` engine trace, so a padded engine batch and a
    single-request call compile the same math and their per-row scores
    stay bit-identical. The VFL route round-trips its feature uploads
    and score download through the wire codec (per-row messages:
    ``encode_decode_stacked`` gives every sample row its own scale and
    top-k threshold, so zero-padded rows never perturb live ones).
    """
    if route is Route.MULTIMODAL:
        h_a = encoder_apply(models["f_A"], x_a, ecfg)
        h_b = encoder_apply(models["f_B"], x_b, ecfg)
        return task_scores(fusion_apply(models["g_M"], h_a, h_b), kind)
    if route is Route.UNIMODAL_A:
        return task_scores(dense(models["g_A"], encoder_apply(models["f_A"], x_a, ecfg)), kind)
    if route is Route.UNIMODAL_B:
        return task_scores(dense(models["g_B"], encoder_apply(models["f_B"], x_b, ecfg)), kind)
    if route is Route.VFL_FALLBACK:
        h_a = encoder_apply(models["f_A"], x_a, ecfg)  # feature msg up
        h_b = encoder_apply(models["f_B"], x_b, ecfg)  # feature msg up
        if codec is not None and codec.enabled:
            h_a = wire.encode_decode_stacked(h_a, codec)
            h_b = wire.encode_decode_stacked(h_b, codec)
        scores = task_scores(fusion_apply(server_gmv, h_a, h_b), kind)
        if codec is not None and codec.enabled:  # score msg down
            scores = wire.encode_decode_stacked(scores, codec)
        return scores
    raise ValueError(f"unknown route {route!r}")


# Single-sample calls execute padded to 2 rows: XLA lowers a 1-row
# batch to matrix-vector products whose reduction order differs from the
# matrix-matrix lowering every batch >= 2 shares, so batch-1 scores
# drift by an ulp from the same row served in any batch. Padding the
# lone row keeps predict bit-identical to the serving engine's
# micro-batches (whose capacity ladder floors at 2 for the same reason).
MIN_COMPILED_ROWS = 2


@functools.lru_cache(maxsize=None)
def _predict_fn(route: Route, ecfg: EncoderConfig, kind: str,
                codec: wire.CodecConfig | None):
    """One compiled program per (route, encoder config, task kind, codec)
    — compiled once per input shape. Compiling (rather than running op by
    op) is what makes single-request ``predict`` bit-identical to the
    serving engine's padded batches: XLA's fusion decisions differ
    between eager and jitted execution, while compiled per-row math is
    invariant to batch size (>= MIN_COMPILED_ROWS), padding, and row
    offset."""
    if route is Route.VFL_FALLBACK:
        def fn(models, server_gmv, x_a, x_b):
            return route_scores(models, route, x_a, x_b, ecfg, kind,
                                server_gmv=server_gmv, codec=codec)
    else:
        def fn(models, x_a, x_b):
            return route_scores(models, route, x_a, x_b, ecfg, kind)
    return jax.jit(fn)


def predict(models: dict, req: InferenceRequest, ecfg: EncoderConfig,
            kind: str, *, server_gmv: dict | None = None,
            codec: wire.CodecConfig | str | None = None) -> PredictResult:
    """Serve one request: route by available modalities, run the compiled
    forward, report the network cost.

    ``server_gmv`` (the server's split-training head) is required only
    when the request asks for ``vfl=True``. ``codec`` (a name or
    ``repro.core.codec.CodecConfig``) applies the wire codec to the VFL
    route's messages — both the lossy payload round-trip and the byte
    pricing; local routes never touch the network.
    """
    route = route_for(req)
    if isinstance(codec, str):
        codec = wire.make_codec(codec)
    n = request_rows(req)
    pad = max(0, MIN_COMPILED_ROWS - n)

    def prep(x):
        if x is None:
            return None
        x = jnp.asarray(x)
        # pad rows are sliced off below; they never mix into live rows
        # (all routes are row-parallel), so no mask is needed here
        return jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)]) if pad else x

    x_a, x_b = prep(req.x_a), prep(req.x_b)
    if route is Route.VFL_FALLBACK:
        if server_gmv is None:
            raise ValueError("VFL serving needs the server head: pass "
                             "server_gmv= (see Federation.server_gmv)")
        fn = _predict_fn(route, ecfg, kind, codec)
        scores = fn(models, server_gmv, x_a, x_b)[:n]
        cost = communication_cost(n, ecfg.d_hidden, "vfl",
                                  int(scores.shape[-1]), codec=codec)
        return PredictResult(scores, route, cost["messages"], cost["bytes"])
    fn = _predict_fn(route, ecfg, kind, None)
    scores = fn(models, x_a, x_b)[:n]
    return PredictResult(scores, route, 0, 0)


def communication_cost(batch: int, d_hidden: int, mode: str, out_dim: int,
                       *, dtype_bytes: int = 4, codec=None) -> dict:
    """Analytic bytes over the network per inference batch.

    decentralized: 0 — the blended models are local.
    vfl: two feature uploads + one score download per batch, each sample
    row its own wire message (per-row scale/indices under a lossy codec
    — the same convention as ``codec.encode_decode_stacked``, and what
    the serving engine's measured byte counts reconcile against):

        bytes = batch * (2 * row_bytes(d_hidden) + row_bytes(out_dim))

    ``dtype_bytes`` sizes a dense payload value (4 = fp32 default, 2 =
    bf16 activations); ``codec`` (a ``repro.core.codec.CodecConfig`` or
    codec name) prices each row through the wire codec's format instead,
    so codec savings show up in the decentralized-inference gap
    quantity, not just in training rounds.
    """
    if mode == "decentralized":
        return {"messages": 0, "bytes": 0}
    if isinstance(codec, str):
        codec = wire.make_codec(codec)
    if codec is None:
        codec = wire.CodecConfig()  # "none": dense dtype_bytes payloads
    feat_bytes = 2 * batch * wire.leaf_payload_bytes(d_hidden, codec,
                                                     dtype_bytes)
    score_bytes = batch * wire.leaf_payload_bytes(out_dim, codec, dtype_bytes)
    return {"messages": 3, "bytes": feat_bytes + score_bytes}


# ------------------------------------------------- deprecated wrappers -----

def local_predict(models: dict, req: InferenceRequest, ecfg: EncoderConfig, kind: str):
    """Deprecated: use ``predict`` (returns a typed ``PredictResult``)."""
    warnings.warn(
        "local_predict is deprecated: use repro.core.inference.predict, "
        "which returns a PredictResult (scores / Route / messages / bytes)",
        DeprecationWarning, stacklevel=2)
    res = predict(models, dataclasses.replace(req, vfl=False), ecfg, kind)
    return res.scores, res.route.value


def vfl_server_inference(client_models: dict, server_gmv: dict, req: InferenceRequest,
                         ecfg: EncoderConfig, kind: str):
    """Deprecated: use ``predict(..., server_gmv=...)`` on a request with
    ``vfl=True``."""
    warnings.warn(
        "vfl_server_inference is deprecated: use repro.core.inference."
        "predict with InferenceRequest(vfl=True) and server_gmv=",
        DeprecationWarning, stacklevel=2)
    res = predict(client_models, dataclasses.replace(req, vfl=True), ecfg,
                  kind, server_gmv=server_gmv)
    return res.scores, res.messages
