"""Decentralized inference (paper contribution #2).

After BlendFL training every client holds the blended ``f_A, f_B, g_A,
g_B, g_M`` — so it can serve predictions with whatever modalities a local
sample has, with ZERO server round-trips:

    both modalities present  -> g_M(f_A(x_A), f_B(x_B))
    only A                   -> g_A(f_A(x_A))
    only B                   -> g_B(f_B(x_B))

``vfl_server_inference`` is the conventional-VFL comparison path (SplitNN
style): features go up, predictions come down — 2 network messages per
request, and unavailable when the peer holding the other modality is
offline. ``communication_cost`` quantifies the gap for the benchmark.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import codec as wire
from repro.core.encoders import EncoderConfig, encoder_apply, fusion_apply, task_scores
from repro.models.common import dense


@dataclasses.dataclass
class InferenceRequest:
    x_a: np.ndarray | None  # (B, S_a, F_a) or None if modality missing
    x_b: np.ndarray | None


def local_predict(models: dict, req: InferenceRequest, ecfg: EncoderConfig, kind: str):
    """Decentralized inference on a client's own blended models."""
    if req.x_a is not None and req.x_b is not None:
        h_a = encoder_apply(models["f_A"], jnp.asarray(req.x_a), ecfg)
        h_b = encoder_apply(models["f_B"], jnp.asarray(req.x_b), ecfg)
        return task_scores(fusion_apply(models["g_M"], h_a, h_b), kind), "multimodal"
    if req.x_a is not None:
        h = encoder_apply(models["f_A"], jnp.asarray(req.x_a), ecfg)
        return task_scores(dense(models["g_A"], h), kind), "unimodal_A"
    if req.x_b is not None:
        h = encoder_apply(models["f_B"], jnp.asarray(req.x_b), ecfg)
        return task_scores(dense(models["g_B"], h), kind), "unimodal_B"
    raise ValueError("request carries no modality")


def vfl_server_inference(client_models: dict, server_gmv: dict, req: InferenceRequest,
                         ecfg: EncoderConfig, kind: str):
    """Conventional-VFL serving: client(s) push latent features to the
    server, the server head predicts. Requires both modalities and a live
    server — the baseline BlendFL's decentralized path removes."""
    assert req.x_a is not None and req.x_b is not None, "VFL serving needs both parties"
    h_a = encoder_apply(client_models["f_A"], jnp.asarray(req.x_a), ecfg)  # msg 1 up
    h_b = encoder_apply(client_models["f_B"], jnp.asarray(req.x_b), ecfg)  # msg 2 up
    return task_scores(fusion_apply(server_gmv, h_a, h_b), kind), 3  # 2 up + 1 down


def communication_cost(batch: int, d_hidden: int, mode: str, out_dim: int,
                       *, dtype_bytes: int = 4, codec=None) -> dict:
    """Bytes over the network per inference batch.

    decentralized: 0 — the blended models are local.
    vfl: two feature uploads (batch * d_hidden values each) + one score
    download (batch * out_dim values) per batch — all 3 messages the
    ``vfl_server_inference`` exchange reports are counted.

    ``dtype_bytes`` sizes a dense payload value (4 = fp32 default, 2 =
    bf16 activations); ``codec`` (a ``repro.core.codec.CodecConfig`` or
    codec name) prices each message through the wire codec's format
    instead, so codec savings show up in the decentralized-inference gap
    quantity, not just in training rounds.
    """
    if mode == "decentralized":
        return {"messages": 0, "bytes": 0}
    if isinstance(codec, str):
        codec = wire.make_codec(codec)
    if codec is None:
        codec = wire.CodecConfig()  # "none": dense dtype_bytes payloads
    feat_bytes = 2 * wire.leaf_payload_bytes(batch * d_hidden, codec,
                                             dtype_bytes)
    score_bytes = wire.leaf_payload_bytes(batch * out_dim, codec, dtype_bytes)
    return {"messages": 3, "bytes": feat_bytes + score_bytes}
