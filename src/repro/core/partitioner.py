"""Data fragmentation across federated clients (paper §III-A).

Every global sample is assigned one of the paper's three patient types:

- ``paired``     both modalities collected at ONE client,
- ``fragmented`` modality A at one client, modality B at a DIFFERENT client
                 (same global sample id — the VFL overlap set),
- ``partial``    exactly one modality exists anywhere (never collected).

``partition`` returns one :class:`ClientData` per client, each holding the
per-modality views plus the id arrays the server uses for VFL alignment.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.data.synthetic import SyntheticMultimodal


@dataclasses.dataclass
class ModalView:
    """One client's view of one modality: features + global ids + labels."""

    x: np.ndarray  # (n, seq, feat)
    ids: np.ndarray  # (n,) global sample ids
    y: np.ndarray  # (n, out_dim)

    def __len__(self) -> int:
        return len(self.ids)

    @staticmethod
    def empty(seq: int, feat: int, out_dim: int) -> "ModalView":
        return ModalView(
            np.zeros((0, seq, feat), np.float32),
            np.zeros((0,), np.int64),
            np.zeros((0, out_dim), np.float32),
        )

    @staticmethod
    def concat(views: list["ModalView"]) -> "ModalView":
        return ModalView(
            np.concatenate([v.x for v in views]),
            np.concatenate([v.ids for v in views]),
            np.concatenate([v.y for v in views]),
        )


@dataclasses.dataclass
class ClientData:
    """Local dataset of one client, split by patient type (paper Eq. 1-2)."""

    partial_a: ModalView
    partial_b: ModalView
    frag_a: ModalView
    frag_b: ModalView
    paired_a: ModalView  # paired_a.ids == paired_b.ids row-for-row
    paired_b: ModalView

    @property
    def has_a(self) -> bool:
        return len(self.partial_a) + len(self.frag_a) + len(self.paired_a) > 0

    @property
    def has_b(self) -> bool:
        return len(self.partial_b) + len(self.frag_b) + len(self.paired_b) > 0

    @property
    def has_paired(self) -> bool:
        return len(self.paired_a) > 0

    def all_a(self) -> ModalView:
        """Every modality-A sample this client holds (for unimodal training)."""
        return ModalView.concat([self.partial_a, self.frag_a, self.paired_a])

    def all_b(self) -> ModalView:
        return ModalView.concat([self.partial_b, self.frag_b, self.paired_b])

    def n_samples(self) -> int:
        return (len(self.partial_a) + len(self.partial_b) + len(self.frag_a)
                + len(self.frag_b) + len(self.paired_a))


def partition(
    data: SyntheticMultimodal,
    n_clients: int,
    *,
    frac_paired: float = 0.4,
    frac_fragmented: float = 0.3,
    frac_partial: float = 0.3,
    dirichlet_alpha: float | None = None,
    seed: int = 0,
) -> list[ClientData]:
    """Assign each global sample a patient type and client placement.

    dirichlet_alpha: if set, client placement is label-skewed — each
    class's samples are distributed over clients with probabilities drawn
    from Dirichlet(alpha) (standard non-IID FL protocol; lower alpha =
    more heterogeneity). None = uniform placement.
    """
    assert abs(frac_paired + frac_fragmented + frac_partial - 1.0) < 1e-6
    rng = np.random.default_rng(seed)
    n = len(data)
    spec = data.spec

    if dirichlet_alpha is not None and n_clients > 1:
        y = data.y
        cls = np.argmax(y, axis=1) if y.ndim == 2 and y.shape[1] > 1 else \
            y.ravel().astype(int)
        probs = rng.dirichlet([dirichlet_alpha] * n_clients,
                              size=int(cls.max()) + 1)
        client_of = np.array([rng.choice(n_clients, p=probs[c]) for c in cls])
    else:
        client_of = rng.integers(n_clients, size=n)

    perm = rng.permutation(n)
    n_pair = int(round(frac_paired * n))
    n_frag = int(round(frac_fragmented * n))
    idx_pair = perm[:n_pair]
    idx_frag = perm[n_pair : n_pair + n_frag]
    idx_part = perm[n_pair + n_frag :]

    buckets: list[dict[str, list]] = [
        {k: [] for k in ("partial_a", "partial_b", "frag_a", "frag_b", "paired")}
        for _ in range(n_clients)
    ]

    for i in idx_pair:
        buckets[client_of[i]]["paired"].append(i)
    for i in idx_frag:
        ca = int(client_of[i])
        cb = (ca + 1 + rng.integers(n_clients - 1)) % n_clients if n_clients > 1 else ca
        buckets[ca]["frag_a"].append(i)
        buckets[cb]["frag_b"].append(i)
    for i in idx_part:
        c = client_of[i]
        side = "partial_a" if rng.random() < 0.5 else "partial_b"
        buckets[c][side].append(i)

    def view_a(idx: list) -> ModalView:
        if not idx:
            return ModalView.empty(spec.seq_a, spec.feat_a, spec.out_dim)
        sel = np.asarray(idx)
        return ModalView(data.x_a[sel], data.ids[sel], data.y[sel])

    def view_b(idx: list) -> ModalView:
        if not idx:
            return ModalView.empty(spec.seq_b, spec.feat_b, spec.out_dim)
        sel = np.asarray(idx)
        return ModalView(data.x_b[sel], data.ids[sel], data.y[sel])

    clients = []
    for b in buckets:
        clients.append(
            ClientData(
                partial_a=view_a(b["partial_a"]),
                partial_b=view_b(b["partial_b"]),
                frag_a=view_a(b["frag_a"]),
                frag_b=view_b(b["frag_b"]),
                paired_a=view_a(b["paired"]),
                paired_b=view_b(b["paired"]),
            )
        )
    return clients


def fragmented_overlap(clients: list[ClientData]) -> np.ndarray:
    """Global ids present as modality A at one client AND modality B at
    another — the VFL-trainable overlap set (server-side alignment)."""
    ids_a = np.concatenate([c.frag_a.ids for c in clients]) if clients else np.zeros(0, np.int64)
    ids_b = np.concatenate([c.frag_b.ids for c in clients]) if clients else np.zeros(0, np.int64)
    return np.intersect1d(ids_a, ids_b)
