"""The paper's seven FL baselines + centralized learning (§IV-C).

Every baseline consumes the same partitioned clients and returns the same
metric dict as ``federation.evaluate_global``, so Tables I-III are
apples-to-apples. HFL baselines train local models on ALL locally held
data (fragmented rows are only usable unimodally without a VFL exchange);
VFL baselines train on the cross-client aligned sample set.

Implementation notes (documented deviations, all favorable to baselines):
- FedMA: greedy neuron matching on hidden-layer weights (the full
  Hungarian/BBP-MAP of the paper is replaced by greedy best-match, which
  is the standard light implementation); non-matchable leaves are plain
  averaged.
- One-Shot VFL: the local semi-supervised stage is supervised here (our
  synthetic clients all hold labels), followed by the single feature
  upload and server-side head training on frozen latents.
- HFCL: clients are split half/half into FL-capable and data-sharing; the
  server trains a surrogate model on the pooled shared data and joins the
  FedAvg average.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import vfl
from repro.core.blendavg import blend_trees, fedavg
from repro.core.encoders import (
    encoder_apply,
    fusion_apply,
    init_client_models,
    task_loss,
)
from repro.core.federation import (
    FedConfig,
    _client_fwd,
    eval_multimodal,
    eval_unimodal,
)
from repro.core.partitioner import ClientData, ModalView
from repro.data.synthetic import SyntheticMultimodal
from repro.models.common import dense


# Baseline-local per-client SGD steps. The BlendFL federation itself runs
# on the stacked-client engine (repro.core.engine); the baselines keep the
# simple one-client-at-a-time loop — their published forms are sequential
# and per-client, and benchmark parity is with the paper, not the engine.

@functools.partial(jax.jit, static_argnames=("ecfg", "kind", "lr", "modality"))
def _unimodal_sgd_step(f, g, x, y, *, ecfg, kind, lr, modality):
    del modality  # static arg only to keep per-modality cache entries separate

    def loss_fn(f_, g_):
        h = encoder_apply(f_, x, ecfg)
        return task_loss(dense(g_, h), y, kind)

    loss, (gf, gg) = jax.value_and_grad(loss_fn, argnums=(0, 1))(f, g)
    f = jax.tree.map(lambda p, gr: p - lr * gr, f, gf)
    g = jax.tree.map(lambda p, gr: p - lr * gr, g, gg)
    return f, g, loss


@functools.partial(jax.jit, static_argnames=("ecfg", "kind", "lr"))
def _paired_sgd_step(f_a, f_b, g_m, x_a, x_b, y, *, ecfg, kind, lr):
    def loss_fn(fa, fb, gm):
        h_a = encoder_apply(fa, x_a, ecfg)
        h_b = encoder_apply(fb, x_b, ecfg)
        return task_loss(fusion_apply(gm, h_a, h_b), y, kind)

    loss, (gfa, gfb, ggm) = jax.value_and_grad(loss_fn, argnums=(0, 1, 2))(f_a, f_b, g_m)
    upd = lambda p, gr: jax.tree.map(lambda a, b: a - lr * b, p, gr)
    return upd(f_a, gfa), upd(f_b, gfb), upd(g_m, ggm), loss


@functools.partial(jax.jit, static_argnames=("kind",))
def _server_fwd_bwd(gmv, h_a, h_b, y, *, kind):
    return vfl.server_forward_backward(gmv, h_a, h_b, y, kind)


@functools.partial(jax.jit, static_argnames=("ecfg", "lr"))
def _client_bwd_update(f, x, h_grad, *, ecfg, lr):
    g_enc = vfl.client_backward(f, x, h_grad, ecfg)
    return jax.tree.map(lambda p, gr: p - lr * gr, f, g_enc)


def _evaluate(models: dict, test: SyntheticMultimodal, ecfg, kind) -> dict:
    out = {}
    for metric in ("auroc", "auprc"):
        out[f"multimodal_{metric}"] = eval_multimodal(
            models["f_A"], models["f_B"], models["g_M"],
            test.x_a, test.x_b, test.y, ecfg, kind, metric)
        out[f"uni_a_{metric}"] = eval_unimodal(
            models["f_A"], models["g_A"], test.x_a, test.y, ecfg, kind, metric)
        out[f"uni_b_{metric}"] = eval_unimodal(
            models["f_B"], models["g_B"], test.x_b, test.y, ecfg, kind, metric)
    return out


# ---------------------------------------------------------------- helpers --

def _batches(view: ModalView, bs: int, rng):
    idx = rng.permutation(len(view))
    for i in range(0, len(idx), bs):
        sel = idx[i : i + bs]
        yield jnp.asarray(view.x[sel]), jnp.asarray(view.y[sel])


def _paired_batches(cd: ClientData, bs: int, rng):
    idx = rng.permutation(len(cd.paired_a))
    for i in range(0, len(idx), bs):
        sel = idx[i : i + bs]
        yield (jnp.asarray(cd.paired_a.x[sel]), jnp.asarray(cd.paired_b.x[sel]),
               jnp.asarray(cd.paired_a.y[sel]))


@functools.partial(jax.jit, static_argnames=("ecfg", "kind", "lr", "modality", "mu"))
def _unimodal_prox_step(f, g, x, y, f0, g0, *, ecfg, kind, lr, modality, mu):
    """FedProx local step: + mu/2 ||w - w_global||^2."""
    del modality

    def loss_fn(f_, g_):
        h = encoder_apply(f_, x, ecfg)
        base = task_loss(dense(g_, h), y, kind)
        sq = lambda t, t0: sum(jnp.sum(jnp.square(a - b)) for a, b in
                               zip(jax.tree.leaves(t), jax.tree.leaves(t0)))
        return base + 0.5 * mu * (sq(f_, f0) + sq(g_, g0))

    loss, (gf, gg) = jax.value_and_grad(loss_fn, argnums=(0, 1))(f, g)
    f = jax.tree.map(lambda p, gr: p - lr * gr, f, gf)
    g = jax.tree.map(lambda p, gr: p - lr * gr, g, gg)
    return f, g, loss


def _local_train(models: dict, cd: ClientData, ecfg, kind, lr, bs, epochs, rng,
                 prox_mu: float = 0.0, global_ref: dict | None = None) -> int:
    """Local training on all local data (HFL client). Returns #local steps."""
    steps = 0
    for _ in range(epochs):
        for mod, view in (("A", cd.all_a()), ("B", cd.all_b())):
            if len(view) == 0:
                continue
            f, g = models[f"f_{mod}"], models[f"g_{mod}"]
            for x, y in _batches(view, bs, rng):
                if prox_mu > 0:
                    f, g, _ = _unimodal_prox_step(
                        f, g, x, y, global_ref[f"f_{mod}"], global_ref[f"g_{mod}"],
                        ecfg=ecfg, kind=kind, lr=lr, modality=mod, mu=prox_mu)
                else:
                    f, g, _ = _unimodal_sgd_step(f, g, x, y, ecfg=ecfg, kind=kind,
                                                 lr=lr, modality=mod)
                steps += 1
            models[f"f_{mod}"], models[f"g_{mod}"] = f, g
        if cd.has_paired:
            f_a, f_b, g_m = models["f_A"], models["f_B"], models["g_M"]
            for x_a, x_b, y in _paired_batches(cd, bs, rng):
                f_a, f_b, g_m, _ = _paired_sgd_step(f_a, f_b, g_m, x_a, x_b, y,
                                                    ecfg=ecfg, kind=kind, lr=lr)
                steps += 1
            models["f_A"], models["f_B"], models["g_M"] = f_a, f_b, g_m
    return steps


# --------------------------------------------------------------- HFL core --

def _hfl_train(key, spec, ecfg, clients, test, cfg: FedConfig, *,
               aggregate, prox_mu: float = 0.0, track_steps: bool = False,
               history_test=None):
    """Shared HFL loop: local train -> aggregate(weights, n_samples, taus)."""
    base = init_client_models(key, spec, ecfg)
    global_m = jax.tree.map(jnp.copy, base)
    rng = np.random.default_rng(cfg.seed)
    kind = spec.kind
    history = []
    for r in range(cfg.rounds):
        local = [jax.tree.map(jnp.copy, global_m) for _ in clients]
        taus = []
        for k, cd in enumerate(clients):
            taus.append(_local_train(local[k], cd, ecfg, kind, cfg.lr,
                                     cfg.batch_size, cfg.local_epochs, rng,
                                     prox_mu=prox_mu, global_ref=global_m))
        global_m = aggregate(global_m, local, clients, taus)
        if history_test is not None:
            history.append(dict(_evaluate(global_m, history_test, ecfg, kind), round=r))
    return global_m, history


def _group_avg(global_m, local, clients, weight_fn):
    """Average per model group over the clients that hold that modality."""
    out = dict(global_m)
    groups = {
        "A": (["f_A", "g_A"], [k for k, c in enumerate(clients) if c.has_a]),
        "B": (["f_B", "g_B"], [k for k, c in enumerate(clients) if c.has_b]),
        "M": (["g_M"], [k for k, c in enumerate(clients) if c.has_paired]),
    }
    for _, (keys, members) in groups.items():
        if not members:
            continue
        w = weight_fn(members)
        for gk in keys:
            out[gk] = blend_trees([local[k][gk] for k in members], w)
    return out


def run_fedavg(key, spec, ecfg, clients, val, test, cfg: FedConfig, history_test=None):
    del val

    def aggregate(global_m, local, clients_, taus):
        def weight_fn(members):
            ns = np.asarray([clients_[k].n_samples() for k in members], np.float64)
            return ns / ns.sum()
        return _group_avg(global_m, local, clients_, weight_fn)

    gm, hist = _hfl_train(key, spec, ecfg, clients, test, cfg, aggregate=aggregate,
                          history_test=history_test)
    return _evaluate(gm, test, ecfg, spec.kind), hist


def run_fedprox(key, spec, ecfg, clients, val, test, cfg: FedConfig, mu: float = 0.01,
                history_test=None):
    del val

    def aggregate(global_m, local, clients_, taus):
        def weight_fn(members):
            ns = np.asarray([clients_[k].n_samples() for k in members], np.float64)
            return ns / ns.sum()
        return _group_avg(global_m, local, clients_, weight_fn)

    gm, hist = _hfl_train(key, spec, ecfg, clients, test, cfg, aggregate=aggregate,
                          prox_mu=mu, history_test=history_test)
    return _evaluate(gm, test, ecfg, spec.kind), hist


def run_fednova(key, spec, ecfg, clients, val, test, cfg: FedConfig, history_test=None):
    """Normalized averaging: updates d_k = (w_g - w_k)/tau_k, combined with
    data weights p_k and effective step count tau_eff = sum p_k tau_k."""
    del val

    def aggregate(global_m, local, clients_, taus):
        out = dict(global_m)
        groups = {
            "A": (["f_A", "g_A"], [k for k, c in enumerate(clients_) if c.has_a]),
            "B": (["f_B", "g_B"], [k for k, c in enumerate(clients_) if c.has_b]),
            "M": (["g_M"], [k for k, c in enumerate(clients_) if c.has_paired]),
        }
        for _, (keys, members) in groups.items():
            if not members:
                continue
            ns = np.asarray([clients_[k].n_samples() for k in members], np.float64)
            p = ns / ns.sum()
            tk = np.asarray([max(taus[k], 1) for k in members], np.float64)
            tau_eff = float(np.sum(p * tk))
            for gk in keys:
                # w <- w_g - tau_eff * sum_k p_k (w_g - w_k)/tau_k
                deltas = [jax.tree.map(lambda g, l: (g - l) / tk[i],
                                       global_m[gk], local[k][gk])
                          for i, k in enumerate(members)]
                comb = blend_trees(deltas, p)
                out[gk] = jax.tree.map(lambda g, d: g - tau_eff * d, global_m[gk], comb)
        return out

    gm, hist = _hfl_train(key, spec, ecfg, clients, test, cfg, aggregate=aggregate,
                          history_test=history_test)
    return _evaluate(gm, test, ecfg, spec.kind), hist


def _greedy_match(ref: np.ndarray, cand: np.ndarray) -> np.ndarray:
    """Greedy permutation aligning cand's rows to ref's rows by cosine sim."""
    n = ref.shape[0]
    sim = (ref / (np.linalg.norm(ref, axis=1, keepdims=True) + 1e-9)) @ (
        cand / (np.linalg.norm(cand, axis=1, keepdims=True) + 1e-9)).T
    perm = np.full(n, -1)
    used = np.zeros(n, bool)
    for _ in range(n):
        i, j = np.unravel_index(np.argmax(np.where(used[None, :], -np.inf,
                                                   np.where(perm[:, None] >= 0, -np.inf, sim))),
                                sim.shape)
        perm[i] = j
        used[j] = True
    return perm


def run_fedma(key, spec, ecfg, clients, val, test, cfg: FedConfig, history_test=None):
    """Matched averaging (greedy variant) on the encoder hidden layers."""
    del val
    assert ecfg.enc_type == "mlp", "FedMA matching implemented for mlp encoders"

    def match_encoder(ref_f, f):
        """Permute f's hidden units (rows of out-dim) to align with ref."""
        f = jax.tree.map(np.asarray, f)
        for li in range(len(f["hidden"])):
            ref_w = np.asarray(ref_f["hidden"][li]["w"])  # (d, d)
            perm = _greedy_match(ref_w.T, f["hidden"][li]["w"].T)
            f["hidden"][li]["w"] = f["hidden"][li]["w"][:, perm]
            f["hidden"][li]["b"] = f["hidden"][li]["b"][perm]
            # note: residual MLP keeps the feature basis, so downstream
            # layers need no inverse permutation (h + gelu(Wh) form)
        return jax.tree.map(jnp.asarray, f)

    def aggregate(global_m, local, clients_, taus):
        out = dict(global_m)
        groups = {
            "A": ("f_A", "g_A", [k for k, c in enumerate(clients_) if c.has_a]),
            "B": ("f_B", "g_B", [k for k, c in enumerate(clients_) if c.has_b]),
        }
        for _, (fk, gk, members) in groups.items():
            if not members:
                continue
            ns = np.asarray([clients_[k].n_samples() for k in members], np.float64)
            w = ns / ns.sum()
            ref = local[members[0]][fk]
            matched = [ref] + [match_encoder(ref, local[k][fk]) for k in members[1:]]
            out[fk] = blend_trees(matched, w)
            out[gk] = blend_trees([local[k][gk] for k in members], w)
        mm = [k for k, c in enumerate(clients_) if c.has_paired]
        if mm:
            ns = np.asarray([clients_[k].n_samples() for k in mm], np.float64)
            out["g_M"] = blend_trees([local[k]["g_M"] for k in mm], ns / ns.sum())
        return out

    gm, hist = _hfl_train(key, spec, ecfg, clients, test, cfg, aggregate=aggregate,
                          history_test=history_test)
    return _evaluate(gm, test, ecfg, spec.kind), hist


def run_hfcl(key, spec, ecfg, clients, val, test, cfg: FedConfig, history_test=None):
    """Hybrid federated/centralized: the low-compute half of the clients
    ship raw data to the server; the server trains a surrogate client."""
    del val
    n = len(clients)
    fl_ids = list(range(0, n, 2))  # odd-indexed clients share data
    shared = [clients[k] for k in range(n) if k not in fl_ids]

    def pool(views):
        views = [v for v in views if len(v)]
        return ModalView.concat(views) if views else None

    pooled = ClientData(
        partial_a=pool([c.partial_a for c in shared]) or ModalView.empty(
            spec.seq_a, spec.feat_a, spec.out_dim),
        partial_b=pool([c.partial_b for c in shared]) or ModalView.empty(
            spec.seq_b, spec.feat_b, spec.out_dim),
        frag_a=pool([c.frag_a for c in shared]) or ModalView.empty(
            spec.seq_a, spec.feat_a, spec.out_dim),
        frag_b=pool([c.frag_b for c in shared]) or ModalView.empty(
            spec.seq_b, spec.feat_b, spec.out_dim),
        paired_a=pool([c.paired_a for c in shared]) or ModalView.empty(
            spec.seq_a, spec.feat_a, spec.out_dim),
        paired_b=pool([c.paired_b for c in shared]) or ModalView.empty(
            spec.seq_b, spec.feat_b, spec.out_dim),
    )
    eff_clients = [clients[k] for k in fl_ids] + [pooled]

    def aggregate(global_m, local, clients_, taus):
        def weight_fn(members):
            ns = np.asarray([clients_[k].n_samples() for k in members], np.float64)
            return ns / ns.sum()
        return _group_avg(global_m, local, clients_, weight_fn)

    gm, hist = _hfl_train(key, spec, ecfg, eff_clients, test, cfg, aggregate=aggregate,
                          history_test=history_test)
    return _evaluate(gm, test, ecfg, spec.kind), hist


# --------------------------------------------------------------- VFL side --

def _aligned_vertical_rows(clients, include_paired: bool = False):
    """Samples usable by conventional (fixed-party) VFL: the CROSS-CLIENT
    fragmented overlap. A client's locally-paired rows are NOT vertically
    trainable under the conventional protocol — the party structure is
    fixed per modality, and a client cannot act as both parties for a
    subset of rows (exactly the 'restrictive assumptions' the paper
    criticizes; BlendFL uses those rows in its paired phase instead).
    ``include_paired=True`` gives the permissive variant (used as an
    upper-bound ablation)."""
    xa, xb, ya = [], [], []
    batches = vfl.build_vfl_batches(clients, 10**9, np.random.default_rng(0))
    if batches:
        xa.append(batches[0].x_a); xb.append(batches[0].x_b); ya.append(batches[0].y)
    if include_paired:
        for c in clients:
            if len(c.paired_a):
                xa.append(c.paired_a.x); xb.append(c.paired_b.x); ya.append(c.paired_a.y)
    if not xa:
        return None
    return np.concatenate(xa), np.concatenate(xb), np.concatenate(ya)


def run_splitnn(key, spec, ecfg, clients, val, test, cfg: FedConfig, history_test=None):
    """Pure VFL: split training of shared encoders + a server fusion head
    on the vertically aligned sample set. Unimodal columns come from
    server-side unimodal heads on the same latents (the conventional-VFL
    serving path; no decentralized inference exists here)."""
    del val
    rows = _aligned_vertical_rows(clients)
    kind = spec.kind
    models = init_client_models(key, spec, ecfg)
    rng = np.random.default_rng(cfg.seed)
    history = []
    if rows is None:
        return _evaluate(models, test, ecfg, kind), history
    xa, xb, y = rows
    for r in range(cfg.rounds * cfg.local_epochs):
        idx = rng.permutation(len(y))
        for i in range(0, len(idx), cfg.batch_size):
            sel = idx[i : i + cfg.batch_size]
            b = vfl.VflBatch(xa[sel], xb[sel], y[sel], np.zeros(len(sel)), np.zeros(len(sel)))
            x_a, x_b = jnp.asarray(b.x_a), jnp.asarray(b.x_b)
            h_a = _client_fwd(models["f_A"], x_a, ecfg=ecfg)
            h_b = _client_fwd(models["f_B"], x_b, ecfg=ecfg)
            _, g_srv, g_ha, g_hb = _server_fwd_bwd(models["g_M"], h_a, h_b,
                                                   jnp.asarray(b.y), kind=kind)
            models["g_M"] = jax.tree.map(lambda p, gr: p - cfg.lr * gr,
                                         models["g_M"], g_srv)
            models["f_A"] = _client_bwd_update(models["f_A"], x_a, g_ha,
                                               ecfg=ecfg, lr=cfg.lr)
            models["f_B"] = _client_bwd_update(models["f_B"], x_b, g_hb,
                                               ecfg=ecfg, lr=cfg.lr)
            # server-side unimodal heads on the (detached) latents
            for mod, h in (("A", h_a), ("B", h_b)):
                def head_loss(g):
                    return task_loss(dense(g, h), jnp.asarray(b.y), kind)
                gg = jax.grad(head_loss)(models[f"g_{mod}"])
                models[f"g_{mod}"] = jax.tree.map(lambda p, gr: p - cfg.lr * gr,
                                                  models[f"g_{mod}"], gg)
        if history_test is not None:
            history.append(dict(_evaluate(models, history_test, ecfg, kind), round=r))
    return _evaluate(models, test, ecfg, kind), history


def run_oneshot_vfl(key, spec, ecfg, clients, val, test, cfg: FedConfig,
                    history_test=None):
    """One-Shot VFL: local (supervised) encoder training, ONE feature
    upload, then server-side fusion-head training on frozen latents."""
    del val
    kind = spec.kind
    rng = np.random.default_rng(cfg.seed)
    models = init_client_models(key, spec, ecfg)
    locals_ = [jax.tree.map(jnp.copy, models) for _ in clients]
    # stage 1: purely local training
    for k, cd in enumerate(clients):
        _local_train(locals_[k], cd, ecfg, kind, cfg.lr, cfg.batch_size,
                     cfg.rounds * cfg.local_epochs, rng)
    # one-shot aggregation of unimodal models (single communication)
    has_a = [k for k, c in enumerate(clients) if c.has_a]
    has_b = [k for k, c in enumerate(clients) if c.has_b]
    if has_a:
        na = np.asarray([clients[k].n_samples() for k in has_a], np.float64)
        models["f_A"] = blend_trees([locals_[k]["f_A"] for k in has_a], na / na.sum())
        models["g_A"] = blend_trees([locals_[k]["g_A"] for k in has_a], na / na.sum())
    if has_b:
        nb = np.asarray([clients[k].n_samples() for k in has_b], np.float64)
        models["f_B"] = blend_trees([locals_[k]["f_B"] for k in has_b], nb / nb.sum())
        models["g_B"] = blend_trees([locals_[k]["g_B"] for k in has_b], nb / nb.sum())
    # stage 2: single latent upload, server trains the fusion head
    rows = _aligned_vertical_rows(clients)
    history = []
    if rows is not None:
        xa, xb, y = rows
        h_a = _client_fwd(models["f_A"], jnp.asarray(xa), ecfg=ecfg)
        h_b = _client_fwd(models["f_B"], jnp.asarray(xb), ecfg=ecfg)
        for r in range(cfg.rounds):
            idx = rng.permutation(len(y))
            for i in range(0, len(idx), cfg.batch_size):
                sel = idx[i : i + cfg.batch_size]

                def head_loss(gm):
                    return task_loss(fusion_apply(gm, h_a[sel], h_b[sel]),
                                     jnp.asarray(y[sel]), kind)

                gg = jax.grad(head_loss)(models["g_M"])
                models["g_M"] = jax.tree.map(lambda p, gr: p - cfg.lr * gr,
                                             models["g_M"], gg)
            if history_test is not None:
                history.append(dict(_evaluate(models, history_test, ecfg, kind), round=r))
    return _evaluate(models, test, ecfg, kind), history


# ------------------------------------------------------------- centralized --

def run_centralized(key, spec, ecfg, clients, val, test, cfg: FedConfig,
                    history_test=None):
    """Upper bound: pool ALL raw data centrally. Fragmented samples become
    paired (the center can join them), so the multimodal model trains on
    paired + fragmented-joined rows; unimodal models train on everything."""
    del val
    kind = spec.kind
    rng = np.random.default_rng(cfg.seed)
    models = init_client_models(key, spec, ecfg)
    all_a = ModalView.concat([c.all_a() for c in clients])
    all_b = ModalView.concat([c.all_b() for c in clients])
    rows = _aligned_vertical_rows(clients)
    history = []
    for r in range(cfg.rounds * cfg.local_epochs):
        for mod, view in (("A", all_a), ("B", all_b)):
            f, g = models[f"f_{mod}"], models[f"g_{mod}"]
            for x, y in _batches(view, cfg.batch_size, rng):
                f, g, _ = _unimodal_sgd_step(f, g, x, y, ecfg=ecfg, kind=kind,
                                             lr=cfg.lr, modality=mod)
            models[f"f_{mod}"], models[f"g_{mod}"] = f, g
        if rows is not None:
            xa, xb, y = rows
            idx = rng.permutation(len(y))
            f_a, f_b, g_m = models["f_A"], models["f_B"], models["g_M"]
            for i in range(0, len(idx), cfg.batch_size):
                sel = idx[i : i + cfg.batch_size]
                f_a, f_b, g_m, _ = _paired_sgd_step(
                    f_a, f_b, g_m, jnp.asarray(xa[sel]), jnp.asarray(xb[sel]),
                    jnp.asarray(y[sel]), ecfg=ecfg, kind=kind, lr=cfg.lr)
            models["f_A"], models["f_B"], models["g_M"] = f_a, f_b, g_m
        if history_test is not None:
            history.append(dict(_evaluate(models, history_test, ecfg, kind), round=r))
    return _evaluate(models, test, ecfg, kind), history


BASELINES = {
    "centralized": run_centralized,
    "fedavg": run_fedavg,
    "fedma": run_fedma,
    "fedprox": run_fedprox,
    "fednova": run_fednova,
    "oneshot_vfl": run_oneshot_vfl,
    "hfcl": run_hfcl,
    "splitnn": run_splitnn,
}
