"""Participation scheduling — WHICH K clients train each sampled round.

BlendAvg already weights *aggregation* by per-client performance
(Eq. 9-11); this module makes round *participation* adaptive too. The
sampled-round machinery treats the K client ids as data (they feed the
static-shape ``engine.sample_clients``/``scatter_clients`` gathers), so a
policy that picks ids host-side plugs in without recompiling anything:
every phase keeps its single compiled program across policies and
subsets.

A policy is a pure host-side function

    select(rng, telemetry) -> sorted (K,) int64 client ids

of a ``np.random.Generator`` and a **telemetry** dict. Determinism
contract: given the same rng state and the same telemetry, ``select``
returns the same ids — the property bit-exact checkpoint/resume rests
on (both drivers feed a reproducible rng: the in-host federation its
seeded ``host_rng``, the ``FederatedBatcher`` its stateless
``default_rng([seed, round])``).

Telemetry keys (callers fill what they have; policies read what they
need — see each policy's ``needs_state``):

    round       int    index of the round being scheduled
    last_round  (C,)   round each client last synced (-1 = never)
    omega_ema   (C,)   EMA of each client's BlendAvg omega (see
                       ``ema_update``)
    part_count  (C,)   how many rounds each client has participated in
    rows        (C,)   per-client training-row counts (static data volume)
    active      (C,)   bool membership mask under a churn scenario
                       (``repro.data.scenario``): inactive slots (not yet
                       joined / departed / capacity padding) are never
                       selected. Absent = everyone is active, and every
                       policy's rng consumption stays byte-identical to
                       the pre-scenario code.

``last_round``/``omega_ema``/``part_count`` live in the drivers' round
state as the ``sched`` telemetry block (``sched_state``), so they
checkpoint/restore bit-exactly through the existing full-round-state
path; ``round`` and ``rows`` are caller-local.

Policies (``make_policy``):

    uniform      today's behavior, bit-exact: one
                 ``rng.choice(C, K, replace=False)`` draw, sorted —
                 byte-identical rng consumption to the pre-scheduler code
    round_robin  deterministic coverage: rounds r..r+ceil(C/K)-1 select a
                 contiguous (mod C) block of K ids each, so every client
                 participates at least once per ceil(C/K) rounds
    staleness    prioritize the largest ``round - 1 - last_round`` gaps
                 (random tie-break) — bounds how stale any client's
                 weights can get under async rounds
    omega_ema    power-of-choice: oversample a uniform candidate pool of
                 ``pool_factor * K`` clients, keep the top K by omega EMA
                 (random tie-break) — exploits BlendAvg's own signal of
                 which clients' updates actually improve the global model
                 while the pool keeps exploration alive
    data_volume  rows-proportional sampling without replacement
                 (Efraimidis-Spirakis exponential keys) — clients with
                 more data participate proportionally more often
"""
from __future__ import annotations

import math

import numpy as np

POLICIES = ("uniform", "round_robin", "staleness", "omega_ema", "data_volume")

# power-of-choice candidate-pool oversampling factor (omega_ema policy)
POOL_FACTOR = 2


# ----------------------------------------------------- telemetry helpers --

def sched_state(n_clients: int):
    """The ``sched`` telemetry block a driver threads through its round
    state: omega EMA, participation counts, and a ``last_round`` mirror —
    jnp leaves, so the block rides the existing full-round-state
    checkpoint path bit-exactly."""
    import jax.numpy as jnp

    return {
        "omega_ema": jnp.zeros((n_clients,), jnp.float32),
        "part_count": jnp.zeros((n_clients,), jnp.int32),
        "last_round": jnp.full((n_clients,), -1, jnp.int32),
    }


def telemetry_from_state(state: dict) -> dict:
    """Pull a round state's ``sched`` block to host numpy — the dict a
    driver's ``telemetry_fn`` hands ``FederatedBatcher.rounds`` for
    state-reading policies. Blocks until the round that produced the
    state has finished (the unavoidable serialization of telemetry-
    dependent selection)."""
    import jax
    import numpy as np

    return {k: np.asarray(v)
            for k, v in jax.device_get(state["sched"]).items()}


def ema_update(ema, omega, beta, idx=None):
    """One exponential-moving-average step of the per-client omega
    telemetry: ``ema' = beta * ema + (1 - beta) * omega``.

    With ``idx`` (a (K,) id vector), only the participants' slots move —
    non-sampled clients keep their EMA untouched, exactly like their
    weights under the async broadcast. Pure jnp (jit-safe scatter); the
    numpy reference lives in ``tests/test_schedule.py``.
    """
    import jax.numpy as jnp

    ema = jnp.asarray(ema, jnp.float32)
    beta = jnp.float32(beta)
    new = beta * (ema if idx is None else ema[jnp.asarray(idx, jnp.int32)])
    new = new + (jnp.float32(1.0) - beta) * jnp.asarray(omega, jnp.float32)
    if idx is None:
        return new
    return ema.at[jnp.asarray(idx, jnp.int32)].set(new)


# ------------------------------------------------------------- policies ----

class Policy:
    """Base participation policy: picks the K ids of one sampled round.

    ``needs_state`` marks policies that read round-state telemetry
    (``last_round`` / ``omega_ema``) — their selection for round r depends
    on round r-1's outcome, so a loader cannot prefetch-build their
    batches ahead of the device (``FederatedBatcher.rounds`` drops to the
    synchronous path and asks the driver for fresh telemetry per round).
    """

    name = ""
    needs_state = False

    def __init__(self, n_clients: int, k: int):
        if not 0 < k <= n_clients:
            raise ValueError(f"k={k} must be in (0, n_clients={n_clients}]")
        self.n_clients = int(n_clients)
        self.k = int(k)

    def select(self, rng: np.random.Generator, telemetry: dict) -> np.ndarray:
        raise NotImplementedError

    def _active_ids(self, telemetry: dict) -> np.ndarray | None:
        """Ids the scenario's membership mask allows this round, or None
        when no mask is present (the non-scenario fast path — policies
        must keep their rng consumption unchanged in that case)."""
        act = telemetry.get("active")
        if act is None:
            return None
        ids = np.flatnonzero(np.asarray(act, bool)[: self.n_clients])
        if self.k > len(ids):
            raise ValueError(
                f"policy {self.name!r} needs k={self.k} participants but "
                f"only {len(ids)} clients are active this round")
        return ids

    def _top_k(self, keys: np.ndarray, jitter: np.ndarray) -> np.ndarray:
        """Sorted ids of the K largest keys, ties broken by jitter."""
        order = np.lexsort((jitter, -np.asarray(keys, np.float64)))
        return np.sort(order[: self.k]).astype(np.int64)


class Uniform(Policy):
    """K-of-C uniform sampling — byte-identical rng consumption to the
    pre-scheduler sampled round (the bit-exactness anchor)."""

    name = "uniform"

    def select(self, rng, telemetry):
        ids = self._active_ids(telemetry)
        if ids is None:
            return np.sort(rng.choice(self.n_clients, size=self.k,
                                      replace=False))
        return np.sort(rng.choice(ids, size=self.k, replace=False))


class RoundRobin(Policy):
    """Deterministic rotation: round r takes the K ids starting at
    ``r * K (mod C)``. Any ceil(C/K) consecutive rounds select ceil(C/K)*K
    >= C consecutive (mod C) ids — every client participates at least
    once per ceil(C/K) rounds, whatever the start round."""

    name = "round_robin"

    @property
    def coverage_rounds(self) -> int:
        return math.ceil(self.n_clients / self.k)

    def select(self, rng, telemetry):
        r = int(telemetry["round"])
        ids = self._active_ids(telemetry)
        if ids is None:
            return np.sort((r * self.k + np.arange(self.k)) % self.n_clients
                           ).astype(np.int64)
        # rotate within the active cohort: same coverage guarantee over
        # the ids that actually exist this round
        pos = (r * self.k + np.arange(self.k)) % len(ids)
        return np.sort(ids[pos]).astype(np.int64)


class Staleness(Policy):
    """Largest ``round - 1 - last_round`` gaps first (never-synced clients
    count from -1, so they lead). Ties — e.g. the all-fresh first round —
    break by rng jitter, keeping the policy unbiased at equal staleness."""

    name = "staleness"
    needs_state = True

    def select(self, rng, telemetry):
        last = np.asarray(telemetry["last_round"], np.int64)
        stale = np.maximum(int(telemetry["round"]) - 1 - last, 0
                           ).astype(np.float64)
        ids = self._active_ids(telemetry)
        if ids is not None:
            mask = np.zeros(self.n_clients, bool)
            mask[ids] = True
            stale = np.where(mask, stale, -np.inf)
        return self._top_k(stale, rng.random(self.n_clients))


class OmegaEMA(Policy):
    """Power-of-choice over BlendAvg's own signal: draw a uniform pool of
    ``pool_factor * K`` candidates, keep the top K by omega EMA. The
    uniform pool keeps exploration alive (a client whose EMA never got a
    chance to rise can still enter); the top-K exploit step routes
    participation to clients whose updates have actually been improving
    the global model."""

    name = "omega_ema"
    needs_state = True

    def __init__(self, n_clients: int, k: int, pool_factor: int = POOL_FACTOR):
        super().__init__(n_clients, k)
        self.pool = min(n_clients, max(k, int(pool_factor) * k))

    def select(self, rng, telemetry):
        ids = self._active_ids(telemetry)
        if ids is None:
            pool = rng.choice(self.n_clients, size=self.pool, replace=False)
        else:
            pool = rng.choice(ids, size=min(len(ids), self.pool),
                              replace=False)
        ema = np.asarray(telemetry["omega_ema"], np.float64)[pool]
        order = np.lexsort((rng.random(len(pool)), -ema))
        return np.sort(pool[order[: self.k]]).astype(np.int64)


class DataVolume(Policy):
    """Rows-proportional sampling without replacement via Efraimidis-
    Spirakis keys (``u ** (1/w)``): P(client in the K) grows with its row
    count, zero-row clients sink to the bottom (picked only when fewer
    than K clients hold data)."""

    name = "data_volume"

    def select(self, rng, telemetry):
        w = np.maximum(np.asarray(telemetry["rows"], np.float64), 0.0)
        u = rng.random(self.n_clients)
        ids = self._active_ids(telemetry)
        if ids is None:
            if not (w > 0).any():  # degenerate: nobody holds rows -> uniform
                return self._top_k(np.zeros(self.n_clients), u)
            keys = np.where(w > 0, u ** (1.0 / np.maximum(w, 1e-300)), -1.0)
            return self._top_k(keys, u)
        # active zero-row clients rank at -1 (picked only when fewer than
        # K active clients hold data); inactive slots sink to -inf and —
        # since _active_ids guarantees k <= active count — never surface
        keys = np.where(w > 0, u ** (1.0 / np.maximum(w, 1e-300)), -1.0)
        mask = np.zeros(self.n_clients, bool)
        mask[ids] = True
        return self._top_k(np.where(mask, keys, -np.inf), u)


_POLICY_CLASSES = {p.name: p for p in
                   (Uniform, RoundRobin, Staleness, OmegaEMA, DataVolume)}
assert tuple(_POLICY_CLASSES) == POLICIES


def make_policy(name: str, n_clients: int, k: int, **kw) -> Policy:
    """Policy factory; raises on unknown names so a typo'd ``--policy``
    fails at federation construction, not mid-run."""
    try:
        cls = _POLICY_CLASSES[name]
    except KeyError:
        raise ValueError(f"unknown participation policy {name!r}; "
                         f"known: {', '.join(POLICIES)}") from None
    return cls(n_clients, k, **kw)
