"""Aggregation strategies — the pluggable drift-robust family.

BlendAvg (paper §III-B, Eq. 9-11) is one point in a design space the
non-IID FL literature has mapped thoroughly: under client drift the
standard remedies are control variates (SCAFFOLD), proximal client
objectives (FedProx), and server-side adaptive optimizers (FedAdam /
FedAvgM). This module factors that family into one strategy interface
over the **stacked client pytrees** the round engine already speaks:

    init_state      strategy state pytrees, threaded through round state
                    exactly like opt moments ("" = stateless: blendavg /
                    fedavg / fedprox add NO state keys, so default runs
                    keep the pre-strategy checkpoint layout bit-for-bit)
    client_term     additive per-step gradient correction applied inside
                    the engine's phase functions: the FedProx proximal
                    pull  mu * (w - anchor)  and/or the SCAFFOLD
                    control-variate correction  c_global - c_local
    scaffold_round  post-round control-variate update (SCAFFOLD Option
                    II): participants' c_local rows move by
                    (anchor - trained) / (steps * lr), c_global absorbs
                    the participation-weighted mean shift
    server_update   server-side optimizer (FedAdam / momentum) applied
                    to the blended delta before broadcast — composes
                    with ANY aggregator

Aggregation weights per strategy (the engine's ``fedavg_update`` /
``blendavg_update`` consume them):

    blendavg   Eq. 9-10 validation-improvement omegas (score-based)
    fedavg     data-volume weights
    fedprox    data-volume weights (the prox term is client-side)
    scaffold   uniform over participants (SCAFFOLD's x + mean(y_i - x)
               server step at eta_g = 1)

Byzantine-robust defenses are the same kind of object — a *stateless*
strategy name that changes only how candidates reduce to the new
global (so old checkpoints stay loadable and the compile cache stays 1
per strategy):

    median        coordinate-wise median of the candidates (breakdown
                  point f < n/2); weights are ignored
    trimmed_mean  coordinate-wise mean after dropping the n_malicious
                  largest and smallest values per coordinate (needs
                  n >= 2 * n_malicious + 1); at n_malicious = 0 it
                  degenerates to the unweighted fedavg path bit-exactly
    krum          multi-Krum (Blanchard et al. 2017): score each
                  candidate by the summed squared distances to its
                  n - f - 2 nearest peers, keep the m = n - f
                  lowest-scoring, and average the survivors through the
                  ordinary volume-weighted fedavg path — at
                  n_malicious = 0 every candidate survives, so krum IS
                  fedavg bit-for-bit

State layout (only the keys a strategy needs exist — mirrors the codec
block's "none adds no keys" contract):

    c_global   per-group trees, unstacked (the server's control variate)
    c_local    per-group trees with leading C axis — gathered/scattered
               by sampled ids exactly like opt moments (``sample_state``
               / ``scatter_state``)
    srv        server-optimizer moments: {m, t} (momentum) or {m, v, t}
               (adam), trees matching the global model groups

Everything here is pure jnp over pytrees: jit-safe, shard-safe, and
checkpointable through the existing full-round-state path (bit-exact
``--selftest-resume`` holds under ``--strategy scaffold``).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

ROBUST = ("median", "trimmed_mean", "krum")
STRATEGIES = ("blendavg", "fedavg", "scaffold", "fedprox") + ROBUST
SERVER_OPTS = ("none", "adam", "momentum")

# Strategy-state trees that carry a leading client axis (gathered /
# scattered by sampled ids, like the optimizer moment trees). The
# canonical declaration is the "strat" block of the round-state registry
# (``repro.core.state.REGISTRY``); this mirror exists for readers of
# this module only.
_STACKED_KEYS = ("c_local",)


@dataclasses.dataclass(frozen=True)
class StrategyConfig:
    """Static aggregation-strategy configuration (hashable: lives in
    ``EngineConfig``, so a strategy choice is round *structure* — the
    default traces zero extra ops and switching strategies is a new
    compiled round, never a retrace of an existing one)."""

    name: str = "blendavg"  # one of STRATEGIES
    # FedProx proximal coefficient: adds mu/2 * ||w - anchor||^2 to every
    # client objective (as the exact gradient term mu * (w - anchor)).
    # mu = 0 is the identity — "fedprox" at mu 0 IS plain fedavg.
    fedprox_mu: float = 0.0
    # SCAFFOLD Option-II scaling uses the *client* lr; a schedule makes
    # the 1/(steps*lr) term approximate (standard practice).
    # Server-side optimizer applied to the blended delta before
    # broadcast; composes with any strategy name.
    server_opt: str = "none"  # one of SERVER_OPTS
    server_lr: float = 1.0
    server_beta1: float = 0.9
    server_beta2: float = 0.99
    server_eps: float = 1e-3  # FedAdam tau (Reddi et al. 2021)
    # Assumed malicious-client budget f for the robust defenses: the
    # per-side trim count for trimmed_mean, the f in multi-Krum's
    # m = n - f survivor count and n - f - 2 neighbor count. Static
    # structure (a different f is a different compiled round); ignored
    # by the non-robust strategies and by median (whose breakdown point
    # is f < n/2 regardless).
    n_malicious: int = 1

    def __post_init__(self):
        if self.name not in STRATEGIES:
            raise ValueError(f"strategy {self.name!r} not in {STRATEGIES}")
        if self.server_opt not in SERVER_OPTS:
            raise ValueError(
                f"server_opt {self.server_opt!r} not in {SERVER_OPTS}")
        if self.fedprox_mu < 0:
            raise ValueError(f"fedprox_mu must be >= 0, got {self.fedprox_mu}")
        if self.fedprox_mu and self.name not in ("fedprox",):
            raise ValueError("fedprox_mu > 0 requires strategy 'fedprox' "
                             f"(got {self.name!r})")
        if not isinstance(self.n_malicious, int) or self.n_malicious < 0:
            raise ValueError(
                f"n_malicious must be an int >= 0, got {self.n_malicious!r}")

    # -- static structure queries (drivers branch on these at trace time) --

    @property
    def prox(self) -> bool:
        """Client loss carries the proximal pull."""
        return self.fedprox_mu > 0

    @property
    def control(self) -> bool:
        """Client steps carry SCAFFOLD control-variate corrections."""
        return self.name == "scaffold"

    @property
    def client_active(self) -> bool:
        """Phase functions need the per-client ``strat`` block (anchor
        and/or control variates)."""
        return self.prox or self.control

    @property
    def stateful(self) -> bool:
        """The strategy threads state through round state."""
        return self.control or self.server_opt != "none"

    @property
    def score_based(self) -> bool:
        """Aggregation weights come from validation scores (Eq. 9-10)."""
        return self.name == "blendavg"

    @property
    def robust(self) -> bool:
        """Candidates reduce through a Byzantine-robust reducer instead
        of a weighted average (stateless: adds no state keys)."""
        return self.name in ROBUST


def make_strategy(name: str = "blendavg", fedprox_mu: float = 0.0,
                  server_opt: str = "none", server_lr: float = 1.0,
                  n_malicious: int = 1) -> StrategyConfig:
    return StrategyConfig(name=name, fedprox_mu=fedprox_mu,
                          server_opt=server_opt, server_lr=server_lr,
                          n_malicious=int(n_malicious))


# ------------------------------------------------------------ state layout --

def _zeros_like(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


def init_state(scfg: StrategyConfig, stacked_models: dict,
               global_models: dict) -> dict:
    """Strategy state for one federation: ``{}`` when the strategy is
    stateless (no key is ever added to round state — the default layout
    is untouched, like codec \"none\").

    ``stacked_models`` / ``global_models`` are the engine's client-group
    dicts (stacked leaves carry the leading C axis).
    """
    out = {}
    if scfg.control:
        out["c_global"] = _zeros_like(global_models)
        out["c_local"] = _zeros_like(stacked_models)
    if scfg.server_opt == "momentum":
        out["srv"] = {"m": _zeros_like(global_models),
                      "t": jnp.zeros((), jnp.int32)}
    elif scfg.server_opt == "adam":
        out["srv"] = {"m": _zeros_like(global_models),
                      "v": _zeros_like(global_models),
                      "t": jnp.zeros((), jnp.int32)}
    return out


def sample_state(state: dict, idx) -> dict:
    """Gather the sampled clients' rows of the stacked strategy trees
    ((C, ...) -> (K, ...)); unstacked entries (c_global, srv moments)
    pass through untouched — the "strat" block of the round-state
    registry (``repro.core.state``), which owns the semantics."""
    from repro.core import state as round_state

    return round_state.sample_block("strat", state, idx)


def scatter_state(state: dict, sub: dict, idx) -> dict:
    """Write a sampled round's strategy state back: stacked rows scatter
    to the sampled positions, unstacked entries replace wholesale (the
    registry's "strat" block scatter)."""
    from repro.core import state as round_state

    return round_state.scatter_block("strat", state, sub, idx)


# ------------------------------------------------------- client-side terms --

def client_term(scfg: StrategyConfig, grads: dict, params: dict,
                strat: dict | None) -> dict:
    """Additive gradient correction for one phase's group subset.

    ``grads``/``params`` are the phase's per-group stacked trees;
    ``strat`` carries what the strategy configured (``anchor`` — each
    participant's round-start weights — for FedProx, ``c_global`` /
    ``c_local`` for SCAFFOLD). Returns corrected grads:

        g  +  mu * (w - anchor)  +  (c_global - c_local)

    Unstacked c_global leaves broadcast against the stacked (C, ...)
    grads. The config is static, so the default strategy adds NO ops.
    """
    if strat is None or not scfg.client_active:
        return grads
    out = dict(grads)
    for grp in grads:
        g = out[grp]
        if scfg.prox:
            mu = jnp.float32(scfg.fedprox_mu)
            g = jax.tree.map(
                lambda gg, p, a: gg + mu * (p.astype(jnp.float32) - a),
                g, params[grp], strat["anchor"][grp])
        if scfg.control:
            g = jax.tree.map(lambda gg, cg, cl: gg + (cg - cl),
                             g, strat["c_global"][grp], strat["c_local"][grp])
        out[grp] = g
    return out


# ------------------------------------------------- SCAFFOLD round update ----

def scaffold_round(scfg: StrategyConfig, c_global: dict, c_local: dict,
                   anchor: dict, trained: dict, steps: dict, lr: float,
                   frac: float):
    """Post-round control-variate update (SCAFFOLD Option II).

    Per participant i (the K gathered rows):

        c_i^+  =  c_i - c + (anchor_i - trained_i) / (steps * lr)
        c^+    =  c + frac * mean_i(c_i^+ - c_i)        frac = K / C

    ``steps`` maps each model group to the optimizer steps it took this
    round (groups differ: encoders step in three phases, unimodal heads
    in one); ``lr`` is the client lr (a schedule makes the scaling
    approximate — standard practice). Returns (c_global', c_local'_rows)
    with the participants' K rows updated; the caller scatters them back
    like opt moments.
    """
    inv_lr = 1.0 / float(lr)
    new_cl, new_cg = {}, {}
    for grp in trained:
        # steps may arrive traced (the jitted in-host hook): jnp math only
        inv = inv_lr / jnp.maximum(jnp.float32(steps[grp]), 1.0)
        cl = jax.tree.map(
            lambda c, cg, a, t: c - cg + inv * (a - t.astype(jnp.float32)),
            c_local[grp], c_global[grp], anchor[grp], trained[grp])
        new_cl[grp] = cl
        new_cg[grp] = jax.tree.map(
            lambda cg, n, o: cg + jnp.float32(frac) * jnp.mean(n - o, axis=0),
            c_global[grp], cl, c_local[grp])
    return new_cg, new_cl


# --------------------------------------------------- server-side optimizer --

def server_update(scfg: StrategyConfig, srv: dict, new_global: dict,
                  prev_global: dict):
    """Server optimizer on the blended delta (one step per round).

    delta = blend - prev_global is the server's "gradient" (FedOpt,
    Reddi et al. 2021). ``adam`` keeps bias-corrected first/second
    moments; ``momentum`` a running sum (FedAvgM). Returns (adjusted
    global tree dict, new srv state). A keep-global round (blendavg with
    no improver) contributes a zero delta — the moments decay toward
    zero instead of freezing, exactly like a zero minibatch gradient.
    """
    if scfg.server_opt == "none":
        return new_global, srv
    delta = jax.tree.map(
        lambda n, p: n.astype(jnp.float32) - p.astype(jnp.float32),
        new_global, prev_global)
    t = srv["t"] + 1
    lr = jnp.float32(scfg.server_lr)
    b1 = jnp.float32(scfg.server_beta1)
    if scfg.server_opt == "momentum":
        m = jax.tree.map(lambda mm, d: b1 * mm + d, srv["m"], delta)
        out = jax.tree.map(lambda p, mm: (p.astype(jnp.float32) + lr * mm
                                          ).astype(p.dtype), prev_global, m)
        return out, {"m": m, "t": t}
    b2 = jnp.float32(scfg.server_beta2)
    eps = jnp.float32(scfg.server_eps)
    m = jax.tree.map(lambda mm, d: b1 * mm + (1 - b1) * d, srv["m"], delta)
    v = jax.tree.map(lambda vv, d: b2 * vv + (1 - b2) * jnp.square(d),
                     srv["v"], delta)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    out = jax.tree.map(
        lambda p, mm, vv: (p.astype(jnp.float32)
                           + lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps)
                           ).astype(p.dtype), prev_global, m, v)
    return out, {"m": m, "v": v, "t": t}


# ------------------------------------------------- Byzantine-robust reducers --
#
# Pure jnp reductions over a stacked candidate tree (leading axis = the
# n candidates). They ignore aggregation weights by design: robustness
# comes from order statistics / distance scores, and a weighted variant
# would let one attacker inflate its own weight. Each has a numpy
# reference + property tests in tests/test_robust.py.

def coordinate_median_tree(stacked: dict) -> dict:
    """Coordinate-wise median of ``n`` stacked candidates. Tolerates any
    f < n/2 arbitrary candidates per coordinate (the optimal breakdown
    point). Never reduces to a mean — even honest-only cohorts get the
    order statistic, which is why median has no fedavg-parity claim."""
    return jax.tree.map(
        lambda x: jnp.median(x.astype(jnp.float32), axis=0).astype(x.dtype),
        stacked)


def trimmed_mean_tree(stacked: dict, trim: int) -> dict:
    """Coordinate-wise mean after dropping the ``trim`` largest and
    ``trim`` smallest values per coordinate. Needs n >= 2*trim + 1
    (validated by the drivers); callers route trim == 0 through the
    ordinary fedavg path instead, so the degenerate case stays bit-exact
    with fedavg rather than merely close."""
    def red(x):
        n = x.shape[0]
        if n <= 2 * trim:
            raise ValueError(
                f"trimmed mean needs > 2*trim candidates, got n={n} "
                f"with trim={trim}")
        s = jnp.sort(x.astype(jnp.float32), axis=0)
        return jnp.mean(s[trim:n - trim], axis=0).astype(x.dtype)

    return jax.tree.map(red, stacked)


def _flatten_candidates(stacked: dict) -> jnp.ndarray:
    """(n, D) float32 matrix: every leaf of every candidate, flattened
    and concatenated — Krum scores distances in full parameter space."""
    leaves = jax.tree.leaves(stacked)
    n = leaves[0].shape[0]
    return jnp.concatenate(
        [leaf.astype(jnp.float32).reshape(n, -1) for leaf in leaves], axis=1)


def krum_scores(stacked: dict, f: int) -> jnp.ndarray:
    """(n,) Krum scores (Blanchard et al. 2017): candidate i's score is
    the sum of squared distances to its n - f - 2 nearest peers (clamped
    to at least one neighbor for tiny cohorts). Outliers sit far from
    everything, so low score = well-supported candidate. The guarantee
    needs n >= 2f + 3; computing only needs n >= 2."""
    x = _flatten_candidates(stacked)
    n = x.shape[0]
    sq = jnp.sum(x * x, axis=1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    d2 = jnp.maximum(d2, 0.0)
    d2 = jnp.where(jnp.eye(n, dtype=bool), jnp.inf, d2)
    k = max(n - f - 2, 1)
    return jnp.sum(jnp.sort(d2, axis=1)[:, :k], axis=1)


def krum_mask(stacked: dict, f: int) -> jnp.ndarray:
    """(n,) float32 0/1 multi-Krum survivor mask: the m = n - f
    lowest-scoring candidates. At f = 0 the mask is all-ones whatever
    the scores — multiplying it into the fedavg volume weights is then
    the identity, which is the defense==fedavg bit-parity contract."""
    n = len(jax.tree.leaves(stacked)[0])
    m = max(n - f, 1)
    if m >= n:
        return jnp.ones(n, jnp.float32)
    order = jnp.argsort(krum_scores(stacked, f))
    return jnp.zeros(n, jnp.float32).at[order[:m]].set(1.0)
