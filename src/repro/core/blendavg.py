"""BlendAvg — performance-weighted global aggregation (paper §III-B).

Given the previous global model and L candidate (locally trained) models:

1. score every candidate and the global model on the server's private
   representative validation set              (A_i, A_global)
2. Δ_i = A_i − A_global; discard Δ_i ≤ 0      (Eq. 9)
3. ω_i = Δ_i / Σ_{Δ_j>0} Δ_j                  (Eq. 10)
4. W_blended = Σ ω_i · W_i                    (Eq. 11)

If no candidate improves, the previous global model is kept unchanged
(the paper: "promoting updates only if the validation performance
improves, thereby preventing model degradation").

The weighted sum runs through the fused Pallas ``blend_params`` kernel
(one HBM pass over the stacked models) — see repro/kernels/blendavg.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.common.tree import tree_stack
from repro.kernels.blendavg.ops import blend_params


def blendavg_weights(scores: Sequence[float], global_score: float,
                     staleness: Sequence[float] | None = None,
                     staleness_exp: float = 0.5) -> np.ndarray:
    """Eq. 9-10: masked, normalized improvement weights. Zero vector if no
    candidate improves on the global model.

    ``staleness`` (per-candidate, rounds since the candidate's base global
    model was current) damps improvements by (1 + s)^-``staleness_exp``
    before normalization — the async BlendAvg used for partial-
    participation rounds. Candidates that did not finish should arrive
    with score -inf (or NaN), masking them like any non-improver.

    A non-finite ``global_score`` is an ERROR, not a keep-global: a NaN
    score poisons every delta (masking all candidates forever), and a
    -inf score makes every delta +inf (NaN omegas after normalization).
    Both mean the server's scoring pass is broken — raise instead of
    silently freezing the federation on the last good global model.
    """
    global_score = float(global_score)
    if not np.isfinite(global_score):
        raise ValueError(
            f"blendavg_weights: global_score is {global_score} — the "
            "server's validation scoring is broken (a NaN score would "
            "silently mask every candidate, a -inf score would emit NaN "
            "omegas); refusing to aggregate")
    deltas = np.asarray(scores, np.float64) - global_score
    deltas = np.where(np.isnan(deltas), -np.inf, deltas)
    mask = deltas > 0
    if not mask.any():
        return np.zeros(len(deltas), np.float64)
    w = np.where(mask, deltas, 0.0)
    if staleness is not None and staleness_exp:
        s = np.maximum(np.asarray(staleness, np.float64), 0.0)
        w = w * (1.0 + s) ** (-staleness_exp)
    return w / w.sum()


def blend_trees(trees: Sequence, omega: np.ndarray):
    """Eq. 11 via the fused kernel over the stacked client models."""
    stacked = tree_stack(list(trees))
    return blend_params(stacked, jnp.asarray(omega, jnp.float32))


def blendavg(
    global_params,
    candidates: Sequence,
    eval_fn: Callable[[object], float],
    *,
    global_score: float | None = None,
):
    """Full BlendAvg step for one model group.

    eval_fn(params) -> validation score (higher is better, e.g. AUROC).
    Returns (blended_params, info dict).
    """
    if global_score is None:
        global_score = eval_fn(global_params)
    scores = [eval_fn(c) for c in candidates]
    omega = blendavg_weights(scores, global_score)
    if omega.sum() == 0:  # no improvement anywhere -> keep global model
        return global_params, {
            "scores": scores, "global_score": global_score,
            "omega": omega, "kept_global": True,
        }
    blended = blend_trees(candidates, omega)
    return blended, {
        "scores": scores, "global_score": global_score,
        "omega": omega, "kept_global": False,
    }


def fedavg(candidates: Sequence, n_samples: Sequence[int] | None = None):
    """FedAvg baseline: data-volume (or uniform) weighted average.

    All-zero ``n_samples`` is an error: no candidate holds data, so there
    is nothing to average — blending would silently return an all-zero
    model. Callers that can legitimately hit this (e.g. a zero-overlap
    federation) must keep the previous global model instead, exactly what
    ``engine.fedavg_update`` does with its explicit keep-global branch.
    """
    l = len(candidates)
    if n_samples is None:
        w = np.full(l, 1.0 / l)
    else:
        tot = float(sum(n_samples))
        if tot <= 0:
            raise ValueError(
                "fedavg: all candidate sample counts are zero — nothing to "
                "average; keep the previous global model instead (see "
                "engine.fedavg_update)")
        w = np.asarray(n_samples, np.float64) / tot
    return blend_trees(candidates, w)
