"""Decentralized serving engine: jitted micro-batched request execution.

The millions-of-users path for the paper's third pillar. A
``ServingEngine`` takes a stream of heterogeneous ``InferenceRequest``s
— any mix of modality-presence combos — and turns Python-loop per-request
serving into four compiled programs fed with padded micro-batches:

1. **Route bucketing.** Each request is routed by
   ``inference.route_for`` (multimodal / unimodal_A / unimodal_B /
   vfl_fallback) and its rows coalesced with same-route neighbours from
   the same assembly window into one micro-batch.
2. **Capacity padding.** A micro-batch pads up to the smallest
   configured capacity that holds it (the ``core.state.capacity_for``
   idiom, with an explicit capacity ladder instead of one bucket size),
   so arbitrary request mixes replay a tiny set of static shapes:
   compile cache stays EXACTLY 1 per (route, capacity) forever.
3. **Donated-buffer execution.** One jitted function per (route,
   capacity); the padded input and mask buffers are donated — they are
   per-batch scratch, so XLA may reuse their memory for the scores.
   Padded rows are masked (``scores * mask[:, None]``) and the live
   rows are bit-identical to single-request ``inference.predict`` calls:
   both trace the same ``route_scores`` forward, and row-parallel
   compiled math doesn't change with batch padding.
4. **Double-buffered assembly.** Host-side window assembly (routing,
   chunking, padding — numpy only) runs on a daemon worker thread
   feeding a bounded queue, the ``data.pipeline`` prefetch idiom, so
   batch assembly overlaps device execution. ``stall_seconds`` is
   assembly time the overlap failed to hide.

The VFL fallback route threads its per-row feature/score messages
through the wire codec (``core.codec``), and the engine meters actual
bytes per executed micro-batch — ``stats["wire_bytes"]`` is a MEASURED
quantity that reconciles exactly against the analytic
``inference.communication_cost`` formula (bytes are per-row, so
coalescing changes message counts, never byte totals).

Requests larger than the top capacity are chunked into parts and
reassembled in arrival order, so one engine serves single-sample lookups
and bulk scoring batches through the same four compiled programs.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
import warnings

import jax.numpy as jnp
import numpy as np

from repro.core import codec as wire
from repro.core import inference
from repro.core.encoders import EncoderConfig
from repro.core.inference import (InferenceRequest, Route, ROUTES,
                                  communication_cost, request_rows,
                                  route_for, route_scores)

_SENTINEL = object()  # end-of-stream marker for the assembly queue


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Engine shape/wire policy. Frozen: it keys compiled programs.

    ``capacities`` is the padded-batch ladder (ascending); its maximum
    is also the micro-batch coalescing limit. The ladder floors at 2:
    XLA lowers 1-row batches to matrix-vector products whose reduction
    order drifts an ulp from the matrix-matrix lowering all batches
    >= 2 share (``inference.MIN_COMPILED_ROWS``), which would break the
    engine's bit-parity with ``predict``. ``codec`` applies the wire
    codec to the VFL route's messages. ``window`` is how many requests
    one assembly pass may coalesce; ``prefetch`` is how many assembled
    windows the worker may stage ahead (0 = synchronous assembly).
    """

    capacities: tuple = (2, 4, 16, 64)
    codec: str = "none"
    topk_frac: float = 0.25
    window: int = 32
    prefetch: int = 2

    def __post_init__(self):
        caps = tuple(int(c) for c in self.capacities)
        if not caps or list(caps) != sorted(set(caps)):
            raise ValueError(f"capacities must be ascending unique ints, got {self.capacities}")
        if caps[0] < inference.MIN_COMPILED_ROWS:
            raise ValueError(
                f"capacities floor at {inference.MIN_COMPILED_ROWS} (got "
                f"{caps[0]}): 1-row batches lower to matrix-vector math "
                "whose bits drift from every batched shape, breaking "
                "parity with inference.predict")
        object.__setattr__(self, "capacities", caps)
        if self.codec not in wire.CODECS:
            raise ValueError(f"codec {self.codec!r} not in {wire.CODECS}")
        if self.window < 1:
            raise ValueError(f"window={self.window} must be >= 1")
        if self.prefetch < 0:
            raise ValueError(f"prefetch={self.prefetch} must be >= 0")


def bucket_for(n: int, capacities: tuple) -> int:
    """Smallest configured capacity holding ``n`` rows (the
    ``core.state.capacity_for`` idiom over an explicit ladder)."""
    if n < 1:
        raise ValueError(f"n={n} must be >= 1")
    for c in capacities:
        if n <= c:
            return c
    raise ValueError(f"n={n} rows exceed the top capacity {capacities[-1]}; "
                     "chunk before bucketing")


@dataclasses.dataclass
class ServedResult:
    """One completed request.

    ``messages``/``bytes`` are the request's own logical network cost
    (``communication_cost`` of its rows; 0 on local routes) — what this
    request would cost served alone. The engine's *actual* coalesced
    wire traffic is metered in ``ServingEngine.stats`` (same byte total,
    fewer messages).
    """

    index: int
    scores: jnp.ndarray
    route: Route
    messages: int
    bytes: int
    latency_s: float


# One part of one request inside an assembly window: requests larger
# than the top capacity are split into parts, served independently, and
# reassembled in offset order.
@dataclasses.dataclass
class _Part:
    index: int  # request index in the stream
    offset: int  # row offset inside the request
    x_a: np.ndarray | None
    x_b: np.ndarray | None

    @property
    def rows(self) -> int:
        return len(self.x_a) if self.x_a is not None else len(self.x_b)


@dataclasses.dataclass
class _Batch:
    """One padded micro-batch ready to execute: static (route, cap)
    shape, numpy host buffers, and the spans mapping padded rows back to
    request parts."""

    route: Route
    cap: int
    x_a: np.ndarray | None
    x_b: np.ndarray | None
    mask: np.ndarray  # (cap,) float 1=live 0=padding
    spans: list  # [(index, offset, start_row, n_rows)]
    n_live: int


class ServingEngine:
    """Batched request engine over one client's blended models.

    ``server_gmv`` (the VFL server head) is only needed when the stream
    may carry ``vfl=True`` requests. ``stats`` accumulates across calls;
    compiled programs are lazy — only (route, capacity) pairs the
    traffic actually exercises are built.
    """

    def __init__(self, models: dict, ecfg: EncoderConfig, kind: str, *,
                 server_gmv: dict | None = None,
                 cfg: ServingConfig | None = None):
        self.models = models
        self.ecfg = ecfg
        self.kind = kind
        self.server_gmv = server_gmv
        self.cfg = cfg if cfg is not None else ServingConfig()
        self._codec = wire.make_codec(self.cfg.codec, self.cfg.topk_frac)
        self._fns: dict = {}  # (Route, cap) -> jitted fn
        self.stats = {
            "requests": 0, "rows": 0, "batches": 0,
            "batches_by_route": {r.value: 0 for r in ROUTES},
            "wire_messages": 0, "wire_bytes": 0,
            "build_seconds": 0.0, "stall_seconds": 0.0,
            "execute_seconds": 0.0,
        }

    # ------------------------------------------------ compiled programs ---

    def _fn(self, route: Route, cap: int):
        key = (route, cap)
        fn = self._fns.get(key)
        if fn is None:
            fn = self._build_fn(route)
            self._fns[key] = fn
        return fn

    def _build_fn(self, route: Route):
        import jax  # local: keep module import light for host-only use

        ecfg, kind = self.ecfg, self.kind
        codec = self._codec if (route is Route.VFL_FALLBACK and self._codec.enabled) else None
        # The padded x/mask buffers are per-batch scratch — donate them
        # so XLA can reuse their memory. Model params are NOT donated
        # (they persist across every batch).
        if route is Route.VFL_FALLBACK:
            def fn(models, server_gmv, x_a, x_b, mask):
                s = route_scores(models, route, x_a, x_b, ecfg, kind,
                                 server_gmv=server_gmv, codec=codec)
                return s * mask[:, None]
            return jax.jit(fn, donate_argnums=(2, 3, 4))
        if route is Route.MULTIMODAL:
            def fn(models, x_a, x_b, mask):
                s = route_scores(models, route, x_a, x_b, ecfg, kind)
                return s * mask[:, None]
            return jax.jit(fn, donate_argnums=(1, 2, 3))

        def fn(models, x, mask):
            xa, xb = (x, None) if route is Route.UNIMODAL_A else (None, x)
            s = route_scores(models, route, xa, xb, ecfg, kind)
            return s * mask[:, None]
        return jax.jit(fn, donate_argnums=(1, 2))

    def cache_counts(self) -> dict:
        """{(route_value, capacity): compile-cache size}. The engine's
        standing invariant: every entry is exactly 1 — each (route,
        capacity) pair compiles once, no matter the request mix."""
        return {(route.value, cap): fn._cache_size()
                for (route, cap), fn in sorted(
                    self._fns.items(), key=lambda kv: (kv[0][0].value, kv[0][1]))}

    # ------------------------------------------------- window assembly ----

    def _plan_window(self, window: list) -> tuple:
        """Assemble one window of (index, request) into padded
        micro-batches (host-side numpy only — runs on the worker
        thread). Returns (meta, batches): meta maps request index to
        (route, n_parts, rows)."""
        top = self.cfg.capacities[-1]
        parts_by_route: dict = {r: [] for r in ROUTES}
        meta: dict = {}
        for index, req in window:
            route = route_for(req)
            if route is Route.VFL_FALLBACK and self.server_gmv is None:
                raise ValueError("stream carries vfl=True requests but the "
                                 "engine has no server_gmv head")
            n = request_rows(req)
            n_parts = 0
            for off in range(0, n, top):
                end = min(off + top, n)
                parts_by_route[route].append(_Part(
                    index, off,
                    None if req.x_a is None else np.asarray(req.x_a[off:end]),
                    None if req.x_b is None else np.asarray(req.x_b[off:end])))
                n_parts += 1
            meta[index] = (route, n_parts, n)

        batches = []
        for route in ROUTES:
            cur, cur_rows = [], 0
            for part in parts_by_route[route]:
                if cur and cur_rows + part.rows > top:
                    batches.append(self._pack(route, cur, cur_rows))
                    cur, cur_rows = [], 0
                cur.append(part)
                cur_rows += part.rows
            if cur:
                batches.append(self._pack(route, cur, cur_rows))
        return meta, batches

    def _pack(self, route: Route, parts: list, n_live: int) -> _Batch:
        """Pad one coalesced run of same-route parts up to its capacity
        bucket. Padding rows are zeros with mask 0 — under the per-row
        wire codec they're independent messages, so they never perturb
        the live rows' scores."""
        cap = bucket_for(n_live, self.cfg.capacities)

        def pad(blocks):
            first = blocks[0]
            out = np.zeros((cap,) + first.shape[1:], first.dtype)
            row = 0
            for b in blocks:
                out[row:row + len(b)] = b
                row += len(b)
            return out

        x_a = pad([p.x_a for p in parts]) if parts[0].x_a is not None else None
        x_b = pad([p.x_b for p in parts]) if parts[0].x_b is not None else None
        mask = np.zeros(cap, np.float32)
        mask[:n_live] = 1.0
        spans, row = [], 0
        for p in parts:
            spans.append((p.index, p.offset, row, p.rows))
            row += p.rows
        return _Batch(route, cap, x_a, x_b, mask, spans, n_live)

    # -------------------------------------------------------- execution ---

    def _execute(self, batch: _Batch) -> jnp.ndarray:
        """Run one padded micro-batch through its compiled program and
        meter the wire traffic it actually generated."""
        fn = self._fn(batch.route, batch.cap)
        mask = jnp.asarray(batch.mask)
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            # Donation pays on accelerators, where the padded input slab
            # aliases the output allocation; CPU XLA can't use these
            # donations ((cap, S, F) inputs never alias (cap, out_dim)
            # scores) and says so once per compile — expected, not a bug.
            warnings.filterwarnings(
                "ignore", message="Some donated buffers were not usable")
            if batch.route is Route.VFL_FALLBACK:
                scores = fn(self.models, self.server_gmv,
                            jnp.asarray(batch.x_a), jnp.asarray(batch.x_b),
                            mask)
            elif batch.route is Route.MULTIMODAL:
                scores = fn(self.models, jnp.asarray(batch.x_a),
                            jnp.asarray(batch.x_b), mask)
            else:
                x = batch.x_a if batch.route is Route.UNIMODAL_A else batch.x_b
                scores = fn(self.models, jnp.asarray(x), mask)
        scores.block_until_ready()
        self.stats["execute_seconds"] += time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["batches_by_route"][batch.route.value] += 1
        self.stats["rows"] += batch.n_live
        if batch.route is Route.VFL_FALLBACK:
            # Measured bytes: this micro-batch moved n_live per-row
            # feature messages up (x2) and score rows down, priced by
            # the wire codec — the quantity the analytic
            # communication_cost formula must reconcile against.
            cost = communication_cost(batch.n_live, self.ecfg.d_hidden,
                                      "vfl", int(scores.shape[-1]),
                                      codec=self._codec)
            self.stats["wire_messages"] += cost["messages"]
            self.stats["wire_bytes"] += cost["bytes"]
        return scores

    def _request_cost(self, route: Route, rows: int, out_dim: int) -> tuple:
        if route is not Route.VFL_FALLBACK:
            return 0, 0
        cost = communication_cost(rows, self.ecfg.d_hidden, "vfl", out_dim,
                                  codec=self._codec)
        return cost["messages"], cost["bytes"]

    def _serve_window(self, meta: dict, batches: list):
        """Execute one assembled window; yield each request's
        ServedResult as its last part completes."""
        t_w0 = time.perf_counter()
        pending = {index: {} for index in meta}  # index -> offset -> scores
        for batch in batches:
            scores = self._execute(batch)
            for index, offset, start, n in batch.spans:
                pending[index][offset] = scores[start:start + n]
                route, n_parts, rows = meta[index]
                if len(pending[index]) == n_parts:
                    got = pending.pop(index)
                    full = (got[0] if n_parts == 1 else
                            jnp.concatenate([got[k] for k in sorted(got)]))
                    msgs, nbytes = self._request_cost(
                        route, rows, int(full.shape[-1]))
                    self.stats["requests"] += 1
                    yield ServedResult(index, full, route, msgs, nbytes,
                                       time.perf_counter() - t_w0)

    # -------------------------------------------------------- public API --

    def serve_stream(self, requests):
        """Serve an iterable of ``InferenceRequest``s, yielding
        ``ServedResult``s in completion order (same-window requests can
        reorder across routes; use ``run`` for stream-order results).

        Window assembly (routing + chunking + padding; pure numpy) runs
        on a daemon worker thread staging up to ``cfg.prefetch`` windows
        ahead of device execution — the ``data.pipeline`` double-buffer
        idiom, including its error propagation: an assembly error (e.g.
        a no-modality request) is re-raised here, not swallowed.
        """
        def windows():
            buf = []
            for index, req in enumerate(requests):
                buf.append((index, req))
                if len(buf) >= self.cfg.window:
                    yield buf
                    buf = []
            if buf:
                yield buf

        if self.cfg.prefetch <= 0:
            for win in windows():
                t0 = time.perf_counter()
                plan = self._plan_window(win)
                self.stats["build_seconds"] += time.perf_counter() - t0
                yield from self._serve_window(*plan)
            return

        q: queue.Queue = queue.Queue(maxsize=self.cfg.prefetch)
        stop_evt = threading.Event()

        def _feed(item) -> bool:
            while not stop_evt.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for win in windows():
                    t0 = time.perf_counter()
                    plan = self._plan_window(win)
                    self.stats["build_seconds"] += time.perf_counter() - t0
                    if stop_evt.is_set() or not _feed(plan):
                        return
                _feed(_SENTINEL)
            except BaseException as e:  # surface assembly errors to the
                _feed(e)  # consumer instead of hanging it on q.get()

        t = threading.Thread(target=worker, daemon=True,
                             name="serving-engine-assembly")
        t.start()
        try:
            while True:
                t0 = time.perf_counter()
                item = q.get()
                self.stats["stall_seconds"] += time.perf_counter() - t0
                if item is _SENTINEL:
                    return
                if isinstance(item, BaseException):
                    raise item
                yield from self._serve_window(*item)
        finally:
            stop_evt.set()

    def run(self, requests) -> list:
        """Serve a request list; results in stream order."""
        return sorted(self.serve_stream(list(requests)),
                      key=lambda r: r.index)
