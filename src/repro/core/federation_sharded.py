"""BlendFL round as ONE SPMD program — the TPU-pod expression of Alg. 1.

Hardware adaptation (DESIGN.md §2): the paper's federation is N hospital
GPU boxes + an RPC parameter server. On a TPU pod we map:

    client k            ->  slice k of the mesh "data" axis (stacked
                            client models: every leaf gains a leading C
                            axis sharded over "data"; large hidden dims
                            shard over "model")
    feature upload      ->  all-gather of latent h over the client axis
                            (the alignment gather below; its transpose is
                            the gradient return, from plain autodiff)
    weight upload +     ->  masked weighted reduction over the client
    BlendAvg + broadcast    axis: blended = sum_k omega_k * W_k, lowered
                            by XLA to an all-reduce; the result is already
                            resident on every slice, so the "broadcast
                            back" of Alg. 1 line 32 is free.

Architecture: the four phases are NOT implemented here — they are the
shared stacked-client phase functions from ``repro.core.engine``
(``make_phase_fns``), the same math the in-host ``federation.Federation``
drives. This module only adapts them to the SPMD batch layout (uniform
per-client row counts -> all-ones masks; the PSI alignment arrives as the
``perm_b`` gather) and composes them into one jittable ``round_fn``. The
optimizer is pluggable via ``ShardedFedSpec.optimizer`` ("sgd"|"adamw");
stacked per-client optimizer state shards and threads through the round
inside the state dict.

BlendAvg's validation scoring runs as a vmapped evaluation of all stacked
client models on a replicated validation shard. Inside the SPMD program
the score is the (negative) validation LOSS: a monotone on-device
surrogate for the paper's AUROC (rank statistics don't belong in the hot
aggregation path; the in-host federation.py uses real AUROC). The blend
uses the engine's "reduce" formulation here — the same Eq. 11 the in-host
path runs through the Pallas ``blend_params`` kernel, but expressed as a
weighted reduction over the client axis so GSPMD lowers it to the masked
all-reduce pictured above (a Pallas custom call has no partition rule and
would force an all-gather of every client model).

Partial participation (``ShardedFedSpec.n_sampled`` = K > 0): the round
becomes the K-of-C sampled, staleness-weighted async round. The host (or
an outer loop) draws K client ids into the ``sampled`` batch vector; the
round gathers those rows of the stacked models/opt moments
(``engine.sample_clients`` — a static-shape gather, so the round still
compiles once across subsets), trains the phases at leading axis K,
aggregates over the K candidates with omegas damped by each candidate's
staleness (``round - 1 - last_round[sampled]`` — non-sampled clients are
simply absent from the blend, masked like empty batches), and scatters
the broadcast back to the participants only. ``last_round``/``round``
int vectors thread through the state dict alongside the opt moments.
WHICH K ids arrive is the host's choice: ``ShardedFedSpec.policy`` names
a ``repro.core.schedule`` participation policy fed by the ``sched``
telemetry block (omega EMA / participation counts / last_round mirror)
the round maintains in its state — the ids stay data, so every policy
shares this one compiled program.

Everything below is pure jnp under jit — sharding in_shardings do the
distribution; no host round-trips inside a federated round.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import aggregate as strategies
from repro.core import codec as wire
from repro.core import schedule
from repro.core import state as rstate
from repro.core.encoders import EncoderConfig
from repro.core.engine import (
    CLIENT_GROUPS,
    EngineConfig,
    make_phase_fns,
    stack_with,
)


@dataclasses.dataclass(frozen=True)
class ShardedFedSpec:
    """Static description of the sharded federation workload."""

    n_clients: int = 16
    d_hidden: int = 1024
    n_layers: int = 2
    seq_a: int = 64
    feat_a: int = 128
    seq_b: int = 64
    feat_b: int = 128
    out_dim: int = 25
    kind: str = "multilabel"
    n_partial: int = 512  # per client, per modality
    n_frag: int = 512  # per client (aligned cross-client rows)
    n_paired: int = 512  # per client
    n_val: int = 1024  # replicated server validation set
    # §Perf C.1: BlendAvg only needs the val set to RANK models; scoring
    # all C client models on the full set dominates the round's HBM bytes
    # (measured ~75%). Score on a fixed subsample instead.
    n_val_score: int = 0  # 0 = full n_val
    lr: float = 1e-3
    optimizer: str = "sgd"  # sgd | adamw
    weight_decay: float = 0.0  # adamw only
    schedule: str = "constant"  # constant | cosine
    total_steps: int = 0  # client cosine horizon (optimizer steps)
    # The server g_M^v head steps once per round, not once per client
    # minibatch — under a schedule it needs its own horizon (threaded to
    # EngineConfig.server_total_steps, which selects fns.srv_opt).
    server_total_steps: int = 0
    # Partial participation: K-of-C sampled async rounds. 0 = every
    # client trains every round.
    n_sampled: int = 0
    staleness_exp: float = 0.5  # async omega damping (1+s)^-a
    # Which K clients participate each sampled round — a host-side
    # ``repro.core.schedule`` policy fed by the ``sched`` telemetry block
    # this round threads through its state. The ids stay DATA (they feed
    # the same static-shape gathers), so the policy choice never
    # recompiles anything. "uniform" reproduces the pre-scheduler
    # sampled round bit-exactly.
    policy: str = "uniform"
    ema_beta: float = 0.9  # omega-EMA telemetry decay (schedule.ema_update)
    # "reduce" so the blend lowers to the masked all-reduce over the
    # sharded client axis (a Pallas custom call would force an all-gather
    # of every client model — see EngineConfig.blend).
    blend: str = "reduce"  # reduce | pallas
    # Wire codec for the simulated round traffic (candidate uplink +
    # broadcast downlink deltas, with error-feedback residuals in round
    # state). "none" = uncompressed fp32; see ``repro.core.codec``.
    codec: str = "none"  # none | int8 | topk | int8_topk
    topk_frac: float = 0.25  # entries kept per leaf by sparsifying codecs
    # Aggregation strategy (``repro.core.aggregate``): blendavg keeps the
    # Eq. 9-11 scored blend; fedavg/fedprox weight candidates by data
    # volume (fedprox additionally pulls every client step toward its
    # round-start anchor with ``fedprox_mu``); scaffold corrects client
    # grads with control variates threaded through round state like opt
    # moments and blends participants uniformly. ``server_opt`` applies a
    # server-side FedAdam/momentum step to the blended delta before
    # broadcast and composes with any strategy. Like the codec, the
    # strategy is static round structure: the default adds no state keys
    # and traces no extra ops.
    # blendavg | fedavg | scaffold | fedprox, or a Byzantine-robust
    # reducer: median | trimmed_mean | krum (stateless — no new state
    # keys, old checkpoints stay loadable; ``n_malicious`` is their
    # assumed attacker budget f).
    strategy: str = "blendavg"
    fedprox_mu: float = 0.0
    server_opt: str = "none"  # none | adam | momentum
    server_lr: float = 1.0
    n_malicious: int = 1
    # Gradient-space uplink attackers (``repro.data.scenario`` sign_flip
    # / scale events): when True the batch carries a per-participant
    # ``attack_coef`` (K,) float32 vector — 1.0 honest (exact
    # passthrough), -1.0 sign-flip, SCALE_FACTOR boosted — applied to
    # each candidate's delta vs. its round-start anchor AFTER training
    # (and the SCAFFOLD control update) but BEFORE the uplink codec, so
    # the server decodes exactly what the attacker shipped. The flag is
    # static structure; WHO attacks each round is data.
    attacks: bool = False

    def __post_init__(self):
        if not 0 <= self.n_sampled <= self.n_clients:
            raise ValueError(
                f"n_sampled={self.n_sampled} must be in [0, n_clients="
                f"{self.n_clients}]: a K-of-C sampled round cannot gather "
                "more client rows than the federation stacks (jit gathers "
                "clamp out-of-range ids silently, so this must fail on the "
                "host)")
        f = self.n_malicious
        if self.strategy == "krum" and self.k_round < f + 3:
            raise ValueError(
                f"krum needs at least n_malicious + 3 = {f + 3} candidates "
                f"per round to score n - f - 2 neighbors, got K="
                f"{self.k_round}")
        if self.strategy == "trimmed_mean" and self.k_round < 2 * f + 1:
            raise ValueError(
                f"trimmed_mean needs at least 2 * n_malicious + 1 = "
                f"{2 * f + 1} candidates per round, got K={self.k_round}")

    @property
    def ecfg(self) -> EncoderConfig:
        return EncoderConfig(d_hidden=self.d_hidden, n_layers=self.n_layers,
                             enc_type="mlp")

    @property
    def k_round(self) -> int:
        """Clients that train per round (leading axis of the batch)."""
        return self.n_sampled or self.n_clients

    @property
    def engine_cfg(self) -> EngineConfig:
        return EngineConfig(ecfg=self.ecfg, kind=self.kind,
                            optimizer=self.optimizer, lr=self.lr,
                            weight_decay=self.weight_decay,
                            schedule=self.schedule, total_steps=self.total_steps,
                            server_total_steps=self.server_total_steps,
                            staleness_exp=self.staleness_exp, blend=self.blend,
                            codec=wire.make_codec(self.codec, self.topk_frac),
                            strategy=strategies.make_strategy(
                                self.strategy, self.fedprox_mu,
                                self.server_opt, self.server_lr,
                                self.n_malicious))


def init_stacked_models(key, spec: ShardedFedSpec):
    """Stacked client models: every leaf has leading axis C. All clients
    start from the same init (standard FL), so we broadcast one init."""
    from repro.core.encoders import init_client_models
    from repro.data.synthetic import TaskSpec

    tspec = TaskSpec("sharded", spec.kind, spec.out_dim, spec.seq_a, spec.feat_a,
                     spec.seq_b, spec.feat_b)
    base = init_client_models(key, tspec, spec.ecfg)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (spec.n_clients,) + x.shape), base)
    server_gmv = base["g_M"]
    global_models = base
    return stacked, server_gmv, global_models


def init_round_state(key, spec: ShardedFedSpec) -> dict:
    """Full round-state pytree: stacked models + global/server models +
    stacked optimizer state + the async round bookkeeping (``round``
    counter and per-client ``last_round`` sync vector) + the ``sched``
    participation telemetry (omega EMA, participation counts, last_round
    mirror — what the host-side ``repro.core.schedule`` policies read).
    This is what ``make_blendfl_round`` threads; because the telemetry is
    ordinary state leaves, it checkpoints/restores bit-exactly through
    the existing full-round-state path and an adaptive policy resumes on
    the same ids it would have picked uninterrupted. The server head's
    state comes from ``fns.srv_opt`` — the optimizer with the server's
    own schedule horizon (``server_total_steps``), not the clients' — so
    the threaded schedule state matches the optimizer that consumes it in
    ``vfl_step``.

    The block LAYOUT is not spelled out here — it is the round-state
    registry's (``repro.core.state.build_round_state``, byte-identical
    to the historical layout): codec "none" and stateless strategies add
    no keys, so existing checkpoints restore untouched."""
    stacked, server_gmv, global_models = init_stacked_models(key, spec)
    fns = make_phase_fns(spec.engine_cfg)
    return rstate.build_round_state(
        stacked=stacked, server_gmv=server_gmv, global_models=global_models,
        opt_state=fns.opt.init({k: stacked[k] for k in CLIENT_GROUPS}),
        srv_opt_state=fns.srv_opt.init(server_gmv),
        n_clients=spec.n_clients, codec_on=spec.codec != "none",
        scfg=spec.engine_cfg.strategy)


def make_blendfl_round(spec: ShardedFedSpec):
    """Returns round_fn(state, batch) -> (state', metrics).

    state: see ``init_round_state``. batch keys (leading K = per-round
    client axis, = C at full participation, unless noted):
      partial_a (K,Np,Sa,Fa)  partial_ya (K,Np,O)   partial_b / _yb
      frag_a    (K,Nf,Sa,Fa)  frag_y    (K,Nf,O)    frag_b (K,Nf,Sb,Fb)
      perm_b    (K*Nf,) int32 global alignment: row i of gathered h_a
                pairs with row perm_b[i] of gathered h_b (the PSI output)
      sampled   (K,) int32 sampled client ids [n_sampled > 0 only]
      attack_coef (K,) f32 per-participant uplink attack coefficient
                (1 honest / -1 sign-flip / SCALE_FACTOR) [attacks only]
      val_a (Nv,Sa,Fa) val_b (Nv,Sb,Fb) val_y (Nv,O)   [replicated]

    With ``spec.n_sampled`` set, the round gathers the sampled rows of the
    stacked models/opt moments, trains at leading axis K, damps each
    candidate's omega by its staleness, and scatters the broadcast back to
    the participants only (async: non-sampled clients keep stale weights
    and are absent from the blend). The sampled ids are DATA — the round
    compiles once across different subsets of the same K. Like every
    gather index under jit (``perm_b`` included), ids must lie in
    [0, n_clients): out-of-range values clamp silently instead of
    raising, so validate on the host when ids come from untrusted input.
    """
    fns = make_phase_fns(spec.engine_cfg)
    K = spec.k_round
    scfg = spec.engine_cfg.strategy
    # SCAFFOLD Option-II scaling: optimizer steps each group took this
    # round (encoders step in all three phases; heads in one).
    scaffold_steps = {"f_A": 3.0, "f_B": 3.0, "g_A": 1.0, "g_B": 1.0,
                      "g_M": 1.0}

    def aggregate(models, server_gmv, global_models, batch, staleness):
        """Phase 4 on device: -val-loss scores, then the shared (async)
        BlendAvg over the K participating candidates."""
        val_a, val_b, val_y = batch["val_a"], batch["val_b"], batch["val_y"]
        if spec.n_val_score and spec.n_val_score < spec.n_val:
            val_a = val_a[: spec.n_val_score]
            val_b = val_b[: spec.n_val_score]
            val_y = val_y[: spec.n_val_score]
        ones = jnp.ones(val_y.shape[0], jnp.float32)

        def uni_score(f, g, x):  # higher is better
            return -fns.unimodal_loss(f, g, x, val_y, ones)[0]

        def multi_score(g_m, f_a, f_b):
            return -fns.paired_loss(f_a, f_b, g_m, val_a, val_b, val_y, ones)[0]

        new_global = dict(global_models)
        infos = {}
        for mod, x_val in (("A", val_a), ("B", val_b)):
            scores = jax.vmap(lambda f, g: uni_score(f, g, x_val))(
                models[f"f_{mod}"], models[f"g_{mod}"])
            gscore = uni_score(global_models[f"f_{mod}"],
                               global_models[f"g_{mod}"], x_val)
            cand = {"f": models[f"f_{mod}"], "g": models[f"g_{mod}"]}
            glob = {"f": global_models[f"f_{mod}"], "g": global_models[f"g_{mod}"]}
            blended, omega, _ = fns.blendavg_update(glob, cand, scores, gscore,
                                                    staleness=staleness)
            new_global[f"f_{mod}"], new_global[f"g_{mod}"] = blended["f"], blended["g"]
            infos[f"omega_{mod}"] = omega

        # multimodal: K participating heads + the server's g_M^v (Eq. 8);
        # the server head trains every round, so its staleness is 0
        cand = stack_with(models["g_M"], server_gmv)
        stale_m = (None if staleness is None
                   else jnp.concatenate([staleness, jnp.zeros(1, jnp.float32)]))
        scores = jax.vmap(lambda gm: multi_score(gm, new_global["f_A"],
                                                 new_global["f_B"]))(cand)
        gscore = multi_score(global_models["g_M"], new_global["f_A"],
                             new_global["f_B"])
        new_global["g_M"], infos["omega_M"], _ = fns.blendavg_update(
            global_models["g_M"], cand, scores, gscore, staleness=stale_m)
        return new_global, infos

    def aggregate_weighted(models, server_gmv, global_models, batch):
        """Phase 4 for the score-free strategies: fedavg/fedprox weight
        each candidate by the rows it trained on this round (read off the
        batch masks; the uniform synthetic layout reduces to equal
        weights), scaffold blends participants uniformly (SCAFFOLD's
        x + (K/C-scaled) mean(y_i - x) server step at eta_g = 1). The
        multimodal blend stacks the server's g_M^v as candidate K with
        the total live aligned rows as its volume — it trained on every
        client's fragmented rows. Staleness damping is a BlendAvg scoring
        concept and does not apply here.

        The Byzantine-robust strategies route the same candidates
        through ``fns.robust_update`` instead of the weighted average:
        krum masks the volume weights down to the multi-Krum survivors
        (so at n_malicious = 0 it IS this function's fedavg path
        bit-for-bit), median / trimmed_mean reduce coordinate-wise. The
        server's g_M^v rides as an extra candidate for the M head there
        too — an honest anchor the distance scores can lean on."""
        if "partial_ma" in batch:
            na = jnp.sum(batch["partial_ma"], axis=1)
            nb = jnp.sum(batch["partial_mb"], axis=1)
        else:
            na = jnp.full((K,), float(spec.n_partial))
            nb = jnp.full((K,), float(spec.n_partial))
        n_pair = (jnp.sum(batch["paired_m"], axis=1) if "paired_m" in batch
                  else jnp.full((K,), float(spec.n_paired)))
        n_frag = (jnp.sum(batch["frag_w"].reshape(K, spec.n_frag), axis=1)
                  if "frag_w" in batch
                  else jnp.full((K,), float(spec.n_frag)))
        if scfg.control:
            w_cli = jnp.ones((K,), jnp.float32)
            w_m = jnp.ones((K + 1,), jnp.float32)
        else:
            w_cli = na + nb + n_pair + n_frag
            w_m = jnp.concatenate([n_pair, jnp.sum(n_frag)[None]])

        new_global = dict(global_models)
        infos = {}
        for mod in ("A", "B"):
            cand = {"f": models[f"f_{mod}"], "g": models[f"g_{mod}"]}
            glob = {"f": global_models[f"f_{mod}"],
                    "g": global_models[f"g_{mod}"]}
            if scfg.robust:
                blended, om = fns.robust_update(glob, cand, w_cli)
            else:
                blended = fns.fedavg_update(glob, cand, w_cli)
                # normalized weights double as the sched telemetry
                # omegas, so the participation policies see the same
                # [0, 1] mass they see under blendavg
                om = w_cli / jnp.maximum(jnp.sum(w_cli), 1e-12)
            new_global[f"f_{mod}"] = blended["f"]
            new_global[f"g_{mod}"] = blended["g"]
            infos[f"omega_{mod}"] = om
        cand = stack_with(models["g_M"], server_gmv)
        if scfg.robust:
            new_global["g_M"], infos["omega_M"] = fns.robust_update(
                global_models["g_M"], cand, w_m)
        else:
            new_global["g_M"] = fns.fedavg_update(global_models["g_M"],
                                                  cand, w_m)
            infos["omega_M"] = w_m / jnp.maximum(jnp.sum(w_m), 1e-12)
        return new_global, infos

    def round_fn(state, batch):
        # ONE registry-routed gather covers every block: stacked leaves
        # come down to the K sampled rows ((C,...) -> (K,...), ids as
        # data), global leaves pass through. Full participation (idx
        # None) is the identity.
        idx = batch["sampled"] if spec.n_sampled else None
        sub = rstate.sample(state, idx)
        models, opt_state = sub["models"], sub["opt"]
        staleness = (jnp.maximum(state["round"] - 1 - sub["last_round"], 0)
                     .astype(jnp.float32) if spec.n_sampled else None)
        server_gmv, srv_state = sub["server_gmv"], sub["srv_opt"]
        codec_on = spec.codec != "none"
        if codec_on:
            # uplink base: the weights each participant starts this
            # round from (its delta is what crosses the wire), plus its
            # error-feedback residual rows
            base = models
            resid_up = sub["codec"]["resid_up"]
        # strategy block for the phase functions: each participant's
        # round-start weights anchor the FedProx pull; SCAFFOLD's c_local
        # rows arrive gathered like opt moments
        anchor = models
        strat = None
        if scfg.control:
            c_local = sub["strat"]["c_local"]
        if scfg.client_active:
            strat = {}
            if scfg.prox:
                strat["anchor"] = anchor
            if scfg.control:
                strat["c_global"] = state["strat"]["c_global"]
                strat["c_local"] = c_local

        # phase 1: local unimodal training. Ragged federations (the
        # FederatedBatcher) ship real 0/1 row masks; the uniform synthetic
        # path omits them and every padded row is live.
        p1 = {"xa": batch["partial_a"], "ya": batch["partial_ya"],
              "ma": batch.get("partial_ma",
                              jnp.ones(batch["partial_ya"].shape[:2], jnp.float32)),
              "xb": batch["partial_b"], "yb": batch["partial_yb"],
              "mb": batch.get("partial_mb",
                              jnp.ones(batch["partial_yb"].shape[:2], jnp.float32))}
        models, opt_state, i1 = fns.unimodal_step(models, opt_state, p1, strat)
        # average over clients that actually held rows (all of them in the
        # uniform layout, where this reduces to the plain mean)
        wa = (i1["n_a"] > 0).astype(jnp.float32)
        wb = (i1["n_b"] > 0).astype(jnp.float32)
        loss_uni = ((jnp.sum(i1["loss_a"] * wa) + jnp.sum(i1["loss_b"] * wb))
                    / jnp.maximum(jnp.sum(wa) + jnp.sum(wb), 1.0))

        # phase 2: split (VFL) training; identity gather on the a side,
        # the PSI permutation on the b side. ``frag_w`` zero-weights
        # padded/unmatched alignment rows; ``frag_part_*`` excludes
        # clients with no live aligned rows from the param update.
        p2 = {"xa": batch["frag_a"], "xb": batch["frag_b"],
              "gather_a": jnp.arange(K * spec.n_frag, dtype=jnp.int32),
              "gather_b": batch["perm_b"],
              "y": batch["frag_y"].reshape(K * spec.n_frag, -1),
              "w": batch.get("frag_w"),
              "part_a": batch.get("frag_part_a"),
              "part_b": batch.get("frag_part_b")}
        models, server_gmv, opt_state, srv_state, loss_vfl = fns.vfl_step(
            models, server_gmv, opt_state, srv_state, p2, strat)

        # phase 3: local multimodal training on paired rows
        p3 = {"xa": batch["paired_a"], "xb": batch["paired_b"],
              "y": batch["paired_y"],
              "m": batch.get("paired_m",
                             jnp.ones(batch["paired_y"].shape[:2], jnp.float32))}
        models, opt_state, i3 = fns.paired_step(models, opt_state, p3, strat)
        wp = (i3["n"] > 0).astype(jnp.float32)
        loss_paired = (jnp.sum(i3["loss"] * wp)
                       / jnp.maximum(jnp.sum(wp), 1.0))

        # SCAFFOLD control-variate round update on the TRUE trained
        # weights (Option II runs server-side on what the clients really
        # computed — before the lossy uplink codec touches the
        # candidates), scaled by the participation fraction K/C
        if scfg.control:
            new_cg, new_cl = fns.scaffold_round(
                state["strat"]["c_global"], c_local, anchor, models,
                scaffold_steps, K / spec.n_clients)

        # gradient-space uplink attackers: each participant ships
        # anchor + coef * (trained - anchor). coef is DATA (the attacker
        # set changes round to round without recompiling); an exact
        # where-passthrough keeps honest rows (coef == 1) bit-identical
        # to the unattacked round. Sits after the SCAFFOLD update (the
        # true training still happened client-side) and before the
        # uplink codec (the server decodes what the attacker shipped).
        if spec.attacks:
            coef = batch["attack_coef"].astype(jnp.float32)

            def forge(t, a):
                c = coef.reshape((K,) + (1,) * (t.ndim - 1))
                forged = (a.astype(jnp.float32)
                          + c * (t.astype(jnp.float32)
                                 - a.astype(jnp.float32))).astype(t.dtype)
                return jnp.where(c == 1.0, t, forged)

            models = jax.tree.map(forge, models, anchor)

        # wire codec, uplink leg: the trained weights become candidates
        # only after the lossy client->server round-trip — aggregation
        # scores and blends what the server would actually receive
        if codec_on:
            models, resid_up = fns.codec_uplink(models, base, resid_up)

        # phase 4: aggregation + broadcast. BlendAvg scores candidates on
        # the replicated val shard (Eq. 9-11); the score-free strategies
        # blend by data volume / uniformly. Full participation: the
        # broadcast is free under SPMD (the reduction leaves the blend
        # resident on every slice). Sampled: participants-only scatter —
        # stragglers keep their stale rows; the trained weights only
        # mattered as candidates, while opt moments ride home per client.
        if scfg.score_based:
            new_global, infos = aggregate(
                models, server_gmv, global_models=state["global_models"],
                batch=batch, staleness=staleness)
        else:
            new_global, infos = aggregate_weighted(
                models, server_gmv, global_models=state["global_models"],
                batch=batch)
        # server-side optimizer on the blended delta, before anything is
        # broadcast (clients — and the downlink codec — see the adjusted
        # global, and the server's g_M^v re-seeds from it)
        if scfg.server_opt != "none":
            new_global, srv_moments = fns.server_update(
                state["strat"]["srv"], new_global, state["global_models"])
        # wire codec, downlink leg: clients adopt the blend as decoded
        # from the broadcast delta. The server's own g_M^v head never
        # crosses a wire — it re-seeds from the TRUE blend below.
        srv_gmv_true = new_global["g_M"]
        if codec_on:
            new_global, resid_down = fns.codec_downlink(
                new_global, state["global_models"], state["codec"]["resid_down"])
        bcast = dict(fns.broadcast(
            {k: new_global[k] for k in CLIENT_GROUPS}, K))
        # per-participant sync stamp: K rows in a sampled round (the
        # registry scatters them to the drawn slots), the whole vector at
        # full participation (idx None replaces wholesale)
        last_round = (jnp.full((K,), state["round"], jnp.int32)
                      if spec.n_sampled
                      else jnp.full_like(state["last_round"], state["round"]))

        # participation telemetry for the host-side scheduler: this
        # round's per-client omega (mean over the three heads' Eq. 10
        # weights; omega_M's trailing server-head slot excluded) folds
        # into the EMA at the participants' slots only, mirroring the
        # async broadcast. Pure jnp — the policy choice is host-side, so
        # the compiled round is identical across policies. The update
        # math runs on the gathered rows; WHERE the rows land is the
        # registry scatter's job.
        cli_omega = (infos["omega_A"] + infos["omega_B"]
                     + infos["omega_M"][: K]) / 3.0
        new_sched = {
            "omega_ema": schedule.ema_update(sub["sched"]["omega_ema"],
                                             cli_omega, spec.ema_beta),
            "part_count": sub["sched"]["part_count"] + 1,
            "last_round": last_round,
        }

        # ONE registry-routed scatter writes the round back: stacked
        # rows land at the sampled slots, global blocks replace.
        updates = {"models": bcast, "server_gmv": srv_gmv_true,
                   "global_models": new_global, "opt": opt_state,
                   "srv_opt": srv_state, "last_round": last_round,
                   "round": state["round"] + 1, "sched": new_sched}
        if codec_on:
            updates["codec"] = {"resid_up": resid_up,
                                "resid_down": resid_down}
        if scfg.stateful:
            new_strat = {}
            if scfg.control:
                new_strat["c_global"] = new_cg
                new_strat["c_local"] = new_cl
            if scfg.server_opt != "none":
                new_strat["srv"] = srv_moments
            updates["strat"] = new_strat
        state = rstate.scatter(state, updates, idx)
        metrics = dict(loss_uni=loss_uni, loss_vfl=loss_vfl,
                       loss_paired=loss_paired, **infos)
        return state, metrics

    return round_fn


def batch_specs(spec: ShardedFedSpec, ragged: bool = False):
    """ShapeDtypeStructs for one federated round's inputs (dry-run).
    Training arrays carry the per-round client axis K (= C at full
    participation); a sampled round additionally takes the K sampled
    client ids. ``ragged=True`` adds the heterogeneous-row-count keys the
    ``FederatedBatcher`` emits: per-row 0/1 masks for phases 1/3, the
    per-aligned-row weight vector for phase 2, and the per-client VFL
    participation flags."""
    f32 = jnp.float32
    K = spec.k_round
    sds = jax.ShapeDtypeStruct
    specs = {
        "partial_a": sds((K, spec.n_partial, spec.seq_a, spec.feat_a), f32),
        "partial_ya": sds((K, spec.n_partial, spec.out_dim), f32),
        "partial_b": sds((K, spec.n_partial, spec.seq_b, spec.feat_b), f32),
        "partial_yb": sds((K, spec.n_partial, spec.out_dim), f32),
        "frag_a": sds((K, spec.n_frag, spec.seq_a, spec.feat_a), f32),
        "frag_b": sds((K, spec.n_frag, spec.seq_b, spec.feat_b), f32),
        "frag_y": sds((K, spec.n_frag, spec.out_dim), f32),
        "perm_b": sds((K * spec.n_frag,), jnp.int32),
        "paired_a": sds((K, spec.n_paired, spec.seq_a, spec.feat_a), f32),
        "paired_b": sds((K, spec.n_paired, spec.seq_b, spec.feat_b), f32),
        "paired_y": sds((K, spec.n_paired, spec.out_dim), f32),
        "val_a": sds((spec.n_val, spec.seq_a, spec.feat_a), f32),
        "val_b": sds((spec.n_val, spec.seq_b, spec.feat_b), f32),
        "val_y": sds((spec.n_val, spec.out_dim), f32),
    }
    if ragged:
        specs.update({
            "partial_ma": sds((K, spec.n_partial), f32),
            "partial_mb": sds((K, spec.n_partial), f32),
            "frag_w": sds((K * spec.n_frag,), f32),
            "frag_part_a": sds((K,), jnp.bool_),
            "frag_part_b": sds((K,), jnp.bool_),
            "paired_m": sds((K, spec.n_paired), f32),
        })
    if spec.n_sampled:
        specs["sampled"] = sds((K,), jnp.int32)
    if spec.attacks:
        specs["attack_coef"] = sds((K,), f32)
    return specs
