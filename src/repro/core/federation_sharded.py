"""BlendFL round as ONE SPMD program — the TPU-pod expression of Alg. 1.

Hardware adaptation (DESIGN.md §2): the paper's federation is N hospital
GPU boxes + an RPC parameter server. On a TPU pod we map:

    client k            ->  slice k of the mesh "data" axis (stacked
                            client models: every leaf gains a leading C
                            axis sharded over "data"; large hidden dims
                            shard over "model")
    feature upload      ->  all-gather of latent h over the client axis
                            (the alignment gather below; its transpose is
                            the gradient return, from plain autodiff)
    weight upload +     ->  masked weighted reduction over the client
    BlendAvg + broadcast    axis: blended = sum_k omega_k * W_k, lowered
                            by XLA to an all-reduce; the result is already
                            resident on every slice, so the "broadcast
                            back" of Alg. 1 line 32 is free.

BlendAvg's validation scoring runs as a vmapped evaluation of all stacked
client models on a replicated validation shard. Inside the SPMD program
the score is the (negative) validation LOSS: a monotone on-device
surrogate for the paper's AUROC (rank statistics don't belong in the hot
aggregation path; the in-host federation.py uses real AUROC).

Everything below is pure jnp under jit — sharding in_shardings do the
distribution; no host round-trips inside a federated round.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.encoders import EncoderConfig, encoder_apply, fusion_apply, task_loss
from repro.models.common import dense


@dataclasses.dataclass(frozen=True)
class ShardedFedSpec:
    """Static description of the sharded federation workload."""

    n_clients: int = 16
    d_hidden: int = 1024
    n_layers: int = 2
    seq_a: int = 64
    feat_a: int = 128
    seq_b: int = 64
    feat_b: int = 128
    out_dim: int = 25
    kind: str = "multilabel"
    n_partial: int = 512  # per client, per modality
    n_frag: int = 512  # per client (aligned cross-client rows)
    n_paired: int = 512  # per client
    n_val: int = 1024  # replicated server validation set
    # §Perf C.1: BlendAvg only needs the val set to RANK models; scoring
    # all C client models on the full set dominates the round's HBM bytes
    # (measured ~75%). Score on a fixed subsample instead.
    n_val_score: int = 0  # 0 = full n_val
    lr: float = 1e-3

    @property
    def ecfg(self) -> EncoderConfig:
        return EncoderConfig(d_hidden=self.d_hidden, n_layers=self.n_layers,
                             enc_type="mlp")


def init_stacked_models(key, spec: ShardedFedSpec):
    """Stacked client models: every leaf has leading axis C. All clients
    start from the same init (standard FL), so we broadcast one init."""
    from repro.core.encoders import init_client_models
    from repro.data.synthetic import TaskSpec

    tspec = TaskSpec("sharded", spec.kind, spec.out_dim, spec.seq_a, spec.feat_a,
                     spec.seq_b, spec.feat_b)
    base = init_client_models(key, tspec, spec.ecfg)
    stacked = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (spec.n_clients,) + x.shape), base)
    server_gmv = base["g_M"]
    global_models = base
    return stacked, server_gmv, global_models


def make_blendfl_round(spec: ShardedFedSpec):
    """Returns round_fn(stacked, server_gmv, global_models, batch) ->
    (stacked', server_gmv', global_models', metrics).

    batch keys (leading C = client axis unless noted):
      partial_a (C,Np,Sa,Fa)  partial_ya (C,Np,O)   partial_b / _yb
      frag_a    (C,Nf,Sa,Fa)  frag_y    (C,Nf,O)    frag_b (C,Nf,Sb,Fb)
      perm_b    (C*Nf,) int32 global alignment: row i of gathered h_a
                pairs with row perm_b[i] of gathered h_b (the PSI output)
      val_a (Nv,Sa,Fa) val_b (Nv,Sb,Fb) val_y (Nv,O)   [replicated]
    """
    ecfg, kind, lr = spec.ecfg, spec.kind, spec.lr
    C = spec.n_clients

    def uni_loss(f, g, x, y):
        h = encoder_apply(f, x, ecfg)
        return task_loss(dense(g, h), y, kind)

    def paired_loss(f_a, f_b, g_m, x_a, x_b, y):
        h_a = encoder_apply(f_a, x_a, ecfg)
        h_b = encoder_apply(f_b, x_b, ecfg)
        return task_loss(fusion_apply(g_m, h_a, h_b), y, kind)

    def sgd(params, grads):
        return jax.tree.map(lambda p, g: p - lr * g, params, grads)

    # ---- phase 1: local unimodal training (vmapped over clients) ----
    def local_unimodal(models, batch):
        def one(f, g, x, y):
            loss, (gf, gg) = jax.value_and_grad(uni_loss, argnums=(0, 1))(f, g, x, y)
            return sgd(f, gf), sgd(g, gg), loss

        fa, ga, la = jax.vmap(one)(models["f_A"], models["g_A"],
                                   batch["partial_a"], batch["partial_ya"])
        fb, gb, lb = jax.vmap(one)(models["f_B"], models["g_B"],
                                   batch["partial_b"], batch["partial_yb"])
        models = dict(models, f_A=fa, g_A=ga, f_B=fb, g_B=gb)
        return models, (jnp.mean(la) + jnp.mean(lb)) / 2

    # ---- phase 2: split (VFL) training on fragmented rows ----
    def vfl_exchange(models, server_gmv, batch):
        def joint(f_a_stack, f_b_stack, gmv):
            # ClientForwardPass on every slice, then the alignment gather
            h_a = jax.vmap(lambda f, x: encoder_apply(f, x, ecfg))(
                f_a_stack, batch["frag_a"])  # (C, Nf, d)
            h_b = jax.vmap(lambda f, x: encoder_apply(f, x, ecfg))(
                f_b_stack, batch["frag_b"])
            h_a = h_a.reshape(C * spec.n_frag, -1)
            h_b = h_b.reshape(C * spec.n_frag, -1)[batch["perm_b"]]  # server PSI align
            y = batch["frag_y"].reshape(C * spec.n_frag, -1)
            return task_loss(fusion_apply(gmv, h_a, h_b), y, kind)

        loss, (gfa, gfb, gsrv) = jax.value_and_grad(joint, argnums=(0, 1, 2))(
            models["f_A"], models["f_B"], server_gmv)
        models = dict(models, f_A=sgd(models["f_A"], gfa), f_B=sgd(models["f_B"], gfb))
        return models, sgd(server_gmv, gsrv), loss

    # ---- phase 3: local multimodal training on paired rows ----
    def local_paired(models, batch):
        def one(f_a, f_b, g_m, x_a, x_b, y):
            loss, (gfa, gfb, ggm) = jax.value_and_grad(paired_loss, argnums=(0, 1, 2))(
                f_a, f_b, g_m, x_a, x_b, y)
            return sgd(f_a, gfa), sgd(f_b, gfb), sgd(g_m, ggm), loss

        fa, fb, gm, losses = jax.vmap(one)(
            models["f_A"], models["f_B"], models["g_M"],
            batch["paired_a"], batch["paired_b"], batch["paired_y"])
        return dict(models, f_A=fa, f_B=fb, g_M=gm), jnp.mean(losses)

    # ---- phase 4: BlendAvg aggregation over the client axis ----
    def blend(stacked_tree, omega):
        """sum_k omega_k W_k over the leading client axis (-> all-reduce)."""
        return jax.tree.map(
            lambda w: jnp.tensordot(omega.astype(jnp.float32),
                                    w.astype(jnp.float32), axes=1).astype(w.dtype),
            stacked_tree)

    def omega_of(scores, global_score):
        delta = scores - global_score  # improvement = val-loss decrease
        mask = delta > 0
        w = jnp.where(mask, delta, 0.0)
        tot = jnp.sum(w)
        return jnp.where(tot > 0, w / jnp.maximum(tot, 1e-12), jnp.zeros_like(w)), tot > 0

    def aggregate(models, server_gmv, global_models, batch):
        val_a, val_b, val_y = batch["val_a"], batch["val_b"], batch["val_y"]
        if spec.n_val_score and spec.n_val_score < spec.n_val:
            val_a = val_a[: spec.n_val_score]
            val_b = val_b[: spec.n_val_score]
            val_y = val_y[: spec.n_val_score]

        def uni_score(f, g, x):  # higher is better
            return -uni_loss(f, g, x, val_y)

        def multi_score(g_m, f_a, f_b):
            h_a = encoder_apply(f_a, val_a, ecfg)
            h_b = encoder_apply(f_b, val_b, ecfg)
            return -task_loss(fusion_apply(g_m, h_a, h_b), val_y, kind)

        new_global = dict(global_models)
        infos = {}
        for mod, x_val in (("A", val_a), ("B", val_b)):
            scores = jax.vmap(lambda f, g: uni_score(f, g, x_val))(
                models[f"f_{mod}"], models[f"g_{mod}"])
            gscore = uni_score(global_models[f"f_{mod}"], global_models[f"g_{mod}"], x_val)
            omega, any_up = omega_of(scores, gscore)
            cand = {"f": models[f"f_{mod}"], "g": models[f"g_{mod}"]}
            blended = blend(cand, omega)
            new_global[f"f_{mod}"] = jax.tree.map(
                lambda b, g: jnp.where(any_up, b, g), blended["f"],
                global_models[f"f_{mod}"])
            new_global[f"g_{mod}"] = jax.tree.map(
                lambda b, g: jnp.where(any_up, b, g), blended["g"],
                global_models[f"g_{mod}"])
            infos[f"omega_{mod}"] = omega

        # multimodal: C client heads + the server's g_M^v (Eq. 8)
        scores_m = jax.vmap(lambda gm: multi_score(gm, new_global["f_A"],
                                                   new_global["f_B"]))(models["g_M"])
        score_srv = multi_score(server_gmv, new_global["f_A"], new_global["f_B"])
        scores_all = jnp.concatenate([scores_m, score_srv[None]])
        gscore = multi_score(global_models["g_M"], new_global["f_A"], new_global["f_B"])
        omega, any_up = omega_of(scores_all, gscore)
        stacked_all = jax.tree.map(lambda s, srv: jnp.concatenate([s, srv[None]]),
                                   models["g_M"], server_gmv)
        blended_m = blend(stacked_all, omega)
        new_global["g_M"] = jax.tree.map(lambda b, g: jnp.where(any_up, b, g),
                                         blended_m, global_models["g_M"])
        infos["omega_M"] = omega
        return new_global, infos

    def broadcast(new_global):
        """LocalUpdate (line 32): every slice adopts the blended weights."""
        return jax.tree.map(
            lambda g: jnp.broadcast_to(g[None], (C,) + g.shape),
            new_global)

    def round_fn(stacked, server_gmv, global_models, batch):
        stacked, loss_uni = local_unimodal(stacked, batch)
        stacked, server_gmv, loss_vfl = vfl_exchange(stacked, server_gmv, batch)
        stacked, loss_paired = local_paired(stacked, batch)
        new_global, infos = aggregate(stacked, server_gmv, global_models, batch)
        stacked = dict(
            broadcast({k: new_global[k] for k in ("f_A", "g_A", "f_B", "g_B", "g_M")}))
        server_gmv = new_global["g_M"]
        metrics = dict(loss_uni=loss_uni, loss_vfl=loss_vfl, loss_paired=loss_paired,
                       **infos)
        return stacked, server_gmv, new_global, metrics

    return round_fn


def batch_specs(spec: ShardedFedSpec):
    """ShapeDtypeStructs for one federated round's inputs (dry-run)."""
    f32 = jnp.float32
    C = spec.n_clients
    sds = jax.ShapeDtypeStruct
    return {
        "partial_a": sds((C, spec.n_partial, spec.seq_a, spec.feat_a), f32),
        "partial_ya": sds((C, spec.n_partial, spec.out_dim), f32),
        "partial_b": sds((C, spec.n_partial, spec.seq_b, spec.feat_b), f32),
        "partial_yb": sds((C, spec.n_partial, spec.out_dim), f32),
        "frag_a": sds((C, spec.n_frag, spec.seq_a, spec.feat_a), f32),
        "frag_b": sds((C, spec.n_frag, spec.seq_b, spec.feat_b), f32),
        "frag_y": sds((C, spec.n_frag, spec.out_dim), f32),
        "perm_b": sds((C * spec.n_frag,), jnp.int32),
        "paired_a": sds((C, spec.n_paired, spec.seq_a, spec.feat_a), f32),
        "paired_b": sds((C, spec.n_paired, spec.seq_b, spec.feat_b), f32),
        "paired_y": sds((C, spec.n_paired, spec.out_dim), f32),
        "val_a": sds((spec.n_val, spec.seq_a, spec.feat_a), f32),
        "val_b": sds((spec.n_val, spec.seq_b, spec.feat_b), f32),
        "val_y": sds((spec.n_val, spec.out_dim), f32),
    }
