"""Unified stacked-client round engine — the single source of Algorithm 1 math.

Both federation drivers (the in-host ``federation.Federation`` and the
TPU-pod ``federation_sharded.make_blendfl_round``) express the paper's
round through the phase functions built here. Clients live as a leading
``C`` axis on every model/optimizer/batch leaf ("stacked client pytrees"),
so one compiled program steps all clients of a phase at once:

    phase 1  ``unimodal_step``   masked per-client SGD/AdamW on both
                                 modalities in ONE step (vmap over C)
    phase 2  ``vfl_step``        joint split-training vjp: stacked client
                                 encoders + server head, alignment as a
                                 gather over the flattened (C*N) latent rows
    phase 3  ``paired_step``     masked per-client multimodal SGD/AdamW
    phase 4  ``blendavg_update`` Eq. 9-11 over the stacked candidates,
             / ``fedavg_update`` blended through the Pallas ``blend_params``
                                 kernel (in-host; interpret/ref off-TPU) or
                                 the all-reduce-lowerable reduction (SPMD)
                                 — ``EngineConfig.blend``

Static padded batch shapes + per-row masks make ragged per-client data
jit-stable: a federation compiles each phase once, regardless of client
count or which modalities a client holds. Clients that hold no rows for a
phase contribute exactly-zero gradients and are additionally excluded from
the parameter/momentum update (``_where_clients``), matching the legacy
per-client loop that skipped them outright.

The optimizer is pluggable (``EngineConfig.optimizer``: ``sgd`` | ``adamw``,
with constant/cosine schedules from ``repro.optim``). Optimizer state is a
stacked pytree too — per-client first/second moments shard and thread
through rounds alongside the params; BlendAvg broadcast replaces client
*weights* while each client keeps its own moments (standard stateful-FL
practice; with plain SGD this is exactly the paper's algorithm).

Partial participation rides on the same stacked representation:

    K-of-C sampling   ``sample_clients`` / ``scatter_clients`` gather K
                      sampled rows of every stacked leaf into (K, ...)
                      trees (``sample_opt_state`` / ``scatter_opt_state``
                      for the optimizer pytrees, whose ``step`` counter is
                      shared). The phase functions are rank-polymorphic in
                      the leading axis, so a federation that always
                      gathers a fixed K keeps the one-compile-per-phase
                      property — the sampled *indices* are data, not
                      shape.
    async BlendAvg    ``blendavg_update`` takes optional per-candidate
                      ``staleness`` (rounds since the candidate's base
                      global model was current; omegas are damped by
                      (1+s)^-``EngineConfig.staleness_exp``) and
                      ``finished`` flags (unfinished clients are masked
                      out of Eq. 9-10 exactly like empty batches are
                      masked out of the training phases).
"""
from __future__ import annotations

import dataclasses
from types import SimpleNamespace

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import aggregate
from repro.core import codec as wire
from repro.core.encoders import (
    EncoderConfig,
    encoder_apply,
    fusion_apply,
    task_scores,
)
from repro.core.state import (  # noqa: F401  (re-exported: the sampling
    CLIENT_GROUPS,              # primitives and group/moment-key constants
    OPT_MOMENT_KEYS,            # moved to the round-state block registry,
    sample_clients,             # repro.core.state, but the engine remains
    sample_opt_state,           # their historical import surface)
    scatter_clients,
    scatter_opt_state,
)
from repro.kernels.blendavg.ops import blend_params
from repro.models.common import dense, sigmoid_bce, softmax_cross_entropy

UNIMODAL_GROUPS = ("f_A", "g_A", "f_B", "g_B")
VFL_GROUPS = ("f_A", "f_B")
PAIRED_GROUPS = ("f_A", "f_B", "g_M")

_STATE_TREES = OPT_MOMENT_KEYS  # optimizer-state pytrees mirroring params


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static configuration of the round engine (hashable, jit-safe)."""

    ecfg: EncoderConfig
    kind: str  # binary | multilabel | multiclass
    optimizer: str = "sgd"  # sgd | adamw
    lr: float = 1e-3
    momentum: float = 0.0  # sgd only
    weight_decay: float = 0.0  # adamw decoupled decay
    schedule: str = "constant"  # constant | cosine
    total_steps: int = 0  # cosine horizon (optimizer steps, not rounds)
    # The server g_M^v head steps once per VFL phase while clients step
    # once per minibatch, so under a schedule it needs its own (shorter)
    # horizon. 0 = share total_steps (fine for constant lr).
    server_total_steps: int = 0
    # Async aggregation: omega damping exponent a in (1 + staleness)^-a,
    # applied when a staleness vector is passed to blendavg_update. 0
    # disables damping (stale candidates count at face value).
    staleness_exp: float = 0.5
    # Eq. 11 implementation. "pallas": the fused single-pass blend_params
    # kernel (interpret/ref path off-TPU) — right for in-host clients where
    # the stacked models live on one device. "reduce": plain weighted
    # tensordot over the client axis — right under SPMD sharding, where it
    # lowers to the masked all-reduce (Mosaic custom calls carry no GSPMD
    # partition rule, so the Pallas kernel would force an all-gather of
    # every client model).
    blend: str = "pallas"  # pallas | reduce
    # Wire codec applied to the simulated round traffic (uplink candidate
    # deltas, downlink broadcast deltas) between the phase outputs and
    # blendavg_update/fedavg_update. CodecConfig is frozen/hashable, so
    # it is static round structure: codec "none" traces no codec ops at
    # all, and switching codecs means a new round program — never a
    # retrace of an existing one.
    codec: wire.CodecConfig = wire.CodecConfig()
    # Aggregation strategy (repro.core.aggregate): which client-side
    # objective corrections (FedProx prox pull, SCAFFOLD control
    # variates) the phase functions apply, and which server-side
    # optimizer massages the blended delta. Like the codec, it is static
    # round structure — the default blendavg strategy traces zero extra
    # ops and adds zero state keys.
    strategy: aggregate.StrategyConfig = aggregate.StrategyConfig()


def make_optimizer(cfg: EngineConfig) -> optim.Optimizer:
    """Resolve ``EngineConfig`` to a ``repro.optim.Optimizer``."""
    if cfg.schedule == "cosine":
        if cfg.total_steps <= 0:
            raise ValueError("cosine schedule requires total_steps > 0")
        lr = optim.cosine_decay(cfg.lr, cfg.total_steps)
    elif cfg.schedule == "constant":
        lr = cfg.lr
    else:
        raise ValueError(f"unknown schedule {cfg.schedule!r}")
    if cfg.optimizer == "adamw":
        return optim.adamw(lr, weight_decay=cfg.weight_decay)
    if cfg.optimizer == "sgd":
        return optim.sgd(lr, momentum=cfg.momentum)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")


# ------------------------------------------------------------ masked losses --

def task_loss_rows(logits, y, kind: str):
    """Per-row task loss (mean over rows == encoders.task_loss)."""
    if kind == "multiclass":
        return softmax_cross_entropy(logits, jnp.argmax(y, axis=-1))
    return jnp.mean(sigmoid_bce(logits, y), axis=-1)


def masked_mean(rows, mask):
    """(mean over mask-selected rows, number of selected rows)."""
    n = jnp.sum(mask)
    return jnp.sum(rows * mask) / jnp.maximum(n, 1.0), n


# ------------------------------------------------ stacked-state helpers ----

def _where_clients(flag, new, old):
    """Per-client select: flag (C,) bool; every leaf has leading C axis."""
    return jax.tree.map(
        lambda n, o: jnp.where(flag.reshape(flag.shape + (1,) * (n.ndim - 1)), n, o),
        new, old)


def _state_subset(state, keys):
    """Slice the per-group optimizer-state pytrees down to ``keys``."""
    sub = {k: v for k, v in state.items() if k not in _STATE_TREES}
    for f in _STATE_TREES:
        if f in state:
            sub[f] = {k: state[f][k] for k in keys}
    return sub


def _state_merge(state, sub):
    """Write a phase's updated state slice back into the full state."""
    out = dict(state)
    for k, v in sub.items():
        out[k] = dict(state[k], **v) if k in _STATE_TREES else v
    return out


def _masked_opt_update(opt, grads, state, params, flags):
    """One optimizer step on stacked params; clients with flag False keep
    their params AND moments untouched (they did not participate)."""
    updates, new_state = opt.update(grads, state, params)
    new_params = optim.apply_updates(params, updates)
    for grp, flag in flags.items():
        if flag is None:
            continue
        new_params = dict(new_params,
                          **{grp: _where_clients(flag, new_params[grp], params[grp])})
        for f in _STATE_TREES:
            if f in new_state:
                new_state = dict(new_state, **{f: dict(
                    new_state[f],
                    **{grp: _where_clients(flag, new_state[f][grp], state[f][grp])})})
    return new_params, new_state


def stack_with(stacked_tree, extra_tree):
    """Append one unstacked candidate (e.g. the server head) to a stacked
    tree: (C, ...) ++ (...)  ->  (C+1, ...)."""
    return jax.tree.map(lambda s, e: jnp.concatenate([s, e[None]]), stacked_tree,
                        extra_tree)


# ------------------------------------------------------------- phase math --

def make_phase_fns(cfg: EngineConfig) -> SimpleNamespace:
    """Build the pure (un-jitted) phase functions closed over ``cfg``.

    Everything returned is plain jnp math over stacked pytrees — safe to
    compose under an outer jit (sharded SPMD round) or to wrap phase-by-
    phase with jit + lax.scan minibatching (in-host ``RoundEngine``).
    """
    ecfg, kind = cfg.ecfg, cfg.kind
    opt = make_optimizer(cfg)
    srv_opt = (make_optimizer(dataclasses.replace(
        cfg, total_steps=cfg.server_total_steps))
        if cfg.server_total_steps else opt)

    def unimodal_loss(f, g, x, y, mask):
        h = encoder_apply(f, x, ecfg)
        return masked_mean(task_loss_rows(dense(g, h), y, kind), mask)

    def paired_loss(f_a, f_b, g_m, x_a, x_b, y, mask):
        h_a = encoder_apply(f_a, x_a, ecfg)
        h_b = encoder_apply(f_b, x_b, ecfg)
        return masked_mean(task_loss_rows(fusion_apply(g_m, h_a, h_b), y, kind), mask)

    # ---- strategy corrections (repro.core.aggregate) ----

    def _strat_grads(grads, params, strat):
        """Apply the configured client-side strategy terms (FedProx
        proximal pull, SCAFFOLD control-variate correction) to a phase's
        grads. ``cfg.strategy`` is static: the default adds no ops, and
        ``strat`` (anchor / c_global / c_local sub-trees for the phase's
        groups) is sliced down to exactly the groups being stepped."""
        if strat is None or not cfg.strategy.client_active:
            return grads
        sub = {k: {g: v[g] for g in grads} for k, v in strat.items()}
        return aggregate.client_term(cfg.strategy, grads, params, sub)

    # ---- phase 1: local unimodal training (lines 3-8) ----

    def unimodal_step(models, opt_state, batch, strat=None):
        """One optimizer step for ALL clients x BOTH modalities.

        batch: xa (C,B,Sa,Fa) ya (C,B,O) ma (C,B)  + xb/yb/mb. Returns
        (models', opt_state', info) where info carries per-client masked
        losses and row counts for both modalities. ``strat`` is the
        optional per-client strategy block (see ``_strat_grads``).
        """
        params = {k: models[k] for k in UNIMODAL_GROUPS}

        def total(p):
            la, na = jax.vmap(unimodal_loss)(
                p["f_A"], p["g_A"], batch["xa"], batch["ya"], batch["ma"])
            lb, nb = jax.vmap(unimodal_loss)(
                p["f_B"], p["g_B"], batch["xb"], batch["yb"], batch["mb"])
            return jnp.sum(la) + jnp.sum(lb), (la, na, lb, nb)

        (_, (la, na, lb, nb)), grads = jax.value_and_grad(total, has_aux=True)(params)
        grads = _strat_grads(grads, params, strat)
        flags = {"f_A": na > 0, "g_A": na > 0, "f_B": nb > 0, "g_B": nb > 0}
        sub = _state_subset(opt_state, UNIMODAL_GROUPS)
        new_params, sub = _masked_opt_update(opt, grads, sub, params, flags)
        info = {"loss_a": la, "n_a": na, "loss_b": lb, "n_b": nb}
        return dict(models, **new_params), _state_merge(opt_state, sub), info

    # ---- phase 2: split (VFL) training on fragmented rows (lines 9-23) ----

    def vfl_step(models, server_gmv, opt_state, srv_state, batch, strat=None):
        """One joint split-training step over pre-aligned fragmented rows.

        batch: xa (C,Nfa,Sa,Fa) xb (C,Nfb,Sb,Fb); gather_a/gather_b (n,)
        index the flattened (C*Nf) latent rows into server alignment order
        (the PSI output); y (n,O); part_a/part_b (C,) bool participation.
        An optional row weight ``w`` (n,) masks aligned rows out of the
        split loss — a K-of-C sampled round keeps the alignment's static
        shape and zero-weights rows whose owner was not sampled, the same
        trick the other phases use for empty batches. All grads come from
        ONE joint vjp of the split loss — definitionally identical to the
        upload/download exchange (see repro.core.vfl).
        """
        params = {k: models[k] for k in VFL_GROUPS}

        def joint(p, gmv):
            h_a = jax.vmap(lambda f, x: encoder_apply(f, x, ecfg))(p["f_A"], batch["xa"])
            h_b = jax.vmap(lambda f, x: encoder_apply(f, x, ecfg))(p["f_B"], batch["xb"])
            h_a = h_a.reshape(-1, h_a.shape[-1])[batch["gather_a"]]
            h_b = h_b.reshape(-1, h_b.shape[-1])[batch["gather_b"]]
            rows = task_loss_rows(fusion_apply(gmv, h_a, h_b), batch["y"], kind)
            if batch.get("w") is None:
                return jnp.mean(rows)
            return masked_mean(rows, batch["w"])[0]

        loss, (grads, g_srv) = jax.value_and_grad(joint, argnums=(0, 1))(
            params, server_gmv)
        # strategy terms correct the CLIENT encoders only — the server's
        # g_M^v head never leaves the server, so it gets no prox pull
        # and no control variate
        grads = _strat_grads(grads, params, strat)
        flags = {"f_A": batch.get("part_a"), "f_B": batch.get("part_b")}
        sub = _state_subset(opt_state, VFL_GROUPS)
        new_params, sub = _masked_opt_update(opt, grads, sub, params, flags)
        upd_srv, new_srv = srv_opt.update(g_srv, srv_state, server_gmv)
        new_gmv = optim.apply_updates(server_gmv, upd_srv)
        if batch.get("w") is not None:
            # a weighted round with NO live aligned row has exactly-zero
            # grads, but AdamW would still decay the server head's
            # moments, advance its schedule step, and weight-decay the
            # params — skip the server update entirely, the same "empty
            # batch" contract the part flags enforce for clients
            live = jnp.any(batch["w"] > 0)
            new_gmv = jax.tree.map(
                lambda n, o: jnp.where(live, n, o), new_gmv, server_gmv)
            new_srv = jax.tree.map(
                lambda n, o: jnp.where(live, n, o), new_srv, srv_state)
        return (dict(models, **new_params), new_gmv,
                _state_merge(opt_state, sub), new_srv, loss)

    # ---- phase 3: local multimodal training on paired rows (lines 24-29) ----

    def paired_step(models, opt_state, batch, strat=None):
        """One optimizer step on paired rows for all paired clients.

        batch: xa (C,B,Sa,Fa) xb (C,B,Sb,Fb) y (C,B,O) m (C,B).
        """
        params = {k: models[k] for k in PAIRED_GROUPS}

        def total(p):
            l, n = jax.vmap(paired_loss)(
                p["f_A"], p["f_B"], p["g_M"], batch["xa"], batch["xb"],
                batch["y"], batch["m"])
            return jnp.sum(l), (l, n)

        (_, (l, n)), grads = jax.value_and_grad(total, has_aux=True)(params)
        grads = _strat_grads(grads, params, strat)
        flags = {k: n > 0 for k in PAIRED_GROUPS}
        sub = _state_subset(opt_state, PAIRED_GROUPS)
        new_params, sub = _masked_opt_update(opt, grads, sub, params, flags)
        info = {"loss": l, "n": n}
        return dict(models, **new_params), _state_merge(opt_state, sub), info

    # ---- phase 4: BlendAvg aggregation + broadcast (lines 30-32) ----

    def omega_from_scores(scores, global_score, staleness=None, finished=None):
        """Eq. 9-10 on device: masked, normalized improvement weights.

        Async extensions (both optional, both per-candidate vectors):
        ``finished`` (bool) masks clients that have not delivered a
        candidate this round — exactly like empty batches in the training
        phases, they contribute weight zero. ``staleness`` (rounds since
        the candidate's base global model was current) damps surviving
        improvements by (1 + s)^-``cfg.staleness_exp`` before the Eq. 10
        normalization, so a straggler's stale candidate counts less than
        an equally-improving fresh one.
        """
        delta = scores - global_score
        delta = jnp.where(jnp.isnan(delta), -jnp.inf, delta)
        if finished is not None:
            delta = jnp.where(finished, delta, -jnp.inf)
        w = jnp.where(delta > 0, delta, 0.0)
        if staleness is not None and cfg.staleness_exp:
            s = jnp.maximum(jnp.asarray(staleness, jnp.float32), 0.0)
            w = w * (1.0 + s) ** (-cfg.staleness_exp)
        tot = jnp.sum(w)
        omega = jnp.where(tot > 0, w / jnp.maximum(tot, 1e-12), jnp.zeros_like(w))
        return omega, tot > 0

    def blend_stacked(stacked_tree, omega):
        """Eq. 11: sum_k omega_k W_k over the leading candidate axis, via
        the fused Pallas kernel or the all-reduce-lowerable reduction
        (see EngineConfig.blend)."""
        om = jnp.asarray(omega, jnp.float32)
        if cfg.blend == "reduce":
            return jax.tree.map(
                lambda w: jnp.tensordot(om, w.astype(jnp.float32),
                                        axes=1).astype(w.dtype), stacked_tree)
        if cfg.blend != "pallas":
            raise ValueError(f"unknown blend impl {cfg.blend!r}")
        return blend_params(stacked_tree, om)

    def blendavg_update(global_tree, stacked_cands, scores, global_score,
                        staleness=None, finished=None):
        """Full BlendAvg step: returns (new_global, omega, any_improved);
        keeps the previous global model when nothing improves. Optional
        ``staleness``/``finished`` vectors make it the async Eq. 9-11 (see
        ``omega_from_scores``)."""
        omega, any_up = omega_from_scores(scores, global_score, staleness,
                                          finished)
        blended = blend_stacked(stacked_cands, omega)
        new = jax.tree.map(lambda b, g: jnp.where(any_up, b, g.astype(b.dtype)),
                           blended, global_tree)
        return new, omega, any_up

    def fedavg_update(global_tree, stacked_cands, weights):
        """Volume-weighted FedAvg over the stacked candidates. Zero total
        weight (e.g. a zero-overlap federation with no paired clients)
        keeps the previous global model explicitly — no silent floor."""
        weights = jnp.asarray(weights, jnp.float32)
        tot = jnp.sum(weights)
        omega = jnp.where(tot > 0, weights / jnp.maximum(tot, 1e-12),
                          jnp.zeros_like(weights))
        blended = blend_stacked(stacked_cands, omega)
        return jax.tree.map(lambda b, g: jnp.where(tot > 0, b, g.astype(b.dtype)),
                            blended, global_tree)

    def robust_update(global_tree, stacked_cands, weights):
        """Byzantine-robust phase-4 reduction (cfg.strategy is one of
        ``aggregate.ROBUST``). Returns (new_global, omega) where omega is
        the effective per-candidate weight vector (for telemetry — the
        sched block's omega EMA — not for blending):

        - krum: the multi-Krum survivor mask multiplies the volume
          weights and the product goes through the ordinary
          ``fedavg_update`` — at n_malicious = 0 the mask is all-ones,
          so krum is fedavg bit-for-bit;
        - trimmed_mean at trim 0 delegates to ``fedavg_update`` with
          uniform weights (the documented degenerate case);
        - median / trimmed_mean (trim > 0) are coordinate-wise order
          statistics; omega reports the uniform 1/n they treat honest
          candidates with.
        """
        scfg = cfg.strategy
        n = len(jnp.asarray(weights, jnp.float32))
        if scfg.name == "krum":
            mask = aggregate.krum_mask(stacked_cands, scfg.n_malicious)
            w = jnp.asarray(weights, jnp.float32) * mask
            new = fedavg_update(global_tree, stacked_cands, w)
            tot = jnp.sum(w)
            omega = jnp.where(tot > 0, w / jnp.maximum(tot, 1e-12),
                              jnp.zeros_like(w))
            return new, omega
        uniform = jnp.full(n, 1.0 / n, jnp.float32)
        if scfg.name == "trimmed_mean":
            if scfg.n_malicious == 0:
                return fedavg_update(global_tree, stacked_cands,
                                     uniform), uniform
            new = aggregate.trimmed_mean_tree(stacked_cands, scfg.n_malicious)
        elif scfg.name == "median":
            new = aggregate.coordinate_median_tree(stacked_cands)
        else:
            raise ValueError(f"not a robust strategy: {scfg.name!r}")
        new = jax.tree.map(lambda b, g: b.astype(g.dtype), new, global_tree)
        return new, uniform

    def broadcast(global_tree, n_clients: int):
        """LocalUpdate (line 32): every client adopts the blended weights."""
        return jax.tree.map(
            lambda g: jnp.broadcast_to(g[None], (n_clients,) + g.shape), global_tree)

    # ---- wire codec: between the phase outputs and phase-4 aggregation ----

    def codec_uplink(trained, base, resid):
        """Client -> server wire for the stacked phase-3 candidates.

        Each participant ships its training delta vs. the ``base`` tree
        it started the round from (+ its error-feedback residual row)
        through the lossy codec; aggregation then scores and blends the
        DECODED candidates — exactly what a real server would hold.
        Returns (decoded candidate tree, new residual rows).
        """
        return wire.uplink_roundtrip(trained, base, resid, cfg.codec)

    def codec_downlink(new_global, prev_global, resid):
        """Server -> clients broadcast wire: the blend delta vs. the
        global the clients already hold, through the same codec. The
        decoded tree becomes the clients' view of the global model (the
        server's own g_M^v head never crosses a wire and keeps the true
        blend). Returns (decoded global tree, new residual)."""
        return wire.downlink_roundtrip(new_global, prev_global, resid,
                                       cfg.codec)

    # ---- aggregation-strategy round hooks (repro.core.aggregate) ----

    def scaffold_round(c_global, c_local, anchor, trained, steps, frac):
        """SCAFFOLD Option-II control-variate update for the round's
        participants, scaled by the client lr this engine steps with.
        See ``aggregate.scaffold_round``."""
        return aggregate.scaffold_round(cfg.strategy, c_global, c_local,
                                        anchor, trained, steps, cfg.lr, frac)

    def server_update(srv, new_global, prev_global):
        """Server-side FedAdam/momentum on the blended delta (see
        ``aggregate.server_update``)."""
        return aggregate.server_update(cfg.strategy, srv, new_global,
                                       prev_global)

    return SimpleNamespace(
        opt=opt, srv_opt=srv_opt, unimodal_loss=unimodal_loss,
        paired_loss=paired_loss,
        unimodal_step=unimodal_step, vfl_step=vfl_step, paired_step=paired_step,
        omega_from_scores=omega_from_scores, blend_stacked=blend_stacked,
        blendavg_update=blendavg_update, fedavg_update=fedavg_update,
        robust_update=robust_update,
        broadcast=broadcast, codec_uplink=codec_uplink,
        codec_downlink=codec_downlink, scaffold_round=scaffold_round,
        server_update=server_update)


# ------------------------------------------------------- in-host driver ----

class RoundEngine:
    """Jitted minibatching driver over the shared phase functions.

    Owns exactly one compiled program per phase: scan over static padded
    minibatches, vmap over the stacked client axis. Per-batch losses stay
    on device; a phase returns ONE scalar (a single host sync per phase).
    """

    def __init__(self, cfg: EngineConfig, batch_size: int):
        self.cfg = cfg
        self.batch_size = int(batch_size)
        self.fns = make_phase_fns(cfg)
        self.opt = self.fns.opt
        self.unimodal_phase = jax.jit(self._build_unimodal_phase())
        self.paired_phase = jax.jit(self._build_paired_phase())
        self.vfl_phase = jax.jit(self.fns.vfl_step)
        self.uni_scores = jax.jit(self._build_uni_scores())
        self.multi_scores = jax.jit(self._build_multi_scores())
        # wire-codec stages (identity-free: only jitted when a codec is
        # configured, so the uncompressed engine traces no codec ops)
        if cfg.codec.enabled:
            self.codec_uplink = jax.jit(self.fns.codec_uplink)
            self.codec_downlink = jax.jit(self.fns.codec_downlink)
        # strategy round hooks, same contract: only jitted when the
        # strategy needs them, so the default engine traces nothing new
        if cfg.strategy.control:
            self.scaffold_round = jax.jit(self.fns.scaffold_round)
        if cfg.strategy.server_opt != "none":
            self.server_update = jax.jit(self.fns.server_update)

    def init_opt_state(self, stacked_models):
        return self.opt.init({k: stacked_models[k] for k in CLIENT_GROUPS})

    def init_server_opt_state(self, server_gmv):
        return self.fns.srv_opt.init(server_gmv)

    # -- phase drivers (jitted once each in __init__) --

    def _build_unimodal_phase(self):
        fns, B = self.fns, self.batch_size

        def phase(models, opt_state, data, key, strat=None):
            """data: xa (C,N,Sa,Fa) ya (C,N,O) ma (C,N) + xb/yb/mb, with
            N a multiple of the batch size. Shuffles per client on device,
            scans the minibatches, returns the mean of valid per-(client,
            batch, modality) losses — the legacy loop's logging metric.
            ``strat`` is the optional per-client strategy block (anchor /
            control variates), constant across the scanned minibatches."""
            C, n_rows = data["ma"].shape
            nb = n_rows // B
            ka, kb = jax.random.split(key)

            def perms(k):
                return jax.vmap(lambda kk: jax.random.permutation(kk, n_rows))(
                    jax.random.split(k, C))

            idx_a, idx_b = perms(ka), perms(kb)
            take = jax.vmap(lambda arr, sel: arr[sel])

            def body(carry, t):
                models, opt_state = carry
                sa = jax.lax.dynamic_slice_in_dim(idx_a, t * B, B, axis=1)
                sb = jax.lax.dynamic_slice_in_dim(idx_b, t * B, B, axis=1)
                batch = {"xa": take(data["xa"], sa), "ya": take(data["ya"], sa),
                         "ma": take(data["ma"], sa),
                         "xb": take(data["xb"], sb), "yb": take(data["yb"], sb),
                         "mb": take(data["mb"], sb)}
                models, opt_state, info = fns.unimodal_step(models, opt_state,
                                                            batch, strat)
                return (models, opt_state), info

            (models, opt_state), infos = jax.lax.scan(
                body, (models, opt_state), jnp.arange(nb))
            valid_a = (infos["n_a"] > 0).astype(jnp.float32)
            valid_b = (infos["n_b"] > 0).astype(jnp.float32)
            tot = (jnp.sum(infos["loss_a"] * valid_a)
                   + jnp.sum(infos["loss_b"] * valid_b))
            cnt = jnp.sum(valid_a) + jnp.sum(valid_b)
            loss = jnp.where(cnt > 0, tot / jnp.maximum(cnt, 1.0), jnp.nan)
            return models, opt_state, loss

        return phase

    def _build_paired_phase(self):
        fns, B = self.fns, self.batch_size

        def phase(models, opt_state, data, key, strat=None):
            C, n_rows = data["m"].shape
            nb = n_rows // B
            idx = jax.vmap(lambda kk: jax.random.permutation(kk, n_rows))(
                jax.random.split(key, C))
            take = jax.vmap(lambda arr, sel: arr[sel])

            def body(carry, t):
                models, opt_state = carry
                sel = jax.lax.dynamic_slice_in_dim(idx, t * B, B, axis=1)
                batch = {"xa": take(data["xa"], sel), "xb": take(data["xb"], sel),
                         "y": take(data["y"], sel), "m": take(data["m"], sel)}
                models, opt_state, info = fns.paired_step(models, opt_state,
                                                          batch, strat)
                return (models, opt_state), info

            (models, opt_state), infos = jax.lax.scan(
                body, (models, opt_state), jnp.arange(nb))
            valid = (infos["n"] > 0).astype(jnp.float32)
            cnt = jnp.sum(valid)
            loss = jnp.where(cnt > 0,
                             jnp.sum(infos["loss"] * valid) / jnp.maximum(cnt, 1.0),
                             jnp.nan)
            return models, opt_state, loss

        return phase

    # -- stacked evaluation (aggregation scoring) --

    def _build_uni_scores(self):
        ecfg, kind = self.cfg.ecfg, self.cfg.kind

        def scores(f_stack, g_stack, x):
            """(C,...) stacked unimodal models -> (C, Nv, O) val scores."""
            def one(f, g):
                return task_scores(dense(g, encoder_apply(f, x, ecfg)), kind)

            return jax.vmap(one)(f_stack, g_stack)

        return scores

    def _build_multi_scores(self):
        ecfg, kind = self.cfg.ecfg, self.cfg.kind

        def scores(f_a, f_b, gm_stack, x_a, x_b):
            """Stacked fusion heads on the (shared) global encoders."""
            h_a = encoder_apply(f_a, x_a, ecfg)
            h_b = encoder_apply(f_b, x_b, ecfg)
            return jax.vmap(
                lambda gm: task_scores(fusion_apply(gm, h_a, h_b), kind))(gm_stack)

        return scores
