"""Wire codec: lossy compression of the per-round model transfers.

At production client counts the BlendFL round bottleneck is bytes on
the wire, not FLOPs: every round ships full fp32 client candidates up
(Algorithm 1 phases 1-3 outputs) and a full blended global model back
(phase 4 broadcast). This module makes that traffic pluggable:

- ``none``       4-byte floats, the uncompressed baseline;
- ``int8``       per-leaf symmetric int8 (scale = abs-max / 127);
- ``topk``       magnitude top-k delta sparsification (values + indices);
- ``int8_topk``  both composed: top-k selection, int8 payload values.

All lossy codecs operate on *deltas* with error feedback: each sender
compresses ``c_t = delta_t + resid_{t-1}`` and carries the quantization
error ``resid_t = c_t - dec(c_t)`` into the next round, so information
dropped on one round is retransmitted later instead of lost (the
telescoping identity  sum(dec_t) = sum(delta_t) - resid_T  holds
exactly). Residuals are ordinary round-state pytrees — threaded through
checkpoints exactly like ``sched`` telemetry and opt moments, so
killed-and-resumed runs stay bit-identical under ``--selftest-resume``.

The hot path (sparsify + quantize + dequantize in one pass per
flattened leaf) is the fused Pallas kernel in
``repro.kernels.wire_codec``. Byte accounting is analytic (wire-format
arithmetic on static shapes — no device sync, no trace impact).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.wire_codec.ops import wire_codec_roundtrip

CODECS = ("none", "int8", "topk", "int8_topk")


@dataclasses.dataclass(frozen=True)
class CodecConfig:
    """Static wire-codec configuration (hashable: lives in EngineConfig).

    name: one of CODECS. topk_frac: fraction of entries kept per leaf by
    the sparsifying codecs (k = max(1, ceil(frac * n))). error_feedback:
    carry the per-sender compression residual into the next round.
    """
    name: str = "none"
    topk_frac: float = 0.25
    error_feedback: bool = True

    def __post_init__(self):
        if self.name not in CODECS:
            raise ValueError(f"codec {self.name!r} not in {CODECS}")
        if not 0.0 < self.topk_frac <= 1.0:
            raise ValueError(f"topk_frac must be in (0, 1], got {self.topk_frac}")

    @property
    def enabled(self) -> bool:
        return self.name != "none"

    @property
    def quantize(self) -> bool:
        return self.name in ("int8", "int8_topk")

    @property
    def sparsify(self) -> bool:
        return self.name in ("topk", "int8_topk")


def make_codec(name: str, topk_frac: float = 0.25) -> CodecConfig:
    return CodecConfig(name=name, topk_frac=topk_frac)


def topk_k(n: int, frac: float) -> int:
    """Entries kept per flattened leaf of n elements."""
    return max(1, min(n, math.ceil(frac * n)))


# ------------------------------------------------------------ tree algebra --

def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def zeros_like_tree(tree):
    """f32 residual buffers matching a model tree's shapes."""
    return jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), tree)


# ----------------------------------------------------------- wire roundtrip --

def encode_decode_stacked(tree, cfg: CodecConfig):
    """Lossy wire round-trip of a stacked tree (leaves (L, ...)).

    Each of the L rows is an independent message: per (row, leaf) scale
    and threshold, so one client's outlier magnitudes cannot wash out
    another's quantization grid. Returns a tree of the same shapes.
    """
    if not cfg.enabled:
        return tree

    def leaf(x):
        l = x.shape[0]
        flat = x.reshape(l, -1)
        k = topk_k(flat.shape[1], cfg.topk_frac) if cfg.sparsify else None
        out = wire_codec_roundtrip(flat, k=k, quantize=cfg.quantize)
        return out.reshape(x.shape).astype(x.dtype)

    return jax.tree.map(leaf, tree)


def encode_decode_tree(tree, cfg: CodecConfig):
    """Lossy wire round-trip of a single (unstacked) message tree."""
    if not cfg.enabled:
        return tree

    def leaf(x):
        flat = x.reshape(1, -1)
        k = topk_k(flat.shape[1], cfg.topk_frac) if cfg.sparsify else None
        out = wire_codec_roundtrip(flat, k=k, quantize=cfg.quantize)
        return out.reshape(x.shape).astype(x.dtype)

    return jax.tree.map(leaf, tree)


# ---------------------------------------------------------- codec stages ----

def _roundtrip(current, reference, resid, cfg: CodecConfig, enc_dec):
    """Shared delta + error-feedback wire round-trip.

    The receiver reconstructs ``reference + dec(c)``; we compute the
    mathematically-equal form ``current + resid - err`` (err = c - dec,
    the new residual) so that an identity codec — ``topk`` at frac=1.0 —
    reconstructs ``current`` BIT-exactly instead of picking up the
    float rounding of ``reference + (current - reference)``.
    """
    delta = tree_sub(current, reference)
    c = tree_add(delta, resid) if cfg.error_feedback else delta
    err = tree_sub(c, enc_dec(c, cfg))
    if cfg.error_feedback:
        return tree_sub(tree_add(current, resid), err), err
    return tree_sub(current, err), resid


def uplink_roundtrip(trained, base, resid, cfg: CodecConfig):
    """Client -> server wire for stacked candidates (leaves (L, ...)).

    Each row's message is its training delta vs. the base it started the
    round from, plus its error-feedback residual. Returns the decoded
    candidates (what the server aggregates/scores) and the new residual.
    """
    return _roundtrip(trained, base, resid, cfg, encode_decode_stacked)


def downlink_roundtrip(new_global, prev_global, resid, cfg: CodecConfig):
    """Server -> clients broadcast wire for one (unstacked) global tree.

    The message is the blend delta vs. the global the clients already
    hold, plus the server-side residual. Returns the clients' decoded
    view of the new global and the new residual.
    """
    return _roundtrip(new_global, prev_global, resid, cfg, encode_decode_tree)


# --------------------------------------------------------- byte accounting --

def leaf_payload_bytes(n: int, cfg: CodecConfig, dtype_bytes: int = 4) -> int:
    """Wire bytes for one flattened leaf of n elements.

    none: n dense values. int8: n 1-byte values + a 4-byte scale. topk:
    k (value, index) pairs — indices are 2 bytes while they fit, else 4.
    int8_topk: k (1-byte value, index) pairs + the 4-byte scale.
    """
    if not cfg.enabled:
        return dtype_bytes * n
    if cfg.name == "int8":
        return n + 4
    k = topk_k(n, cfg.topk_frac)
    idx_bytes = 2 if n <= 65536 else 4
    if cfg.name == "topk":
        return k * (dtype_bytes + idx_bytes)
    return 4 + k * (1 + idx_bytes)  # int8_topk


def tree_payload_bytes(tree, cfg: CodecConfig, dtype_bytes: int = 4) -> int:
    """Wire bytes for one message carrying every leaf of a model tree."""
    return sum(leaf_payload_bytes(int(np.prod(x.shape)), cfg, dtype_bytes)
               for x in jax.tree.leaves(tree))


def round_bytes(template, cfg: CodecConfig, n_up: int, n_down: int) -> dict:
    """Per-round traffic for a federation whose per-link message is one
    ``template`` tree (a single client's model groups, unstacked):
    n_up candidate uploads + n_down broadcast downloads."""
    per_msg = tree_payload_bytes(template, cfg)
    dense = tree_payload_bytes(template, CodecConfig())
    return {
        "bytes_per_message": per_msg,
        "bytes_up": n_up * per_msg,
        "bytes_down": n_down * per_msg,
        "bytes_per_round": (n_up + n_down) * per_msg,
        "dense_bytes_per_round": (n_up + n_down) * dense,
        "compression_ratio": dense / per_msg,
    }
