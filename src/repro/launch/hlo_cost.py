"""While-loop-aware cost analysis over post-SPMD scheduled HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts every computation ONCE:
anything inside a ``while`` body (every ``lax.scan`` — our layer stack,
microbatch loop, recurrent cells) is under-counted by its trip count.
This walker parses ``compiled.as_text()`` and recursively multiplies
``while`` bodies by their ``backend_config known_trip_count``, giving:

    flops        MXU work (dot ops; elementwise ignored — transformers
                 are >99% matmul flops)
    bytes        HBM traffic proxy: operand+result bytes of every
                 scheduled (post-fusion) op — each fusion reads its
                 inputs and writes its outputs exactly once
    coll_bytes   per-collective-kind result-buffer bytes (the shard each
                 device emits; ring-transfer approximation)
    coll_counts  collective op counts (loop-multiplied)

Shapes in the post-SPMD module are PER-DEVICE, so all numbers are
per-device. The roofline layer scales by chip count where a global figure
is needed.
"""
from __future__ import annotations

import dataclasses
import json
import re
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1, "s32": 4, "u32": 4,
    "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota"}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s+->\s+.*\{\s*$")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w.\-]+)\s+=\s+(.*)$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _type_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> list[list[int]]:
    """All array shapes in a type string (first = the result array)."""
    out = []
    for _, dims in _SHAPE_RE.findall(type_str):
        out.append([int(d) for d in dims.split(",") if d])
    return out


def _split_type(rest: str) -> tuple[str, str]:
    """Split 'TYPE op(...)' -> (TYPE, remainder). Handles tuple types."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rest[: i + 1], rest[i + 1 :].strip()
    i = rest.find(" ")
    return rest[:i], rest[i + 1 :].strip()


@dataclasses.dataclass
class _Op:
    name: str
    type_str: str
    kind: str
    rest: str  # everything after the kind word


@dataclasses.dataclass
class _Comp:
    name: str
    params: dict
    ops: list
    types: dict  # local symbol -> type string


def parse_hlo(text: str) -> dict:
    comps: dict[str, _Comp] = {}
    cur = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            name = m.group(2)
            params = {}
            # split header params respecting nesting
            depth = 0
            tok = ""
            items = []
            for ch in m.group(3):
                if ch in "([":
                    depth += 1
                elif ch in ")]":
                    depth -= 1
                if ch == "," and depth == 0:
                    items.append(tok)
                    tok = ""
                else:
                    tok += ch
            if tok.strip():
                items.append(tok)
            for it in items:
                if ":" in it:
                    pname, ptype = it.split(":", 1)
                    params[pname.strip().lstrip("%")] = ptype.strip()
            cur = _Comp(name, params, [], dict(params))
            comps[name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        type_str, remainder = _split_type(rest)
        kind = remainder.split("(")[0].strip()
        cur.types[name] = type_str
        cur.ops.append(_Op(name, type_str, kind, remainder))
    return comps


def _dot_flops(op: _Op, comp: _Comp) -> float:
    """2 * prod(result dims) * prod(lhs contracting dims)."""
    result_shapes = _shape_dims(op.type_str)
    result = result_shapes[0] if result_shapes else []
    operands = _OPERAND_RE.findall(op.rest.split("),")[0] + ")")
    lhs_type = comp.types.get(operands[0], "") if operands else ""
    lhs_shapes = _shape_dims(lhs_type)
    lhs = lhs_shapes[0] if lhs_shapes else []
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    if m and lhs:
        for d in m.group(1).split(","):
            if d:
                contract *= lhs[int(d)]
    n = 1
    for d in result:
        n *= d
    return 2.0 * n * contract


def _site_of(op: _Op) -> str:
    m = re.search(r'op_name="([^"]*)"', op.rest)
    return m.group(1) if m else op.name


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})
    dot_flops_by_site: dict = dataclasses.field(default_factory=dict)
    bytes_by_site: dict = dataclasses.field(default_factory=dict)
    coll_by_site: dict = dataclasses.field(default_factory=dict)

    def _bump(self, d: dict, k: str, v: float):
        d[k] = d.get(k, 0.0) + v

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in _COLLECTIVES:
            self.coll_bytes[k] += other.coll_bytes[k] * mult
            self.coll_counts[k] += int(other.coll_counts[k] * mult)
        for src, dst in ((other.dot_flops_by_site, self.dot_flops_by_site),
                         (other.bytes_by_site, self.bytes_by_site),
                         (other.coll_by_site, self.coll_by_site)):
            for k, v in src.items():
                dst[k] = dst.get(k, 0.0) + v * mult


def _operand_names(op: _Op) -> list:
    args = op.rest[op.rest.find("(") + 1 :]
    depth = 1
    end = 0
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERAND_RE.findall(args[:end])


_SLICE_OPS = {"dynamic-slice", "gather"}


def _fusion_param_reads(callee: _Comp) -> dict:
    """param index -> bytes actually read from HBM for that parameter
    (absent = full tensor).

    - consumed ONLY as the sliced operand of dynamic-slice/gather: read
      slice-wise (lax.scan per-iteration parameter slicing is O(slice))
    - consumed ONLY as the TARGET (operand 0) of the root
      dynamic-update-slice: 0 bytes — the buffer is updated in place
      (aliased), only the updated region moves
    """
    if hasattr(callee, "_param_reads"):
        return callee._param_reads
    reads = {}
    pnames = list(callee.params)
    root = callee.ops[-1] if callee.ops else None
    for i, pname in enumerate(pnames):
        sliced_bytes = 0.0
        ok = None
        for op in callee.ops:
            if op.kind == "parameter":
                continue
            names = _operand_names(op)
            if pname not in names:
                continue
            if op.kind in _SLICE_OPS and names and names[0] == pname:
                sliced_bytes += _type_bytes(op.type_str)
                ok = True if ok is None else ok
            elif (op is root and op.kind == "dynamic-update-slice"
                  and names and names[0] == pname and names.count(pname) == 1):
                ok = True if ok is None else ok  # in-place target: free
            else:
                ok = False
        if ok:
            reads[i] = sliced_bytes
    callee._param_reads = reads
    return reads


def _fusion_result_bytes(callee: _Comp) -> float | None:
    """Result bytes of a fusion: update-size when the root is a
    dynamic-update-slice (output aliases the target buffer), else None
    (= use the full result type)."""
    if not callee.ops:
        return None
    root = callee.ops[-1]
    if root.kind == "dynamic-update-slice":
        names = _operand_names(root)
        if len(names) >= 2:
            return float(2.0 * _type_bytes(callee.types.get(names[1], "")))
    return None


def _op_operand_bytes(op: _Op, comp: _Comp, comps: dict | None = None) -> float:
    names = _operand_names(op)
    if op.kind == "fusion" and comps is not None:
        m = _CALLS_RE.search(op.rest)
        callee = comps.get(m.group(1)) if m else None
        if callee is not None:
            reads = _fusion_param_reads(callee)
            total = 0.0
            for i, n in enumerate(names):
                if i in reads:
                    total += reads[i]
                else:
                    total += _type_bytes(comp.types.get(n, ""))
            return float(total)
    if op.kind == "copy":
        # loop-carried layout copies: read + write the buffer once; the
        # operand IS the result size (avoid double counting via generic)
        return float(_type_bytes(op.type_str))
    if op.kind in _SLICE_OPS and names:
        # read = slice size (result), not the full operand
        others = sum(_type_bytes(comp.types.get(n, "")) for n in names[1:])
        return float(_type_bytes(op.type_str) + min(others, _type_bytes(op.type_str)))
    if op.kind in ("dynamic-update-slice", "scatter") and len(names) >= 2:
        # in-place: read update + write region; the big buffer is aliased
        upd = _type_bytes(comp.types.get(names[1], ""))
        return float(2.0 * upd)
    return float(sum(_type_bytes(comp.types.get(n, "")) for n in names))


def analyze_computation(comp_name: str, comps: dict, memo: dict) -> Cost:
    if comp_name in memo:
        return memo[comp_name]
    comp = comps.get(comp_name)
    cost = Cost()
    memo[comp_name] = cost  # break cycles defensively
    if comp is None:
        return cost
    for op in comp.ops:
        kind = op.kind
        if kind in _FREE_OPS:
            continue
        base_kind = kind.removesuffix("-start").removesuffix("-done")
        if base_kind in _COLLECTIVES:
            if kind.endswith("-done"):
                continue
            b = float(_type_bytes(op.type_str))
            cost.coll_bytes[base_kind] += b
            cost.coll_counts[base_kind] += 1
            cost.bytes += b + _op_operand_bytes(op, comp)
            cost._bump(cost.coll_by_site, f"{base_kind}:{_site_of(op)}", b)
            continue
        if kind == "while":
            m = _TRIP_RE.search(op.rest)
            trips = int(m.group(1)) if m else 1
            mcb = _COND_BODY_RE.search(op.rest)
            if mcb:
                cond, body = mcb.group(1), mcb.group(2)
                cost.add(analyze_computation(body, comps, memo), trips)
                cost.add(analyze_computation(cond, comps, memo), trips)
            continue
        if kind == "conditional":
            m = _BRANCHES_RE.search(op.rest)
            if m:
                branch_costs = [analyze_computation(b.strip().lstrip("%"), comps, memo)
                                for b in m.group(1).split(",")]
                if branch_costs:
                    # upper bound: the most expensive branch
                    best = max(branch_costs, key=lambda c: c.flops + c.bytes)
                    cost.add(best)
            cost.bytes += _type_bytes(op.type_str) + _op_operand_bytes(op, comp)
            continue
        # generic op: HBM traffic = operands + result
        if kind in ("dynamic-update-slice", "scatter"):
            # result aliases the input buffer; only the updated region moves
            op_bytes = _op_operand_bytes(op, comp, comps)
        else:
            result_bytes = _type_bytes(op.type_str)
            if kind == "fusion":
                m = _CALLS_RE.search(op.rest)
                callee = comps.get(m.group(1)) if m else None
                if callee is not None:
                    rb = _fusion_result_bytes(callee)
                    if rb is not None:
                        result_bytes = rb
            op_bytes = result_bytes + _op_operand_bytes(op, comp, comps)
        cost.bytes += op_bytes
        cost._bump(cost.bytes_by_site, _site_of(op), op_bytes)
        if kind == "dot":
            f = _dot_flops(op, comp)
            cost.flops += f
            site = _site_of(op)
            cost.dot_flops_by_site[site] = cost.dot_flops_by_site.get(site, 0.0) + f
        elif kind in ("fusion", "call", "custom-call", "reduce", "sort", "map",
                      "scatter", "select-and-scatter", "reduce-window"):
            m = _CALLS_RE.search(op.rest) or _TO_APPLY_RE.search(op.rest)
            if m:
                sub = analyze_computation(m.group(1), comps, memo)
                # flops recurse (dots can hide in fusions); bytes already
                # counted at this call site — internal traffic is on-chip
                cost.flops += sub.flops
                for k in _COLLECTIVES:
                    cost.coll_bytes[k] += sub.coll_bytes[k]
                    cost.coll_counts[k] += sub.coll_counts[k]
                for k, v in sub.dot_flops_by_site.items():
                    cost.dot_flops_by_site[k] = cost.dot_flops_by_site.get(k, 0.0) + v
    return cost


def analyze(hlo_text: str) -> dict:
    """Per-device cost of the ENTRY computation, loop-multiplied."""
    comps = parse_hlo(hlo_text)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo_text, re.MULTILINE)
    if m:
        entry = m.group(1)
    else:  # fall back: last computation
        entry = list(comps)[-1] if comps else ""
    memo: dict = {}
    cost = analyze_computation(entry, comps, memo)

    def top(d, n=25):
        return dict(sorted(d.items(), key=lambda kv: -kv[1])[:n])

    return {
        "flops": cost.flops,
        "bytes": cost.bytes,
        "coll_bytes": dict(cost.coll_bytes),
        "coll_counts": dict(cost.coll_counts),
        "coll_total": float(sum(cost.coll_bytes.values())),
        "top_dot_sites": top(cost.dot_flops_by_site),
        "top_bytes_sites": top(cost.bytes_by_site),
        "top_coll_sites": top(cost.coll_by_site),
    }
