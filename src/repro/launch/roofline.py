"""Roofline term extraction from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.

    compute term    = HLO_FLOPs / (chips x peak)
    memory term     = HLO_bytes / (chips x hbm_bw)
    collective term = collective_bytes / (chips x link_bw)

``cost_analysis()`` reports the pre-partitioning (global) module, so the
FLOP/byte totals divide by chip count. Collective bytes are NOT in
cost_analysis: we parse the POST-partitioning HLO (``compiled.as_text()``)
whose shapes are per-device, sum the result-buffer sizes of every
collective op, and scale by chips to get the global figure the formula
expects (ring-transfer approximation: each chip moves ~the shard it
emits per collective).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _buffer_bytes(shape_str: str) -> int:
    """Total bytes of all tensors in an HLO result type string (handles
    tuples by summing every typed buffer that appears)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind (result-size sum)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match ops like:  %all-reduce.5 = f32[...] all-reduce(...)
        m = re.match(r"%?[\w.-]+ = (.+?) (all-gather|all-reduce|reduce-scatter|"
                     r"all-to-all|collective-permute)", s)
        if not m:
            continue
        kind = m.group(2)
        # skip -start/-done duplicates (count the -start only)
        if f"{kind}-done" in s:
            continue
        out[kind] += _buffer_bytes(m.group(1))
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # global
    hlo_bytes: float  # global
    coll_bytes_per_dev: float
    model_flops: float
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0

    def finalize(self) -> "Roofline":
        self.t_compute = self.hlo_flops / (self.chips * PEAK_FLOPS)
        self.t_memory = self.hlo_bytes / (self.chips * HBM_BW)
        # per-dev coll bytes / link bw == global/(chips*link_bw)
        self.t_collective = self.coll_bytes_per_dev / ICI_BW
        return self

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def step_time(self) -> float:
        """No-overlap roofline estimate of one step."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_gflops": self.hlo_flops / 1e9,
            "hlo_gbytes": self.hlo_bytes / 1e9,
            "coll_mb_per_dev": self.coll_bytes_per_dev / 1e6,
            "t_compute_ms": self.t_compute * 1e3,
            "t_memory_ms": self.t_memory * 1e3,
            "t_collective_ms": self.t_collective * 1e3,
            "bottleneck": self.bottleneck,
            "model_gflops": self.model_flops / 1e9,
            "useful_ratio": self.useful_ratio,
        }


def model_flops(cfg, shape_kind: str, batch: int, seq: int) -> float:
    """6·N·D (train) / 2·N·D (prefill) / 2·N_active·B (decode, per token)."""
    n_active = cfg.n_active_params
    if shape_kind == "train":
        return 6.0 * n_active * batch * seq
    if shape_kind == "prefill":
        return 2.0 * n_active * batch * seq
    return 2.0 * n_active * batch  # one token per sequence
