"""Training driver: real steps on the local devices.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-350m \
        --reduced --steps 50 --batch 8 --seq 128 [--ckpt-dir /tmp/ckpt]

On the CPU container this runs REDUCED configs (the full configs are
exercised via the dry-run); on a real TPU slice the same driver runs the
full config with the production mesh and sharding rules unchanged.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import ALIASES, get_config
from repro.data.pipeline import token_batches
from repro.launch import shardings as sh
from repro.launch.mesh import make_host_mesh
from repro.models import backbone as bb
from repro.optim import global_norm_clip


def build_batch(cfg, batch, seq, rng):
    out = {}
    toks = rng.integers(0, cfg.vocab_size, size=(batch, seq + 1)).astype(np.int64)
    toks[:, 2::2] = toks[:, 1:-1:2]  # learnable bigram structure
    if cfg.frontend == "vision_stub":
        out["patches"] = rng.normal(0, 1, (batch, cfg.vision_tokens,
                                           cfg.frontend_dim)).astype(np.float32)
    if cfg.is_encdec:
        out["frames"] = rng.normal(0, 1, (batch, 64, cfg.frontend_dim)).astype(np.float32)
    out["tokens"] = toks[:, :-1].astype(np.int32)
    out["labels"] = toks[:, 1:].astype(np.int32)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(ALIASES.get(args.arch, args.arch))
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} reduced={args.reduced} params~{cfg.n_params/1e6:.1f}M")

    mesh = make_host_mesh(args.model_parallel)
    opt = optim.adamw(optim.linear_warmup_cosine(args.lr, warmup=10,
                                                 total_steps=args.steps))
    step_fn = bb.make_train_step(cfg, opt, microbatches=args.microbatches)

    params = bb.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start = latest_step(args.ckpt_dir)
        try:
            # restore params AND optimizer state together: restoring
            # params alone into a fresh opt.init() would zero the AdamW
            # moments and reset the schedule step, silently replaying
            # warmup on resume
            restored = restore_checkpoint(
                args.ckpt_dir, {"params": params, "opt_state": opt_state},
                step=start)
            params, opt_state = restored["params"], restored["opt_state"]
            print(f"restored step {start} (params + opt_state) from {args.ckpt_dir}")
        except KeyError:  # legacy params-only layout: loudly degrade
            params = restore_checkpoint(args.ckpt_dir, params, step=start)
            print(f"restored step {start} from LEGACY params-only checkpoint "
                  f"{args.ckpt_dir}: optimizer moments/schedule step start "
                  "fresh (warmup replays)")

    p_specs = jax.eval_shape(lambda: params)
    jstep = jax.jit(step_fn,
                    in_shardings=(sh.param_shardings(mesh, p_specs, fsdp=False),
                                  None, None))
    rng = np.random.default_rng(0)
    t0 = time.time()
    with mesh:
        for i in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     build_batch(cfg, args.batch, args.seq, rng).items()}
            params, opt_state, metrics = jstep(params, opt_state, batch)
            if (i + 1) % args.log_every == 0:
                loss = float(metrics["loss"])
                print(f"step {i+1:5d} loss {loss:.4f} "
                      f"({(time.time()-t0)/(i+1-start):.2f}s/step)", flush=True)
            if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, i + 1,
                                {"params": params, "opt_state": opt_state},
                                {"arch": cfg.name, "loss": float(metrics['loss'])})
    print("done.")


if __name__ == "__main__":
    main()
