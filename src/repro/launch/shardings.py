"""Sharding rules: pytree-of-NamedSharding factories for params, optimizer
state, batches and decode caches.

Baseline policy (the §Perf loop iterates from here):

- weights: Megatron-style tensor parallel over "model" (column-parallel
  for input projections / up, row-parallel for output projections /
  down) + FSDP over "data" on the other matrix dim — so a 132 B MoE
  shards over all 256 chips of a pod. "pod" replicates params (pods are
  DP replicas; gradients all-reduce over "pod").
- MoE experts: expert-parallel over "model", FSDP over "data" on d_model.
- batch: data-parallel over ("pod",) + "data".
- decode caches: batch over "data", everything else replicated
  (long_500k has batch 1 -> fully replicated, model-parallel compute).

Rules are matched by parameter path NAME, with a size-aware fallback, so
new modules get a sane default instead of a silent replicate.
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


# name fragment -> spec template for the trailing (non-stack) dims.
# "S" = FSDP axis ("data"), "M" = tensor axis ("model"), None = replicate.
_MATRIX_RULES = [
    # attention projections
    ("attn/wq/w", ("S", "M")),
    ("attn/wk/w", ("S", "M")),
    ("attn/wv/w", ("S", "M")),
    ("attn/wo/w", ("M", "S")),
    ("cross/wq/w", ("S", "M")),
    ("cross/wk/w", ("S", "M")),
    ("cross/wv/w", ("S", "M")),
    ("cross/wo/w", ("M", "S")),
    # dense mlp
    ("mlp/up/w", ("S", "M")),
    ("mlp/gate/w", ("S", "M")),
    ("mlp/down/w", ("M", "S")),
    # moe experts: handled dynamically in _spec_for (size-aware, §Perf B.1)
    ("moe/shared/up/w", ("S", "M")),
    ("moe/shared/gate/w", ("S", "M")),
    ("moe/shared/down/w", ("M", "S")),
    ("moe/router/w", (None, None)),
    # mamba (hybrid)
    ("mamba/wxz/w", ("S", "M")),
    ("mamba/wbc/w", ("S", "M")),
    ("mamba/down/w", ("M", "S")),
    ("mamba/wdt/w", (None, None)),
    # mLSTM
    ("mlstm/up/w", ("S", "M")),
    ("mlstm/wq/w", ("S", "M")),
    ("mlstm/wk/w", ("S", "M")),
    ("mlstm/wv/w", ("S", "M")),
    ("mlstm/down/w", ("M", "S")),
    ("mlstm/wg/w", (None, None)),
    # sLSTM
    ("slstm/wx", ("S", "M")),
    ("sdown/w", ("S", "M")),
    # top level — embedding table keeps vocab replicated over "data":
    # a gather from a vocab-sharded table forces SPMD full
    # rematerialization (observed); sharding d_model on "model" keeps
    # the gather local per shard instead.
    ("embed/table", (None, "M")),
    ("lm_head/w", (None, "M")),
    ("pos_emb", (None, "M")),
    ("enc_pos_emb", (None, "M")),
    ("frontend/proj/w", (None, "M")),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _axis(tag, mesh, fsdp: bool):
    if tag == "M":
        return "model"
    if tag == "S":
        return "data" if (fsdp and "data" in mesh.axis_names) else None
    return None


def _spec_for(path: str, shape, mesh, fsdp: bool) -> P:
    # MoE experts (leaf (L, E, din, dout)): experts on "model"; the second
    # shard axis goes on the LARGER matrix dim (§Perf B.1): for coarse
    # experts (ff >= d, e.g. dbrx) shard ff — a d-sharded contraction
    # partial-sums EVERY expert matmul (measured 1.7 TB/dev/step); for
    # fine-grained experts (ff < d, e.g. deepseek-moe) the ff shards are
    # too thin and d-sharding measures cheaper overall.
    if "moe/experts/" in path and path.endswith("/w") and len(shape) >= 3:
        din, dout = shape[-2], shape[-1]
        is_down = "/down/" in path
        ff = din if is_down else dout
        d = dout if is_down else din
        if ff >= d:
            dims = ("M", "S", None) if is_down else ("M", None, "S")
        else:
            dims = ("M", None, "S") if is_down else ("M", "S", None)
        lead = len(shape) - 3
        spec = [None] * lead + [_axis(t, mesh, fsdp) for t in dims]
        for i, ax in enumerate(spec):
            if ax is not None and shape[i] % mesh.shape[ax] != 0:
                spec[i] = None
        return P(*spec)
    for frag, dims in _MATRIX_RULES:
        if frag in path:
            lead = len(shape) - len(dims)
            if lead < 0:  # rule written for stacked form; unstacked leaf
                dims = dims[-len(shape):]
                lead = 0
            spec = [None] * lead + [_axis(t, mesh, fsdp) for t in dims]
            # drop shardings that don't divide AND would be uneven by >0
            for i, ax in enumerate(spec):
                if ax is not None and shape[i] % mesh.shape[ax] != 0:
                    spec[i] = None
            return P(*spec)
    return P()  # biases, norms, gates, scalars: replicate


def param_shardings(mesh, params_shape, fsdp: bool = True):
    """params_shape: pytree of ShapeDtypeStruct (from jax.eval_shape)."""

    def leaf(path, sds):
        return NamedSharding(mesh, _spec_for(_path_str(path), sds.shape, mesh, fsdp))

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def opt_shardings(mesh, opt_shape, fsdp: bool = True):
    """Optimizer state mirrors the params tree under 'mu'/'nu'; scalars
    replicate. The same name rules apply because paths contain the
    parameter names."""
    return param_shardings(mesh, opt_shape, fsdp)


def batch_shardings(mesh, batch_shape, batch_sharded: bool = True):
    """Leading dim of every batch leaf is the global batch."""
    dp = tuple(a for a in mesh.axis_names if a != "model")

    def leaf(path, sds):
        if not batch_sharded or sds.shape == () or sds.shape[0] % _prod_axes(mesh, dp):
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(dp, *([None] * (len(sds.shape) - 1))))

    return jax.tree_util.tree_map_with_path(leaf, batch_shape)


def cache_shardings(mesh, cache_shape, batch: int):
    """Decode caches: (L, B, ...) leaves shard B over 'data' when it
    divides; recurrent states likewise. Everything else replicated."""
    dp = "data"
    n_dp = mesh.shape[dp]

    def leaf(path, sds):
        shp = sds.shape
        if len(shp) >= 2 and shp[1] == batch and batch % n_dp == 0:
            return NamedSharding(mesh, P(None, dp, *([None] * (len(shp) - 2))))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(leaf, cache_shape)


def replicated(mesh, tree_shape):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree_shape)


def _prod_axes(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
