"""Assigned input shapes -> lowered entry points + ShapeDtypeStruct specs.

Each (arch, shape) pair resolves to:
  - a config VARIANT (dry-run uses bf16 compute + remat; long_500k swaps
    full attention for the sliding-window variant on quadratic archs),
  - an entry function (train_step / prefill_step / serve_step),
  - argument ShapeDtypeStructs (no allocation; weak-type-correct),
  - NamedSharding in_shardings for the production mesh.

``applicability(arch, shape)`` encodes the DESIGN.md skip table:
  whisper-medium x long_500k        SKIP (enc-dec, no sub-quadratic form)
  dense/moe/vlm  x long_500k        swa variant (beyond-paper, marked)
  ssm/hybrid     x long_500k        native
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs import get_config
from repro.launch import shardings as sh
from repro.models import backbone as bb
from repro.models.config import ArchConfig

ENC_FRAMES = 1500  # whisper encoder frames (30 s clip)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicability(cfg: ArchConfig, shape: ShapeSpec) -> str:
    """'native' | 'swa' (sliding-window variant) | 'skip'."""
    if shape.name != "long_500k":
        return "native"
    if cfg.is_encdec:
        return "skip"  # whisper: no sensible sub-quadratic variant
    if cfg.subquadratic:
        return "native"  # ssm / hybrid / already-sliding archs
    return "swa"  # dense / moe / vlm: beyond-paper sliding-window variant


def dryrun_config(arch: str, shape: ShapeSpec, multi_pod: bool = False) -> ArchConfig | None:
    """Config variant lowered for this (arch, shape); None -> skip."""
    cfg = get_config(arch)
    app = applicability(cfg, shape)
    if app == "skip":
        return None
    if app == "swa":
        cfg = cfg.replace(attn_kind="sliding", window=4096)
    # activation batch constraint: data-parallel axes (skip batch-1 decode)
    dp = ("pod", "data") if multi_pod else ("data",)
    act = dp if shape.batch >= 16 else ()
    # grouped MoE dispatch: one group per data shard (§Perf B.2)
    groups = (32 if multi_pod else 16) if (cfg.n_experts and act) else 0
    # production numerics: bf16 activations, f32 params, remat for training
    return cfg.replace(compute_dtype="bfloat16", act_shard=act,
                       moe_groups=groups, remat=(shape.kind == "train"))


#: per-(arch, shape) grad-accumulation overrides, set by the §Perf loop.
#: Recurrent stacks (xlstm) pay per-TIME-STEP weight re-reads in every
#: microbatch's scan; their activations are tiny (no attention scores),
#: so one big microbatch amortizes weight traffic ~8x (EXPERIMENTS.md §Perf).
MICROBATCH_OVERRIDES = {
    ("xlstm_350m", "train_4k"): 1,
    ("hymba_1p5b", "train_4k"): 2,
}


def default_microbatches(arch: str, shape) -> int:
    name = shape.name if hasattr(shape, "name") else shape
    return MICROBATCH_OVERRIDES.get((arch, name), 8)


# FSDP threshold (§Perf A.4): ZeRO-3 weight gathers dominate collectives
# for models whose (params + Adam state) ALREADY fit per-device under
# plain 16-way tensor parallelism. 12 bytes/param (f32 p+mu+nu) / 16-way
# TP must stay well under the 16 GB HBM budget -> FSDP only above ~8B.
FSDP_MIN_PARAMS = 8e9


def use_fsdp(cfg: ArchConfig) -> bool:
    return cfg.n_params >= FSDP_MIN_PARAMS


# ------------------------------------------------------------ input specs --

def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec):
    b, s = shape.batch, shape.seq
    i32, f32 = jnp.int32, jnp.float32
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "vision_stub":
        s_text = s - cfg.vision_tokens
        return {
            "patches": sds((b, cfg.vision_tokens, cfg.frontend_dim), f32),
            "tokens": sds((b, s_text), i32),
            "labels": sds((b, s_text), i32),
        }
    if cfg.is_encdec:
        return {
            "frames": sds((b, ENC_FRAMES, cfg.frontend_dim), f32),
            "tokens": sds((b, s), i32),
            "labels": sds((b, s), i32),
        }
    return {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeSpec):
    specs = train_batch_specs(cfg, shape)
    specs.pop("labels")
    return specs


def decode_specs(cfg: ArchConfig, shape: ShapeSpec, cache_dtype=jnp.bfloat16):
    b = shape.batch
    sds = jax.ShapeDtypeStruct
    cache_shape = jax.eval_shape(
        lambda: bb.init_cache(cfg, b, shape.seq, cache_dtype, enc_len=ENC_FRAMES))
    return {
        "tokens": sds((b, 1), jnp.int32),
        "cache": cache_shape,
        "index": sds((), jnp.int32),
    }


def params_specs(cfg: ArchConfig):
    return jax.eval_shape(lambda: bb.init_params(jax.random.PRNGKey(0), cfg))


# ----------------------------------------------------------- entry points --

def make_entry(cfg: ArchConfig, shape: ShapeSpec, microbatches: int = 8):
    """Returns (fn, args_specs tuple, in_shardings_fn(mesh) -> tuple)."""
    p_specs = params_specs(cfg)
    # decode streams the whole weight set per token: 2-D weight sharding
    # (FSDP) splits that stream across "data" and measures better there
    # even for small models (§Perf follow-up to A.4)
    fsdp = use_fsdp(cfg) or shape.kind == "decode"

    if shape.kind == "train":
        opt = optim.adamw(1e-4)
        step = bb.make_train_step(cfg, opt, microbatches=microbatches)
        o_specs = jax.eval_shape(opt.init, p_specs)
        b_specs = train_batch_specs(cfg, shape)

        def fn(params, opt_state, batch):
            return step(params, opt_state, batch)

        args = (p_specs, o_specs, b_specs)

        def in_sh(mesh):
            return (sh.param_shardings(mesh, p_specs, fsdp=fsdp),
                    sh.opt_shardings(mesh, o_specs, fsdp=fsdp),
                    sh.batch_shardings(mesh, b_specs))

        return fn, args, in_sh

    if shape.kind == "prefill":
        b_specs = prefill_batch_specs(cfg, shape)

        def fn(params, batch):
            return bb.prefill(params, cfg, batch, max_len=shape.seq,
                              cache_dtype=jnp.bfloat16)

        args = (p_specs, b_specs)

        def in_sh(mesh):
            return (sh.param_shardings(mesh, p_specs, fsdp=fsdp),
                    sh.batch_shardings(mesh, b_specs))

        return fn, args, in_sh

    # decode
    d_specs = decode_specs(cfg, shape)

    def fn(params, tokens, cache, index):
        return bb.decode_step(params, cfg, tokens, cache, index)

    args = (p_specs, d_specs["tokens"], d_specs["cache"], d_specs["index"])

    def in_sh(mesh):
        from jax.sharding import NamedSharding, PartitionSpec as P

        return (sh.param_shardings(mesh, p_specs, fsdp=fsdp),
                sh.batch_shardings(mesh, d_specs["tokens"]),
                sh.cache_shardings(mesh, d_specs["cache"], shape.batch),
                NamedSharding(mesh, P()))

    return fn, args, in_sh


# ------------------------------------------------- blendfl federated round --

def make_blendfl_entry(n_clients: int = 16, n_sampled: int = 0):
    """The paper's own technique as a dry-run entry: one BlendFL round
    (3 training phases + BlendAvg psum aggregation) as one SPMD program
    over client slices. ``n_sampled`` > 0 lowers the K-of-C sampled async
    round instead — training arrays carry the sampled K axis and the
    stacked state is gathered/scattered inside the program."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import federation_sharded as fs

    spec = fs.ShardedFedSpec(n_clients=n_clients, d_hidden=1024, n_layers=4,
                             seq_a=64, feat_a=128, seq_b=64, feat_b=128,
                             out_dim=25, n_partial=512, n_frag=512,
                             n_paired=512, n_val=2048, n_val_score=512,
                             n_sampled=n_sampled)
    round_fn = fs.make_blendfl_round(spec)
    state_s = jax.eval_shape(
        lambda: fs.init_round_state(jax.random.PRNGKey(0), spec))
    batch_s = fs.batch_specs(spec)
    args = (state_s, batch_s)

    def in_sh(mesh):
        def stacked_leaf(sds):
            spec_dims = [None] * (len(sds.shape) - 1)
            # shard the largest trailing dim over "model" when divisible
            if len(sds.shape) >= 2:
                cand = max(range(1, len(sds.shape)), key=lambda i: sds.shape[i])
                if sds.shape[cand] % mesh.shape["model"] == 0 and sds.shape[cand] >= 256:
                    spec_dims[cand - 1] = "model"
            return NamedSharding(mesh, P("data", *spec_dims))

        def rep_leaf(sds):
            return NamedSharding(mesh, P())

        def state_leaf(path, sds):
            # stacked client models + their optimizer moments shard over
            # the client ("data") axis; global/server models, the shared
            # step counter, the async round bookkeeping, and the
            # server-head opt state are replicated.
            top = sh._path_str(path).split("/")[0]
            if (top in ("models", "opt") and len(sds.shape) >= 1
                    and sds.shape[0] == spec.n_clients):
                return stacked_leaf(sds)
            return rep_leaf(sds)

        def batch_leaf(path, sds):
            name = sh._path_str(path)
            # alignment/sampling index vectors and the val set replicate;
            # training arrays shard over "data" when the per-round client
            # axis K divides the mesh (a sampled K may not)
            if (name.startswith("val_") or name in ("perm_b", "sampled")
                    or sds.shape[0] % mesh.shape["data"] != 0):
                return NamedSharding(mesh, P())
            return NamedSharding(mesh, P("data", *([None] * (len(sds.shape) - 1))))

        return (jax.tree_util.tree_map_with_path(state_leaf, state_s),
                jax.tree_util.tree_map_with_path(batch_leaf, batch_s))

    return round_fn, args, in_sh, spec
