"""Production mesh construction.

Target: TPU v5e pods. Single pod = 256 chips as (16, 16) ("data",
"model"); multi-pod = 2 pods x 256 chips as (2, 16, 16) ("pod", "data",
"model") with batch data-parallel over "pod" (params replicated per pod,
FSDP inside a pod over "data", tensor/expert parallel over "model").

A FUNCTION, not a module constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devs)} — the dry-run "
            "must set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before any jax import/init")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over the real local devices (CPU tests / examples)."""
    n = len(jax.devices())
    dp = n // model_parallel
    return jax.make_mesh((dp, model_parallel), ("data", "model"),
                         devices=jax.devices()[: dp * model_parallel])


def data_axes(mesh) -> tuple:
    """The batch-parallel axes of a mesh (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")
