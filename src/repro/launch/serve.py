"""Deprecated module path — the LM prefill/decode demo moved to
``repro.launch.serve_lm``.

The ``serve`` name was misleading: this module never served the paper's
federated models, it demos backbone prefill + batched decode. BlendFL
serving (the decentralized-inference engine over trained blended
models) is ``repro.launch.serve_federated``.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.launch.serve moved to repro.launch.serve_lm (LM prefill/decode "
    "demo); the federated serving driver is repro.launch.serve_federated",
    DeprecationWarning, stacklevel=2)

from repro.launch.serve_lm import main  # noqa: E402,F401

if __name__ == "__main__":
    main()
