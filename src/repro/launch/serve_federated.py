"""Serving driver for the trained federation: blended models behind the
micro-batched request engine.

    # serve 3 request mixes off a tiny in-process training run
    PYTHONPATH=src python -m repro.launch.serve_federated --train-rounds 6 \
        --requests 64 --mix all_multimodal --mix mixed_unimodal --mix vfl_heavy

    # serve from a train_federated checkpoint (blended global models +
    # the VFL server head restored straight out of the round state)
    PYTHONPATH=src python -m repro.launch.serve_federated \
        --ckpt-dir /tmp/fedckpt --requests 256 --mix vfl_heavy

    # CI smoke: 2 mixes through one engine, cache + parity assertions
    PYTHONPATH=src python -m repro.launch.serve_federated --selftest

The engine (``repro.core.serving``) is the paper's decentralized-
inference pillar at serving scale: requests route by available
modalities to the blended local heads, pad into capacity-bucketed
micro-batches (one compiled program per (route, capacity), cache 1
forever), and the VFL fallback's feature/score messages meter real wire
bytes through the codec. The LM prefill/decode demo that used to own
the ``serve`` name lives at ``repro.launch.serve_lm``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


# Request-mix presets: probability of (multimodal, A-only, B-only, vfl).
MIXES = {
    "all_multimodal": (1.0, 0.0, 0.0, 0.0),
    "mixed_unimodal": (0.0, 0.5, 0.5, 0.0),
    "vfl_heavy": (0.2, 0.1, 0.1, 0.6),
}


def models_from_checkpoint(ckpt_dir: str, spec, ecfg, step: int | None = None):
    """Blended ``global_models`` + VFL ``server_gmv`` out of a
    ``train_federated`` round-state checkpoint.

    Restores through a partial template (just the two serving blocks —
    the stacked per-client models, optimizer moments, and telemetry
    stay on disk), after a manifest preflight that checks the requested
    ``--d-hidden`` against the checkpoint's actual head shapes so a
    mismatch fails with dims, not a leaf-by-leaf shape error.
    """
    import jax

    from repro.checkpoint import latest_step, read_manifest, restore_checkpoint
    from repro.core.encoders import fusion_init, init_client_models

    resolved = latest_step(ckpt_dir) if step is None else step
    if resolved is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    manifest = read_manifest(ckpt_dir, resolved)
    try:
        d_ck, out_ck = manifest["shapes"]["server_gmv/out/w"]
    except KeyError:
        raise KeyError(f"checkpoint {ckpt_dir} step {resolved} has no "
                       "server_gmv head — not a round-state checkpoint")
    if (d_ck, out_ck) != (ecfg.d_hidden, spec.out_dim):
        raise ValueError(
            f"checkpoint {ckpt_dir} step {resolved} was trained with "
            f"d_hidden={d_ck}, out_dim={out_ck}; this serving config asks "
            f"for d_hidden={ecfg.d_hidden}, out_dim={spec.out_dim} — fix "
            "--d-hidden/--task to match (see tools/ckpt_inspect.py)")
    template = {
        "global_models": init_client_models(jax.random.PRNGKey(0), spec, ecfg),
        "server_gmv": fusion_init(jax.random.PRNGKey(0), ecfg.d_hidden,
                                  spec.out_dim),
    }
    state = restore_checkpoint(ckpt_dir, template, step=resolved)
    print(f"restored blended models from {ckpt_dir} step {resolved}")
    return state["global_models"], state["server_gmv"]


def train_models(spec, ecfg, *, rounds: int, clients: int, seed: int):
    """Tiny in-process BlendFL federation — enough training that the
    served models are real blended artifacts, not random init."""
    import jax

    from repro.core.federation import FedConfig, Federation
    from repro.core.partitioner import partition
    from repro.data.synthetic import train_val_test

    tr, va, _ = train_val_test(spec, 240, 120, 60, seed=seed)
    parts = partition(tr, clients, seed=seed + 1)
    fcfg = FedConfig(n_clients=clients, rounds=rounds, batch_size=32,
                     seed=seed)
    fed = Federation.init(jax.random.PRNGKey(seed), fcfg, spec, ecfg,
                          parts, va)
    fed.fit()
    print(f"trained in-process federation: {clients} clients, "
          f"{rounds} rounds")
    return fed.global_models, fed.server_gmv


def make_requests(spec, mix: str, n: int, *, rows: int, seed: int) -> list:
    """A deterministic heterogeneous request stream for one mix preset.
    Row counts vary per request (1..rows) so the stream exercises
    multiple capacity buckets and the chunking path."""
    from repro.core.inference import InferenceRequest

    p_mm, p_a, p_b, p_vfl = MIXES[mix]
    rng = np.random.default_rng([seed, hash(mix) & 0xFFFF])
    kinds = rng.choice(4, size=n, p=[p_mm, p_a, p_b, p_vfl])
    out = []
    for kind in kinds:
        m = int(rng.integers(1, rows + 1))
        xa = rng.standard_normal((m, spec.seq_a, spec.feat_a)).astype(np.float32)
        xb = rng.standard_normal((m, spec.seq_b, spec.feat_b)).astype(np.float32)
        if kind == 1:
            out.append(InferenceRequest(xa, None))
        elif kind == 2:
            out.append(InferenceRequest(None, xb))
        else:
            out.append(InferenceRequest(xa, xb, vfl=(kind == 3)))
    return out


def serve_mix(engine, spec, mix: str, n: int, *, rows: int, seed: int) -> dict:
    """Run one mix through the engine; per-mix latency/throughput/bytes."""
    reqs = make_requests(spec, mix, n, rows=rows, seed=seed)
    t0 = time.perf_counter()
    results = engine.run(reqs)
    wall = time.perf_counter() - t0
    lat_ms = np.array([r.latency_s for r in results]) * 1e3
    total_rows = sum(len(r.scores) for r in results)
    return {
        "mix": mix, "requests": n, "rows": total_rows,
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "rps": n / wall, "rows_per_s": total_rows / wall,
        "bytes_per_request": sum(r.bytes for r in results) / n,
        "wall_s": wall,
        "results": results,
    }


def build_engine(args, models, server_gmv, ecfg, kind):
    from repro.core.serving import ServingConfig, ServingEngine

    cfg = ServingConfig(
        capacities=tuple(int(c) for c in args.capacities.split(",")),
        codec=args.codec, window=args.window, prefetch=args.prefetch)
    return ServingEngine(models, ecfg, kind, server_gmv=server_gmv, cfg=cfg)


def selftest(args) -> None:
    """Smoke assertion for CI: two different request mixes through ONE
    engine must (a) keep the compile cache at exactly 1 per (route,
    capacity), (b) score every request bit-identically to a
    single-request ``predict`` call, and (c) meter wire bytes that
    reconcile exactly with the analytic ``communication_cost``."""
    from repro.core.inference import predict
    from repro.data.synthetic import make_task
    from repro.core.encoders import EncoderConfig

    spec = make_task(args.task)
    ecfg = EncoderConfig(d_hidden=args.d_hidden, n_layers=args.n_layers,
                         enc_type=args.enc_type)
    models, gmv = train_models(spec, ecfg, rounds=max(2, args.train_rounds),
                               clients=args.clients, seed=args.seed)
    engine = build_engine(args, models, gmv, ecfg, spec.kind)

    total_bytes = 0
    for mix in ("mixed_unimodal", "vfl_heavy"):
        reqs = make_requests(spec, mix, args.requests, rows=args.rows,
                             seed=args.seed)
        results = engine.run(reqs)
        assert [r.index for r in results] == list(range(len(reqs)))
        for res, req in zip(results, reqs):
            ref = predict(models, req, ecfg, spec.kind, server_gmv=gmv,
                          codec=args.codec if req.vfl else None)
            assert res.route is ref.route, (res.route, ref.route)
            assert np.array_equal(np.asarray(res.scores),
                                  np.asarray(ref.scores)), \
                f"padded-batch scores diverge from predict ({mix}, " \
                f"request {res.index}, route {res.route.value})"
        total_bytes += sum(r.bytes for r in results)
        print(f"selftest mix {mix}: {len(reqs)} requests bit-exact vs "
              "predict")
    caches = engine.cache_counts()
    assert caches and all(v == 1 for v in caches.values()), \
        f"compile cache not 1 per (route, capacity): {caches}"
    assert total_bytes == engine.stats["wire_bytes"], \
        (total_bytes, engine.stats["wire_bytes"])
    print(f"selftest ok: caches {dict(caches)}; measured wire bytes "
          f"{engine.stats['wire_bytes']} reconcile with analytic")


def main() -> None:
    ap = argparse.ArgumentParser(
        description="serve a trained federation's blended models")
    ap.add_argument("--task", default="smnist")
    ap.add_argument("--ckpt-dir", default=None,
                    help="train_federated checkpoint to serve from "
                         "(default: train a tiny federation in-process)")
    ap.add_argument("--step", type=int, default=None)
    ap.add_argument("--train-rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--d-hidden", type=int, default=32)
    ap.add_argument("--n-layers", type=int, default=1)
    ap.add_argument("--enc-type", default="mlp",
                    choices=("mlp", "recurrent", "transformer"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=64,
                    help="requests per mix")
    ap.add_argument("--rows", type=int, default=8,
                    help="max rows per request (row counts vary 1..rows)")
    ap.add_argument("--mix", action="append", default=None,
                    choices=sorted(MIXES), help="request mix preset "
                    "(repeatable; default: all three)")
    ap.add_argument("--capacities", default="2,4,16,64")
    ap.add_argument("--codec", default="none",
                    help="wire codec for the VFL fallback route")
    ap.add_argument("--window", type=int, default=32)
    ap.add_argument("--prefetch", type=int, default=2)
    ap.add_argument("--selftest", action="store_true",
                    help="2 mixes + cache/parity/bytes assertions, then exit")
    args = ap.parse_args()

    if args.selftest:
        selftest(args)
        return

    from repro.core.encoders import EncoderConfig
    from repro.data.synthetic import make_task

    spec = make_task(args.task)
    ecfg = EncoderConfig(d_hidden=args.d_hidden, n_layers=args.n_layers,
                         enc_type=args.enc_type)
    if args.ckpt_dir:
        models, gmv = models_from_checkpoint(args.ckpt_dir, spec, ecfg,
                                             step=args.step)
    else:
        models, gmv = train_models(spec, ecfg, rounds=args.train_rounds,
                                   clients=args.clients, seed=args.seed)
    engine = build_engine(args, models, gmv, ecfg, spec.kind)

    for mix in (args.mix or sorted(MIXES)):
        row = serve_mix(engine, spec, mix, args.requests, rows=args.rows,
                        seed=args.seed)
        print(f"mix {mix:>15}: {row['requests']} req ({row['rows']} rows) "
              f"p50 {row['p50_ms']:.2f}ms p99 {row['p99_ms']:.2f}ms "
              f"{row['rps']:.1f} req/s {row['bytes_per_request']:.0f} B/req")
    st = engine.stats
    print(f"engine: {st['batches']} batches over routes "
          f"{ {k: v for k, v in st['batches_by_route'].items() if v} }; "
          f"wire {st['wire_messages']} msgs / {st['wire_bytes']} bytes; "
          f"build {st['build_seconds']:.3f}s stall {st['stall_seconds']:.3f}s "
          f"execute {st['execute_seconds']:.3f}s")
    print(f"compile caches (must all be 1): {engine.cache_counts()}")


if __name__ == "__main__":
    main()
