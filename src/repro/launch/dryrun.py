import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, prove memory/sharding coherence, and extract
the roofline terms from the compiled artifacts.

    PYTHONPATH=src python -m repro.launch.dryrun --arch phi4-mini-3.8b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # full sweep

The XLA_FLAGS assignment above MUST stay the first statement of this
module: jax locks the device count at first init, and the placeholder
512-device host platform exists for THIS entry point only (tests and
benches see the real single device).
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ALIASES, ARCH_IDS, get_config
from repro.launch import roofline as rl
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            verbose: bool = True, overrides: dict | None = None,
            microbatches: int | None = None) -> dict:
    """Lower + compile one (arch, shape, mesh); return the result record."""
    shape = SP.SHAPES[shape_name]
    cfg = SP.dryrun_config(arch, shape, multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if cfg is None:
        rec["status"] = "skip"
        rec["reason"] = "no sub-quadratic form (see DESIGN.md)"
        return rec
    if overrides:
        cfg = cfg.replace(**overrides)
    if microbatches is None:
        microbatches = SP.default_microbatches(arch, shape)
    rec["variant"] = SP.applicability(get_config(arch), shape)
    rec["microbatches"] = microbatches

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    fn, args, in_sh = SP.make_entry(cfg, shape, microbatches=microbatches)

    t0 = time.time()
    with mesh, jax.set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=in_sh(mesh)).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    rec.update(status="ok", t_lower_s=round(t_lower, 1),
               t_compile_s=round(t_compile, 1))

    # ---- memory ----
    try:
        mem = compiled.memory_analysis()
        if mem is not None:
            rec["mem"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)
            }
    except Exception as e:  # CPU backend may not implement it
        rec["mem_error"] = str(e)

    # ---- cost: while-aware walk of the post-SPMD (per-device) HLO ----
    from repro.launch import hlo_cost

    hlo = compiled.as_text()
    cost = hlo_cost.analyze(hlo)
    rec["cost"] = {
        "flops_per_dev": cost["flops"],
        "bytes_per_dev": cost["bytes"],
        "coll_bytes_per_dev": cost["coll_total"],
        "coll_bytes": cost["coll_bytes"],
        "coll_counts": cost["coll_counts"],
        "top_dot_sites": cost["top_dot_sites"],
    }

    roof = rl.Roofline(
        arch=arch, shape=shape_name, mesh=rec["mesh"], chips=chips,
        hlo_flops=cost["flops"] * chips, hlo_bytes=cost["bytes"] * chips,
        coll_bytes_per_dev=cost["coll_total"],
        model_flops=rl.model_flops(cfg, shape.kind, shape.batch, shape.seq),
    ).finalize()
    rec["roofline"] = roof.row()
    if verbose:
        r = roof.row()
        print(f"[{arch} x {shape_name} @ {rec['mesh']}] ok "
              f"compile={t_compile:.0f}s flops={r['hlo_gflops']:.0f}G "
              f"coll={r['coll_mb_per_dev']:.1f}MB/dev "
              f"terms(ms) c={r['t_compute_ms']:.2f} m={r['t_memory_ms']:.2f} "
              f"x={r['t_collective_ms']:.2f} -> {r['bottleneck']}",
              flush=True)
    return rec


def run_blendfl_round(multi_pod: bool = False, verbose: bool = True) -> dict:
    """Dry-run the paper's own federated round as one SPMD program."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_clients = mesh.shape["data"] * (mesh.shape.get("pod", 1))
    fn, args, in_sh, spec = SP.make_blendfl_entry(n_clients=n_clients)
    rec = {"arch": "blendfl_round", "shape": f"C{n_clients}",
           "mesh": "2x16x16" if multi_pod else "16x16"}
    t0 = time.time()
    with mesh, jax.set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=in_sh(mesh)).lower(*args)
        compiled = lowered.compile()
    rec["t_compile_s"] = round(time.time() - t0, 1)
    from repro.launch import hlo_cost

    cost = hlo_cost.analyze(compiled.as_text())
    rec["cost"] = {"flops_per_dev": cost["flops"], "bytes_per_dev": cost["bytes"],
                   "coll_bytes_per_dev": cost["coll_total"],
                   "coll_counts": cost["coll_counts"]}
    rec["status"] = "ok"
    if verbose:
        print(f"[blendfl_round @ {rec['mesh']}] ok compile={rec['t_compile_s']}s "
              f"coll={cost['coll_total']/1e6:.1f}MB/dev counts={cost['coll_counts']}",
              flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (dashed or underscored)")
    ap.add_argument("--shape", default=None, choices=list(SP.SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true", help="full 40-pair sweep")
    ap.add_argument("--blendfl", action="store_true", help="the federated round entry")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args()

    records = []
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    def emit(rec):
        records.append(rec)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(rec) + "\n")

    if args.blendfl:
        for mp in meshes:
            emit(run_blendfl_round(multi_pod=mp))
        return

    archs = ARCH_IDS if (args.all or not args.arch) else [ALIASES.get(args.arch, args.arch)]
    shapes = list(SP.SHAPES) if (args.all or not args.shape) else [args.shape]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    emit(run_one(arch, shape, multi_pod=mp))
                except Exception:
                    n_fail += 1
                    print(f"[{arch} x {shape} @ {'2x16x16' if mp else '16x16'}] FAIL",
                          flush=True)
                    traceback.print_exc()
                    emit({"arch": arch, "shape": shape,
                          "mesh": "2x16x16" if mp else "16x16",
                          "status": "fail", "error": traceback.format_exc()[-2000:]})
    ok = sum(1 for r in records if r.get("status") == "ok")
    sk = sum(1 for r in records if r.get("status") == "skip")
    print(f"\ndry-run: {ok} ok, {sk} skip, {n_fail} fail / {len(records)} total")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
