"""Federated training driver: ragged clients -> sharded round -> resumable.

Wires the three pieces that turn ``federation_sharded``'s round function
into a runnable, crash-safe system:

    partitioned ragged data  ->  FederatedBatcher (padded masked batches,
                                 double-buffered host->device transfer)
                             ->  jitted make_blendfl_round(state, batch)
                             ->  periodic save_checkpoint of the FULL
                                 round state (stacked client models, opt
                                 moments, server head + srv_opt,
                                 last_round, round counter)

Resume is **bit-exact**: the batcher's round-``r`` batch is a pure
function of ``(seed, r)`` and the checkpoint carries every leaf of
``init_round_state``, so a killed-and-resumed run produces byte-identical
round metrics to an uninterrupted one (``--selftest-resume`` asserts
this; the ``make train-federated`` smoke lane runs it).

    PYTHONPATH=src python -m repro.launch.train_federated \
        --rounds 8 --clients 8 --ckpt-dir /tmp/fedckpt --ckpt-every 2
    PYTHONPATH=src python -m repro.launch.train_federated --selftest-resume

Out-of-core federations: the one-shot ``import`` subcommand converts the
in-memory synthetic partition to a ``repro.data.store.ClientStore`` of
per-client shard files, and ``--store-dir`` runs the federation straight
off those shards — ``build()`` memory-maps only the drawn row subsets, so
peak host RSS per round is O(K*N*row_bytes) regardless of dataset size,
and round-state checkpoints carry the store fingerprint so a resume
against a different store fails loudly instead of silently diverging.

    PYTHONPATH=src python -m repro.launch.train_federated import \
        --store-dir /tmp/fedstore --clients 32 --n-train 65536
    PYTHONPATH=src python -m repro.launch.train_federated \
        --store-dir /tmp/fedstore --rounds 8 --ckpt-dir /tmp/fedckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import (latest_step, read_manifest, restore_checkpoint,
                              save_checkpoint)
from repro.core import state as rstate
from repro.core.federation_sharded import (
    ShardedFedSpec,
    batch_specs,
    init_round_state,
    make_blendfl_round,
)
from repro.core.aggregate import SERVER_OPTS, STRATEGIES
from repro.core.codec import CODECS, make_codec, round_bytes
from repro.core.partitioner import ClientData, partition
from repro.core.schedule import POLICIES, telemetry_from_state
from repro.data.pipeline import FederatedBatcher
from repro.data.scenario import load_scenario
from repro.data.store import ClientStore, write_store
from repro.data.synthetic import make_task, train_val_test
from repro.launch import shardings as sh
from repro.launch.mesh import make_host_mesh


def client_arrays(cd: ClientData) -> dict:
    """``partitioner.ClientData`` -> the FederatedBatcher's dict-of-arrays
    client format (labels for fragmented rows ride with the a side)."""
    return {
        "partial_a": cd.partial_a.x, "partial_ya": cd.partial_a.y,
        "partial_b": cd.partial_b.x, "partial_yb": cd.partial_b.y,
        "frag_a": cd.frag_a.x, "frag_y": cd.frag_a.y,
        "frag_ids_a": cd.frag_a.ids,
        "frag_b": cd.frag_b.x, "frag_ids_b": cd.frag_b.ids,
        "paired_a": cd.paired_a.x, "paired_b": cd.paired_b.x,
        "paired_y": cd.paired_a.y,
    }


def import_store(args) -> ClientStore:
    """One-shot conversion: in-memory synthetic partition -> on-disk
    ``ClientStore``. The manifest records the task dims, seeds, and val
    size, so a later ``--store-dir`` run is fully self-describing (no
    data-generation args needed, no dataset materialized in host RAM)."""
    if not args.store_dir:
        raise SystemExit("import requires --store-dir")
    task = make_task(args.task)
    tr, va, _ = train_val_test(task, args.n_train, args.n_val, 64,
                               seed=args.data_seed)
    clients = partition(tr, args.clients, seed=args.data_seed,
                        dirichlet_alpha=args.dirichlet_alpha)
    meta = {"task": args.task, "kind": task.kind, "out_dim": task.out_dim,
            "seq_a": task.seq_a, "feat_a": task.feat_a,
            "seq_b": task.seq_b, "feat_b": task.feat_b,
            "n_train": args.n_train, "n_val": args.n_val,
            "data_seed": args.data_seed,
            "dirichlet_alpha": args.dirichlet_alpha}
    store = write_store(args.store_dir, [client_arrays(cd) for cd in clients],
                        {"val_a": va.x_a, "val_b": va.x_b, "val_y": va.y},
                        meta=meta, overwrite=args.overwrite)
    rows = sum(store.rows(c, k) for c in range(store.n_clients)
               for k in store.client_keys(c))
    print(f"imported {store.n_clients} clients ({rows} shard rows, task "
          f"{args.task!r}) -> {args.store_dir}  "
          f"[fingerprint {store.fingerprint()[:12]}]")
    return store


def build_federation(args) -> tuple:
    """(spec, batcher, round_fn, mesh) for a ragged federation — in-memory
    synthetic by default, out-of-core when ``--store-dir`` names an
    imported ``ClientStore`` (client arrays then stay on disk; only the
    drawn row subsets are ever materialized)."""
    # static per-round capacities sized to the ragged partition
    n_partial = max(args.rows_cap, 1)
    scenario = None
    if getattr(args, "scenario", None):
        scenario = load_scenario(args.scenario)
        if getattr(args, "store_dir", None):
            raise SystemExit(
                "--scenario does not compose with --store-dir: a store's "
                "client count is fixed at import, a scenario's roster "
                "grows — partition in-memory data instead")
    store = None
    if getattr(args, "store_dir", None):
        store = ClientStore(args.store_dir)
        m = store.meta  # dims recorded at import time, not CLI args
        spec = ShardedFedSpec(
            n_clients=store.n_clients, d_hidden=args.d_hidden,
            n_layers=args.n_layers, seq_a=m["seq_a"], feat_a=m["feat_a"],
            seq_b=m["seq_b"], feat_b=m["feat_b"], out_dim=m["out_dim"],
            kind=m["kind"], n_partial=n_partial, n_frag=n_partial,
            n_paired=n_partial, n_val=m["n_val"], lr=args.lr,
            optimizer=args.optimizer, n_sampled=args.n_sampled,
            policy=getattr(args, "policy", "uniform"),
            codec=getattr(args, "codec", "none"),
            topk_frac=getattr(args, "topk_frac", 0.25),
            strategy=getattr(args, "strategy", "blendavg"),
            fedprox_mu=getattr(args, "fedprox_mu", 0.0),
            server_opt=getattr(args, "server_opt", "none"),
            server_lr=getattr(args, "server_lr", 1.0),
            n_malicious=getattr(args, "n_malicious", 1))
    else:
        task = make_task(args.task)
        tr, va, _ = train_val_test(task, args.n_train, args.n_val, 64,
                                   seed=args.data_seed)
        # under a scenario the FULL roster (initial cohort + every future
        # joiner) is partitioned up-front — a joiner's data exists from
        # round 0 but its slot stays inactive until its join event — and
        # spec.n_clients is the state CAPACITY for the cohort size at the
        # (possibly resumed) start round, bucketed so growth recompiles
        # at most once per bucket
        n_part = args.clients
        n_cap = args.clients
        if scenario is not None:
            scenario.validate(args.clients)
            n_part = args.clients + scenario.total_joins()
            r0 = ((latest_step(args.ckpt_dir) or 0)
                  if getattr(args, "ckpt_dir", None) else 0)
            n_cap = rstate.capacity_for(
                scenario.n_clients_at(r0 - 1, args.clients))
        clients = partition(tr, n_part, seed=args.data_seed,
                            dirichlet_alpha=args.dirichlet_alpha)
        spec = ShardedFedSpec(
            n_clients=n_cap, d_hidden=args.d_hidden, n_layers=args.n_layers,
            seq_a=task.seq_a, feat_a=task.feat_a, seq_b=task.seq_b,
            feat_b=task.feat_b, out_dim=task.out_dim, kind=task.kind,
            n_partial=n_partial, n_frag=n_partial, n_paired=n_partial,
            n_val=args.n_val, lr=args.lr, optimizer=args.optimizer,
            n_sampled=args.n_sampled, policy=getattr(args, "policy", "uniform"),
            codec=getattr(args, "codec", "none"),
            topk_frac=getattr(args, "topk_frac", 0.25),
            strategy=getattr(args, "strategy", "blendavg"),
            fedprox_mu=getattr(args, "fedprox_mu", 0.0),
            server_opt=getattr(args, "server_opt", "none"),
            server_lr=getattr(args, "server_lr", 1.0),
            n_malicious=getattr(args, "n_malicious", 1),
            # gradient-space attackers ride the scenario: the flag is
            # static round structure (the attack hook + attack_coef
            # batch key trace in), WHO attacks each round is data
            attacks=(scenario.has_uplink_attacks()
                     if scenario is not None else False))
    mesh = make_host_mesh()
    shard = sh.batch_shardings(mesh, batch_specs(spec, ragged=True))
    if store is not None:
        batcher = FederatedBatcher.from_store(
            store, spec, seed=args.seed, shardings=shard,
            prefetch=args.prefetch)
    else:
        batcher = FederatedBatcher(
            [client_arrays(cd) for cd in clients], spec,
            {"val_a": va.x_a, "val_b": va.x_b, "val_y": va.y},
            seed=args.seed, shardings=shard, prefetch=args.prefetch,
            scenario=scenario, n_initial=args.clients)
    return spec, batcher, jax.jit(make_blendfl_round(spec)), mesh


def place_state(state: dict, mesh) -> dict:
    """Put a fresh/restored round state on the mesh with the same
    (replicated) shardings the jitted round emits — keeps the round's
    compile cache at exactly one entry across init, chaining, and
    resume (a SingleDeviceSharding state would retrace once)."""
    return jax.device_put(state, sh.replicated(mesh, state))


def run(args, spec, batcher, round_fn, start: int, state: dict,
        log=print) -> list[dict]:
    """Drive rounds [start, args.rounds), checkpointing the full round
    state every ``ckpt_every`` rounds. Returns per-round metric dicts."""
    history = []
    # store-backed runs stamp the data identity into every checkpoint so
    # init_or_restore can refuse to resume against a different store
    fp = _fingerprint(batcher)

    def sched_telemetry() -> dict:
        # state-reading participation policies (staleness / omega_ema)
        # pull the sched block before each build; ``state`` rebinds every
        # round below, so this always reads the latest round's telemetry
        return telemetry_from_state(state)

    t0 = time.time()
    for r, batch in batcher.rounds(start, args.rounds,
                                   telemetry_fn=sched_telemetry):
        state, metrics = round_fn(state, batch)
        row = {k: float(np.asarray(v)) for k, v in metrics.items()
               if np.asarray(v).ndim == 0}
        row["round"] = r
        history.append(row)
        if args.log_every and (r + 1) % args.log_every == 0:
            log(f"round {r + 1:4d} loss_uni {row['loss_uni']:.4f} "
                f"loss_vfl {row['loss_vfl']:.4f} "
                f"loss_paired {row['loss_paired']:.4f} "
                f"({(time.time() - t0) / (r + 1 - start):.2f}s/round)")
        if args.ckpt_dir and args.ckpt_every and (r + 1) % args.ckpt_every == 0:
            meta = {"round": r + 1, "loss_uni": row["loss_uni"]}
            if fp is not None:
                meta["store_fingerprint"] = fp
            out = save_checkpoint(args.ckpt_dir, r + 1, state, meta)
            log(f"checkpointed round {r + 1} -> {out}")
    return history


def _fingerprint(batcher) -> str | None:
    return batcher.store.fingerprint() if batcher.store is not None else None


def run_scenario(args, spec, batcher, round_fn, mesh, start: int, state: dict,
                 log=print):
    """Drive rounds [start, args.rounds) under the batcher's churn
    scenario: before each round, apply its events — grow the state to the
    round's capacity bucket (one re-jit per NEW bucket; the per-bucket
    round functions live in the returned dict and each compiles exactly
    once), retire departing clients' state rows — then build the round
    batch against the scenario's active mask. Returns
    ``(history, round_fns, spec, state)``.

    Membership is a pure function of the round index, so a resumed run
    replays the identical capacity/event sequence from ``start`` and the
    bit-exact resume contract survives churn unchanged.
    """
    scenario = batcher.scenario
    round_fns = {spec.n_clients: round_fn}
    history = []
    fp = _fingerprint(batcher)
    t0 = time.time()
    for r in range(start, args.rounds):
        ev = scenario.events_at(r)
        n_now = scenario.n_clients_at(r, batcher.n_initial)
        cap = rstate.capacity_for(n_now)
        if cap > spec.n_clients:
            log(f"round {r}: cohort grows to {n_now} clients -> capacity "
                f"{cap} (new bucket, one re-jit)")
            state = place_state(rstate.grow(state, cap), mesh)
            spec = dataclasses.replace(spec, n_clients=cap)
            batcher.set_spec(spec)
            if cap not in round_fns:
                round_fns[cap] = jax.jit(make_blendfl_round(spec))
        if ev is not None and ev.leave:
            log(f"round {r}: clients {list(ev.leave)} depart "
                "(state rows retired, never sampled again)")
            state = place_state(rstate.retire_clients(state, ev.leave), mesh)
        if ev is not None and ev.corrupt:
            log(f"round {r}: clients {list(ev.corrupt)} turn adversarial "
                "(labels flipped from this round on)")
        if ev is not None and (ev.sign_flip or ev.scale or ev.backdoor):
            parts = [f"{kind} {list(ids)}" for kind, ids in
                     (("sign_flip", ev.sign_flip), ("scale", ev.scale),
                      ("backdoor", ev.backdoor)) if ids]
            log(f"round {r}: gradient-space attackers from this round on: "
                + ", ".join(parts))
        sched = (telemetry_from_state(state)
                 if batcher.policy is not None and batcher.policy.needs_state
                 else None)
        batch = batcher.put(batcher.build(r, sched))
        state, metrics = round_fns[spec.n_clients](state, batch)
        row = {k: float(np.asarray(v)) for k, v in metrics.items()
               if np.asarray(v).ndim == 0}
        row["round"] = r
        history.append(row)
        if args.log_every and (r + 1) % args.log_every == 0:
            log(f"round {r + 1:4d} loss_uni {row['loss_uni']:.4f} "
                f"loss_vfl {row['loss_vfl']:.4f} "
                f"loss_paired {row['loss_paired']:.4f} "
                f"[{n_now} clients / cap {spec.n_clients}] "
                f"({(time.time() - t0) / (r + 1 - start):.2f}s/round)")
        if args.ckpt_dir and args.ckpt_every and (r + 1) % args.ckpt_every == 0:
            meta = {"round": r + 1, "loss_uni": row["loss_uni"]}
            if fp is not None:
                meta["store_fingerprint"] = fp
            out = save_checkpoint(args.ckpt_dir, r + 1, state, meta)
            log(f"checkpointed round {r + 1} -> {out}")
    return history, round_fns, spec, state


def init_or_restore(args, spec, mesh, store_fingerprint: str | None = None
                    ) -> tuple[int, dict]:
    """Fresh ``init_round_state`` or the latest full-state checkpoint.

    ``store_fingerprint`` is the current run's ``ClientStore`` identity
    (None for in-memory data). A checkpoint stamped with a *different*
    fingerprint belongs to another federation's data — resuming would
    silently break the bit-exact batch-stream contract, so it raises.
    """
    state = init_round_state(jax.random.PRNGKey(args.seed), spec)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start = latest_step(args.ckpt_dir)
        manifest = read_manifest(args.ckpt_dir, start)
        want = manifest.get("metadata", {}).get("store_fingerprint")
        if want is not None and store_fingerprint is None:
            raise ValueError(
                f"checkpoint at {args.ckpt_dir} round {start} was written "
                "by a store-backed run (store_fingerprint "
                f"{want[:12]}…) — resume it with the same --store-dir, "
                "not in-memory data")
        if want is not None and want != store_fingerprint:
            raise ValueError(
                f"checkpoint at {args.ckpt_dir} round {start} was written "
                f"against a different client store (fingerprint {want[:12]}… "
                f"vs current {store_fingerprint[:12]}…) — refusing to "
                "resume: the (seed, round) batch stream would diverge")
        if want is None and store_fingerprint is not None:
            print("note: resuming a checkpoint with no store fingerprint "
                  "from a store-backed run (ok if the store was imported "
                  "from the same dataset)")
        # capacity migration: a checkpoint stacked for fewer client slots
        # restores bit-exactly into its own capacity, then grows — never
        # silently reinitializes; shrinking in place is refused outright
        ckpt_cap = rstate.manifest_capacity(manifest)
        if ckpt_cap > spec.n_clients:
            raise ValueError(
                f"checkpoint at {args.ckpt_dir} round {start} holds "
                f"{ckpt_cap} client slots but this federation was built "
                f"for {spec.n_clients} — shrinking a cohort in place is "
                f"not supported (retire clients via a scenario instead); "
                f"rerun with --clients >= {ckpt_cap}")
        if ckpt_cap < spec.n_clients:
            print(f"migrating checkpoint: {ckpt_cap} client slots -> "
                  f"capacity {spec.n_clients} (existing rows restore "
                  "bit-exactly; new rows take each block's declared fill)")
            template = init_round_state(
                jax.random.PRNGKey(args.seed),
                dataclasses.replace(spec, n_clients=ckpt_cap))
            state = rstate.grow(
                restore_checkpoint(args.ckpt_dir, template, step=start),
                spec.n_clients)
        else:
            state = restore_checkpoint(args.ckpt_dir, state, step=start)
        print(f"restored full round state at round {start} from {args.ckpt_dir}")
    return start, place_state(state, mesh)


def selftest_resume(args) -> None:
    """Smoke assertion: an interrupted-and-resumed federation reproduces
    the uninterrupted run's round metrics bit-for-bit."""
    import tempfile

    assert args.rounds >= 2, "resume selftest needs >= 2 rounds"
    mid = args.rounds // 2
    spec, batcher, round_fn, mesh = build_federation(args)

    # uninterrupted reference — never writes to a user --ckpt-dir
    ref_args = argparse.Namespace(**{**vars(args), "ckpt_dir": None})
    ref = run(ref_args, spec, batcher, round_fn, 0, place_state(
        init_round_state(jax.random.PRNGKey(args.seed), spec), mesh))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        a = argparse.Namespace(**{**vars(args), "ckpt_dir": ckpt_dir,
                                  "ckpt_every": mid, "rounds": mid})
        part1 = run(a, spec, batcher, round_fn, 0, place_state(
            init_round_state(jax.random.PRNGKey(args.seed), spec), mesh))
        # "crash": rebuild everything from scratch, restore from disk
        spec2, batcher2, round_fn2, mesh2 = build_federation(args)
        a2 = argparse.Namespace(**{**vars(args), "ckpt_dir": ckpt_dir})
        start, state = init_or_restore(a2, spec2, mesh2, _fingerprint(batcher2))
        assert start == mid, f"expected restore at round {mid}, got {start}"
        part2 = run(a2, spec2, batcher2, round_fn2, start, state)
    # round_fn saw fresh-init + chained states; round_fn2 saw a RESTORED
    # state + chained — each wrapper must have compiled exactly once (a
    # place_state regression would retrace on one of them)
    assert int(round_fn._cache_size()) == 1, \
        "fresh-init + chained rounds must share one compiled program"
    assert int(round_fn2._cache_size()) == 1, \
        "restored + chained rounds must share one compiled program"

    resumed = part1 + part2
    assert len(resumed) == len(ref)
    for got, want in zip(resumed, ref):
        for k in want:
            if not (got[k] == want[k] or (np.isnan(got[k]) and np.isnan(want[k]))):
                raise AssertionError(
                    f"resume parity broken at round {want['round']}: "
                    f"{k} {got[k]!r} != {want[k]!r}")
    print(f"resume parity OK: {len(ref)} rounds bit-identical "
          f"(interrupted at round {mid}, n_sampled={args.n_sampled}, "
          f"policy={getattr(args, 'policy', 'uniform')})")


def selftest_resume_scenario(args) -> None:
    """Churn resume smoke: a federation killed and resumed mid-scenario —
    across a cohort-growth event — reproduces the uninterrupted run's
    round metrics bit-for-bit, with every capacity bucket's round
    function compiling exactly once in every leg."""
    import tempfile

    assert args.rounds >= 2, "resume selftest needs >= 2 rounds"
    mid = args.rounds // 2

    def fresh(a):
        spec, batcher, round_fn, mesh = build_federation(a)
        start, state = init_or_restore(a, spec, mesh, None)
        return spec, batcher, round_fn, mesh, start, state

    def check_caches(fns, leg):
        for cap, fn in fns.items():
            n = int(fn._cache_size())
            assert n == 1, (f"{leg}: capacity-{cap} round function "
                            f"compiled {n}x (expected exactly once)")

    spec, batcher, round_fn, mesh, _, state = fresh(
        argparse.Namespace(**{**vars(args), "ckpt_dir": None}))
    scenario = batcher.scenario
    joins = [e.round for e in scenario.events if e.join]
    assert joins and min(joins) < args.rounds, \
        "the scenario resume selftest needs a join event inside the run"
    caps_seen = {rstate.capacity_for(scenario.n_clients_at(r, args.clients))
                 for r in range(args.rounds)}

    ref_args = argparse.Namespace(**{**vars(args), "ckpt_dir": None})
    ref, ref_fns, _, _ = run_scenario(ref_args, spec, batcher, round_fn,
                                      mesh, 0, state)
    check_caches(ref_fns, "reference")
    assert len(ref_fns) == len(caps_seen), \
        f"{len(ref_fns)} compiled buckets for {len(caps_seen)} capacities"

    with tempfile.TemporaryDirectory() as ckpt_dir:
        a1 = argparse.Namespace(**{**vars(args), "ckpt_dir": ckpt_dir,
                                   "ckpt_every": mid, "rounds": mid})
        spec1, b1, fn1, mesh1, _, st1 = fresh(a1)
        part1, fns1, _, _ = run_scenario(a1, spec1, b1, fn1, mesh1, 0, st1)
        check_caches(fns1, "pre-kill")
        # "crash": rebuild from scratch; build_federation sizes the spec
        # to the checkpointed round's capacity, init_or_restore restores
        a2 = argparse.Namespace(**{**vars(args), "ckpt_dir": ckpt_dir})
        spec2, b2, fn2, mesh2, start, st2 = fresh(a2)
        assert start == mid, f"expected restore at round {mid}, got {start}"
        part2, fns2, _, _ = run_scenario(a2, spec2, b2, fn2, mesh2, start, st2)
        check_caches(fns2, "resumed")

    resumed = part1 + part2
    assert len(resumed) == len(ref)
    for got, want in zip(resumed, ref):
        for k in want:
            if not (got[k] == want[k] or (np.isnan(got[k]) and np.isnan(want[k]))):
                raise AssertionError(
                    f"scenario resume parity broken at round {want['round']}: "
                    f"{k} {got[k]!r} != {want[k]!r}")
    print(f"scenario resume parity OK: {len(ref)} rounds bit-identical "
          f"across churn (interrupted at round {mid}, capacities "
          f"{sorted(caps_seen)}, codec={getattr(args, 'codec', 'none')}, "
          f"strategy={getattr(args, 'strategy', 'blendavg')})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("command", nargs="?", choices=["import"], default=None,
                    help="'import': convert the synthetic partition to an "
                         "on-disk ClientStore at --store-dir and exit")
    ap.add_argument("--store-dir", default=None,
                    help="run out-of-core from this imported ClientStore "
                         "(training) / write the store here (import)")
    ap.add_argument("--overwrite", action="store_true",
                    help="import: replace an existing store directory")
    ap.add_argument("--task", default="smnist")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--scenario", default=None,
                    help="churn scenario YAML (repro.data.scenario): "
                         "join/leave/corrupt plus gradient-space attack "
                         "events (sign_flip/scale/backdoor) per round; "
                         "requires --n-sampled > 0, grows state capacity "
                         "in buckets (see examples/scenarios/)")
    ap.add_argument("--n-sampled", type=int, default=0)
    ap.add_argument("--policy", default="uniform", choices=POLICIES,
                    help="participation policy for K-of-C sampled rounds "
                         "(repro.core.schedule); uniform = bit-exact "
                         "pre-scheduler sampling")
    ap.add_argument("--codec", default="none", choices=CODECS,
                    help="wire codec for the simulated round traffic "
                         "(repro.core.codec): candidate uplink + broadcast "
                         "downlink deltas with error-feedback residuals")
    ap.add_argument("--strategy", default="blendavg", choices=STRATEGIES,
                    help="aggregation strategy (repro.core.aggregate): "
                         "blendavg scored blend | fedavg volume weights | "
                         "scaffold control variates | fedprox proximal term "
                         "| median / trimmed_mean / krum Byzantine-robust "
                         "reducers (see --n-malicious)")
    ap.add_argument("--n-malicious", type=int, default=1,
                    help="assumed malicious-client budget f for the robust "
                         "strategies (trim count per side / multi-Krum's f)")
    ap.add_argument("--fedprox-mu", type=float, default=0.0,
                    help="FedProx proximal coefficient (requires "
                         "--strategy fedprox; mu 0 = plain fedavg)")
    ap.add_argument("--server-opt", default="none", choices=SERVER_OPTS,
                    help="server-side optimizer on the blended delta "
                         "(composes with any --strategy)")
    ap.add_argument("--server-lr", type=float, default=1.0,
                    help="server-side optimizer learning rate")
    ap.add_argument("--topk-frac", type=float, default=0.25,
                    help="fraction of entries per leaf kept by the "
                         "sparsifying codecs (topk / int8_topk)")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--n-train", type=int, default=2048)
    ap.add_argument("--n-val", type=int, default=256)
    ap.add_argument("--rows-cap", type=int, default=64,
                    help="static per-client per-phase row capacity")
    ap.add_argument("--d-hidden", type=int, default=32)
    ap.add_argument("--n-layers", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--dirichlet-alpha", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--prefetch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=2)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--selftest-resume", action="store_true",
                    help="run the killed-and-resumed parity assertion and exit")
    args = ap.parse_args()

    if args.command == "import":
        import_store(args)
        return
    if args.selftest_resume:
        if args.scenario:
            selftest_resume_scenario(args)
        else:
            selftest_resume(args)
        return
    spec, batcher, round_fn, mesh = build_federation(args)
    start, state = init_or_restore(args, spec, mesh, _fingerprint(batcher))
    if spec.codec != "none":
        rb = round_bytes(state["global_models"],
                         make_codec(spec.codec, spec.topk_frac),
                         n_up=spec.k_round, n_down=spec.k_round)
        print(f"codec {spec.codec} (topk_frac={spec.topk_frac}): "
              f"{rb['bytes_per_round']:,} bytes/round, "
              f"{rb['compression_ratio']:.1f}x vs dense fp32")
    if batcher.scenario is not None:
        run_scenario(args, spec, batcher, round_fn, mesh, start, state)
    else:
        run(args, spec, batcher, round_fn, start, state)
    print(f"done ({args.rounds - start} rounds; host batch-build "
          f"{batcher.build_seconds:.2f}s over {batcher.rounds_built} builds).")


if __name__ == "__main__":
    main()
