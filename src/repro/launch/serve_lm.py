"""Serving driver: prefill + batched decode on the local devices.

    PYTHONPATH=src python -m repro.launch.serve_lm --arch phi4-mini-3.8b \
        --reduced --batch 4 --prompt-len 32 --gen 16

Demonstrates the full serving path (prefill builds the ring-buffer KV /
recurrent-state cache, decode_step extends it one token at a time) —
the same entry points the decode dry-runs lower at production shapes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, get_config
from repro.models import backbone as bb


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(ALIASES.get(args.arch, args.arch))
    if args.reduced:
        cfg = cfg.reduced()
    rng = np.random.default_rng(0)
    params = bb.init_params(jax.random.PRNGKey(0), cfg)

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)}
    if cfg.frontend == "vision_stub":
        batch["patches"] = jnp.asarray(
            rng.normal(0, 1, (args.batch, cfg.vision_tokens, cfg.frontend_dim)),
            jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jnp.asarray(
            rng.normal(0, 1, (args.batch, 64, cfg.frontend_dim)), jnp.float32)

    t0 = time.time()
    logits, cache, index = jax.jit(
        lambda p, b: bb.prefill(p, cfg, b, max_len=args.max_len))(params, batch)
    print(f"prefill {args.prompt_len} tokens x{args.batch}: {time.time()-t0:.2f}s")

    serve_step = jax.jit(bb.make_serve_step(cfg))
    tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tokens]
    idx = jnp.asarray(index, jnp.int32)
    key = jax.random.PRNGKey(1)
    t0 = time.time()
    for i in range(args.gen):
        logits, cache = serve_step(params, tokens, cache, idx + i)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tokens = jax.random.categorical(
                sub, logits[:, -1] / args.temperature)[:, None].astype(jnp.int32)
        else:
            tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tokens)
    dt = time.time() - t0
    gen = np.concatenate([np.asarray(t) for t in out], axis=1)
    print(f"decoded {args.gen} tokens x{args.batch} in {dt:.2f}s "
          f"({args.gen*args.batch/dt:.1f} tok/s)")
    for row in gen[: min(args.batch, 2)]:
        print("  ", row.tolist())


if __name__ == "__main__":
    main()
