"""Qwen2-VL-2B [arXiv:2409.12191]: 28L, d=1536, 12H GQA kv=2, ff=8960,
vocab=151936, M-RoPE (t/h/w sections), dynamic-resolution ViT STUBBED
(input_specs provides patch embeddings, dim 1176 = 14*14*3*2)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    act="swiglu",
    pos="mrope",
    mrope_sections=(16, 24, 24),
    qkv_bias=True,
    frontend="vision_stub",
    frontend_dim=1176,
    vision_tokens=1024,
    citation="arXiv:2409.12191",
)
