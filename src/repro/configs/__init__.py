"""Architecture config registry. ``get_config(name)`` returns the exact
published configuration; ``get_config(name).reduced()`` is the CPU smoke
variant. ``ARCH_IDS`` lists the 10 assigned architectures."""
from __future__ import annotations

import importlib

from repro.models.config import ArchConfig

ARCH_IDS = [
    "phi4_mini_3p8b",
    "starcoder2_7b",
    "nemotron_4_15b",
    "whisper_medium",
    "deepseek_moe_16b",
    "stablelm_3b",
    "qwen2_vl_2b",
    "hymba_1p5b",
    "xlstm_350m",
    "dbrx_132b",
]

# CLI-friendly aliases (the assignment's dashed ids)
ALIASES = {
    "phi4-mini-3.8b": "phi4_mini_3p8b",
    "starcoder2-7b": "starcoder2_7b",
    "nemotron-4-15b": "nemotron_4_15b",
    "whisper-medium": "whisper_medium",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "stablelm-3b": "stablelm_3b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "hymba-1.5b": "hymba_1p5b",
    "xlstm-350m": "xlstm_350m",
    "dbrx-132b": "dbrx_132b",
    "blendfl-paper": "blendfl_paper",
}


def get_config(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
