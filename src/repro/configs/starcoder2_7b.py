"""StarCoder2-7B [arXiv:2402.19173]: 32L, d=4608, 36H GQA kv=4, ff=18432,
vocab=49152, RoPE, GELU MLP (pre-norm, learned-abs replaced by RoPE per card)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    act="gelu",
    pos="rope",
    qkv_bias=True,
    citation="arXiv:2402.19173",
)
