"""DeepSeekMoE-16B [arXiv:2401.06066]: 28L, d=2048, 16H MHA (kv=16),
expert ff=1408, vocab=102400; fine-grained MoE: 64 routed experts top-6
+ 2 shared experts."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    act="swiglu",
    pos="rope",
    citation="arXiv:2401.06066",
)
