"""The paper's own architecture family (MedFuse-style, [26] in the paper):
an LSTM-family encoder for EHR time-series + a vision encoder for CXR,
fused by a linear multimodal head. Our TPU-native re-expression uses an
xLSTM-pair stack as the recurrent EHR encoder backbone (the modern JAX
equivalent of the paper's 2-layer LSTM) — the BlendFL federation layer in
repro.core instantiates small per-modality encoders directly, see
repro/core/encoders.py. This config exists so the paper's backbone is also
dry-runnable like the assigned archs."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="blendfl-paper",
    family="ssm",
    block_type="xlstm_pair",
    n_layers=4,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=512,
    pos="none",
    citation="BlendFL (this paper), MedFuse arch [26]",
)
