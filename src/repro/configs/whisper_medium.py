"""Whisper-medium [arXiv:2212.04356]: 24L enc + 24L dec, d=1024, 16H,
ff=4096, vocab=51865. Conv/mel frontend STUBBED (input_specs provides frame
embeddings, dim 80 mel bins); learned positions; encoder-decoder."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    block_type="encdec",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51865,
    act="gelu",
    pos="learned",
    frontend="audio_stub",
    frontend_dim=80,
    citation="arXiv:2212.04356",
)
