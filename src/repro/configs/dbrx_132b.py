"""DBRX-132B [hf:databricks/dbrx-base]: 40L, d=6144, 48H GQA kv=8,
expert ff=10752, vocab=100352; fine-grained MoE: 16 experts top-4."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    n_experts=16,
    n_shared_experts=0,
    top_k=4,
    act="swiglu",
    pos="rope",
    citation="hf:databricks/dbrx-base",
)
