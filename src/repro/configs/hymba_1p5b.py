"""Hymba-1.5B [arXiv:2411.13676]: 32L, d=1600, 25H GQA kv=5 (head_dim 64),
ff=5504, vocab=32001; parallel attention + Mamba heads per block,
ssm_state=16; sliding-window attention for most layers (window 1024 global
mix in the paper; we use SWA throughout -> natively sub-quadratic)."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    block_type="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    act="swiglu",
    pos="rope",
    attn_kind="sliding",
    window=1024,
    ssm_state=16,
    ssm_head_dim=64,
    citation="arXiv:2411.13676",
)
