"""xLSTM-350M [arXiv:2405.04517]: 24L (12 mLSTM/sLSTM pairs), d=1024, 4H,
d_ff=0 (projections live inside the cells), vocab=50304."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    block_type="xlstm_pair",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pos="none",
    ssm_expand=2,
    citation="arXiv:2405.04517",
)
