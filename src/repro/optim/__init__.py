from repro.optim.optimizers import (
    Optimizer,
    adamw,
    sgd,
    apply_updates,
    global_norm_clip,
)
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine

__all__ = [
    "Optimizer",
    "adamw",
    "sgd",
    "apply_updates",
    "global_norm_clip",
    "constant",
    "cosine_decay",
    "linear_warmup_cosine",
]
