"""Minimal functional optimizers (AdamW, SGD) — no optax dependency.

An ``Optimizer`` is a pair of pure functions:
    init(params)                  -> opt_state
    update(grads, state, params)  -> (updates, new_state)
``apply_updates(params, updates)`` adds the updates to the params.

Optimizer states are pytrees, so they shard with the params under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm_clip(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gnorm


def adamw(
    lr: float | Callable[[jnp.ndarray], jnp.ndarray],
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.01,
    state_dtype=jnp.float32,
) -> Optimizer:
    """AdamW with decoupled weight decay. ``lr`` may be a schedule fn(step)."""

    def init(params):
        return {
            "step": jnp.zeros([], jnp.int32),
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype), params),
            "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, state_dtype), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        mu = jax.tree.map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(state_dtype), state["mu"], grads
        )
        nu = jax.tree.map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(state_dtype)),
            state["nu"],
            grads,
        )
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def u(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            return -lr_t * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(state_dtype))

        updates = jax.tree.map(u, mu, nu, params)
        return updates, {"step": step, "mu": mu, "nu": nu}

    return Optimizer(init=init, update=update)


def sgd(lr: float | Callable, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros([], jnp.int32)}
        return {
            "step": jnp.zeros([], jnp.int32),
            "mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        }

    def update(grads, state, params):
        del params
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr_t * g, grads), {"step": step}
        mom = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mom"], grads
        )
        return jax.tree.map(lambda m: -lr_t * m, mom), {"step": step, "mom": mom}

    return Optimizer(init=init, update=update)
