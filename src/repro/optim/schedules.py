"""Learning-rate schedules as step -> lr callables."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_decay(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.minimum(step.astype(jnp.float32), total_steps) / max(total_steps, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1 - final_frac) * cos)

    return fn


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int, final_frac: float = 0.1):
    cos = cosine_decay(base_lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        return jnp.where(s < warmup, warm, cos(jnp.maximum(step - warmup, 0)))

    return fn
