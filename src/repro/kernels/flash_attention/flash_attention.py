"""Flash attention (online softmax) as a Pallas TPU kernel.

Schedule: grid = (B*Hq, num_q_blocks, num_k_blocks) with the K axis
innermost/sequential; the (m, l, acc) running statistics live in VMEM
scratch and persist across K iterations (standard TPU flash schedule).
Per program instance, VMEM holds one (block_q, d) Q tile and one
(block_k, d) K/V tile — MXU-aligned when block_q/block_k are multiples of
128 and d in {64, 128, 256}.

Supports GQA (K/V indexed by q_head // group via the BlockSpec index_map,
so kv heads are never materialized repeated), causal masking, and
sliding-window masking. Queries are end-aligned with keys (decode-style
suffix attention when Sq < Sk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            block_q: int, block_k: int, sq: int, sk: int,
            causal: bool, window: int, num_kb: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)  # (block_q, d)
    k = k_ref[0, 0].astype(jnp.float32)  # (block_k, d)
    v = v_ref[0, 0].astype(jnp.float32)
    # zero padded K/V rows: out-of-bounds block reads return garbage (NaN
    # in interpret mode) and 0 * NaN would poison the masked accumulation
    kv_valid = (kb * block_k
                + jax.lax.broadcasted_iota(jnp.int32, (block_k, 1), 0)) < sk
    k = jnp.where(kv_valid, k, 0.0)
    v = jnp.where(kv_valid, v, 0.0)
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qi = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + (sk - sq)
    ki = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = (qi < sk) & (ki < sk)
    if causal:
        mask = mask & (ki <= qi)
    if window > 0:
        mask = mask & (ki > qi - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]  # (block_q, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # rows with no visible key yet keep m = -inf; make exp well-defined
    safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.where(mask, jnp.exp(s - safe_m), 0.0)
    alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - safe_m), 0.0)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_scr[...] = m_new
    l_scr[...] = l_new
    acc_scr[...] = acc_new

    @pl.when(kb == num_kb - 1)
    def _done():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False):
    """q (B,Hq,Sq,d); k,v (B,Hkv,Sk,d) -> (B,Hq,Sq,d)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0
    group = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    num_qb = pl.cdiv(sq, block_q)
    num_kb = pl.cdiv(sk, block_k)

    qf = q.reshape(b * hq, sq, d)
    grid = (b * hq, num_qb, num_kb)

    kernel = functools.partial(
        _kernel, block_q=block_q, block_k=block_k, sq=sq, sk=sk,
        causal=causal, window=window, num_kb=num_kb)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qb, kb: (bh, qb, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bh, qb, kb, group=group, hq=hq:
                         (bh // hq, (bh % hq) // group, kb, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bh, qb, kb, group=group, hq=hq:
                         (bh // hq, (bh % hq) // group, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qb, kb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, k, v)
    return out.reshape(b, hq, sq, d)
