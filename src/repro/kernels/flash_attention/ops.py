"""Public jit'd wrapper for the flash attention kernel.

On non-TPU backends the pallas_call runs in interpret mode (kernel body
executed in Python) so correctness is CPU-testable; on TPU it lowers via
Mosaic.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import on_tpu
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=not on_tpu())
