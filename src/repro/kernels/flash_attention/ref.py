"""Pure-jnp oracle for the flash attention kernel (GQA + causal + SWA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = float("-inf")


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q (B, Hq, Sq, d); k, v (B, Hkv, Sk, d); Hq % Hkv == 0.

    window > 0 restricts each query to the last `window` keys (inclusive of
    itself) — sliding-window attention.
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qi = jnp.arange(sq)[:, None] + (sk - sq)  # queries end-aligned with keys
    ki = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = ki <= qi
    if window > 0:
        mask = mask & (ki > qi - window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
