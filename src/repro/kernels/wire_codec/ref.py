"""Pure-jnp oracle for the fused wire-codec round-trip kernel."""
from __future__ import annotations

import jax.numpy as jnp


def wire_codec_ref(x, scale_thresh, *, quantize: bool):
    """x (L, N); scale_thresh (L, 2) per-row [int8 scale, top-k |x|
    threshold]. Returns the decoded (L, N) reconstruction: entries with
    |x| < thresh are dropped (sent as implicit zeros); kept entries are
    optionally round-tripped through symmetric int8 at q = round(x *
    127/scale), dequantized as q * scale/127."""
    xf = x.astype(jnp.float32)
    scale = scale_thresh[:, 0:1].astype(jnp.float32)
    thresh = scale_thresh[:, 1:2].astype(jnp.float32)
    keep = jnp.abs(xf) >= thresh
    if quantize:
        q = jnp.clip(jnp.round(xf * (127.0 / scale)), -127.0, 127.0)
        xf = q * (scale / 127.0)
    return jnp.where(keep, xf, 0.0).astype(x.dtype)
