"""Fused quantize + sparsify + dequantize wire-codec pass as a Pallas kernel.

Simulated lossy wire round-trip for one batch of flattened messages
(rows = client candidates on the uplink, a single row on the downlink).
Given per-row symmetric int8 scales and top-k magnitude thresholds
(computed outside by one batched ``lax.top_k`` over |x| — a data-
dependent exact top-k scatter is not expressible as a single streaming
pass, but threshold-select is), the kernel applies the whole
encode->decode pipeline in ONE pass over each element:

    keep = |x| >= thresh            # magnitude top-k sparsification
    q    = clip(round(x * 127/s))   # symmetric int8 quantization
    out  = where(keep, q * s/127, 0)

so the round is memory-bound at exactly one read + one write per
parameter, instead of the three materialized passes (scale, quantize,
mask) a naive composition of the codecs would issue.

Grid: (rows, num_blocks) over the flattened parameter axis. Per program,
VMEM holds a (1, block_n) tile of one row plus that row's (1, 2)
[scale, thresh] pair. ``quantize`` is a static flag: the pure top-k
codec skips the rounding so that frac=1.0 is bit-exact identity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, st_ref, o_ref, *, quantize):
    x = x_ref[...].astype(jnp.float32)  # (1, block_n)
    scale = st_ref[0, 0]
    thresh = st_ref[0, 1]
    keep = jnp.abs(x) >= thresh
    if quantize:
        q = jnp.clip(jnp.round(x * (127.0 / scale)), -127.0, 127.0)
        x = q * (scale / 127.0)
    o_ref[...] = jnp.where(keep, x, 0.0).astype(o_ref.dtype)


def wire_codec_pallas(x, scale_thresh, *, quantize: bool,
                      block_n: int = 2048, interpret: bool = False):
    """x (L, N) rows; scale_thresh (L, 2) per-row [scale, thresh].

    Returns the (L, N) decoded reconstruction (same dtype as x).
    """
    l, n = x.shape
    block_n = min(block_n, n)
    pad = (-n) % block_n
    if pad:  # zero pad: padded lanes decode to 0 and are sliced off
        x = jnp.pad(x, ((0, 0), (0, pad)))
    n_padded = n + pad
    grid = (l, n_padded // block_n)
    out = pl.pallas_call(
        functools.partial(_kernel, quantize=quantize),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, 2), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((l, n_padded), x.dtype),
        interpret=interpret,
    )(x, scale_thresh)
    return out[:, :n]
