"""Public jit'd wrapper: lossy wire round-trip of a batch of messages.

``wire_codec_roundtrip`` is the encode+decode hot path used by
``repro.core.codec``: one batched ``lax.top_k`` over |x| yields, per
row, both the symmetric int8 scale (vals[:, 0] = abs-max) and the
magnitude top-k threshold (vals[:, k-1]); the fused Pallas kernel then
streams each row once, applying sparsify + quantize + dequantize.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.wire_codec.wire_codec import wire_codec_pallas

# guards all-zero rows: q = x * 127/eps is still exactly 0 for x == 0
_EPS = 1e-30


@functools.partial(jax.jit,
                   static_argnames=("k", "quantize", "block_n", "interpret"))
def _roundtrip(x, k, quantize, block_n, interpret):
    ax = jnp.abs(x.astype(jnp.float32))
    n = x.shape[1]
    if k is not None and k < n:
        vals = jax.lax.top_k(ax, k)[0]  # (L, k) descending magnitudes
        amax, thresh = vals[:, 0], vals[:, -1]
    else:  # dense: keep everything (thresh 0 keeps exact zeros too)
        amax = jnp.max(ax, axis=1)
        thresh = jnp.zeros_like(amax)
    scale = jnp.maximum(amax, _EPS)
    st = jnp.stack([scale, thresh], axis=1)
    return wire_codec_pallas(x, st, quantize=quantize, block_n=block_n,
                             interpret=interpret)


def wire_codec_roundtrip(x, *, k: int | None = None, quantize: bool = False,
                         block_n: int = 2048):
    """x (L, N) float rows -> (L, N) decoded reconstruction.

    k: keep the k largest-|x| entries per row (None = dense); ties at
    the threshold magnitude are all kept. quantize: round-trip kept
    entries through per-row symmetric int8. k >= N with quantize=False
    is exactly the identity.
    """
    return _roundtrip(x, k, quantize, block_n, not on_tpu())
