"""Public jit'd wrapper for the fused sLSTM cell kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels import on_tpu
from repro.kernels.slstm_cell.slstm_cell import slstm_cell_pallas


@functools.partial(jax.jit, static_argnames=("chunk",))
def slstm_cell(pre_x, r, *, chunk: int = 256):
    return slstm_cell_pallas(pre_x, r, chunk=chunk, interpret=not on_tpu())
