"""Fused sLSTM recurrence as a Pallas TPU kernel.

Motivation (EXPERIMENTS.md §Perf A): under XLA, the sLSTM time-scan
re-reads the recurrent weights r (H, hd, 4hd) from HBM every time step —
the dominant HBM stream of xlstm-350m training even after the A.1/A.3
fixes. This kernel pins r_h in VMEM for the whole sequence and streams
only the per-step pre-activations and outputs:

    HBM traffic: S * (pre chunk + h out)  +  r ONCE            (kernel)
                 S * (pre + h + r + state spills)              (XLA scan)

Schedule: grid = (B, H, num_chunks) with the chunk axis innermost and
sequential; the (c, n, m, h) state lives in VMEM scratch and persists
across chunks; within a chunk a fori_loop steps the recurrence, doing
the (1, hd) x (hd, 4hd) recurrent matmul on the MXU.

Stabilized update (Beck et al.):
    rec   = h_{t-1} @ r_h                       (4hd,)
    z     = tanh(pre_z + rec_z)
    m_t   = max(log_f + m, log_i);  i = exp(log_i - m_t)
    f     = exp(log_f + m - m_t)
    c_t   = f*c + i*z ; n_t = f*n + i ; h_t = o * c_t / max(|n_t|, 1)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(pre_ref, r_ref, o_ref, c_scr, n_scr, m_scr, h_scr, *,
            chunk: int, hd: int):
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        h_scr[...] = jnp.zeros_like(h_scr)

    r = r_ref[0].astype(jnp.float32)  # (hd, 4hd) — resident across chunks

    def step(t, _):
        pre = pre_ref[0, 0, t].astype(jnp.float32)  # (4, hd)
        h_prev = h_scr[...]  # (1, hd)
        rec = jax.lax.dot_general(h_prev, r, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
        rec = rec.reshape(4, hd)
        z = jnp.tanh(pre[0] + rec[0])
        log_i = pre[1] + rec[1]
        log_f = jax.nn.log_sigmoid(pre[2] + rec[2])
        o = jax.nn.sigmoid(pre[3] + rec[3])
        m_new = jnp.maximum(log_f + m_scr[0], log_i)
        i_g = jnp.exp(log_i - m_new)
        f_g = jnp.exp(log_f + m_scr[0] - m_new)
        c_new = f_g * c_scr[0] + i_g * z
        n_new = f_g * n_scr[0] + i_g
        h = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
        c_scr[0] = c_new
        n_scr[0] = n_new
        m_scr[0] = m_new
        h_scr[0] = h
        o_ref[0, 0, t] = h.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


def slstm_cell_pallas(pre_x, r, *, chunk: int = 256, interpret: bool = False):
    """pre_x (B, H, S, 4, hd) pre-activations [z, i, f, o]; r (H, hd, 4hd).

    Returns h (B, H, S, hd). State starts at zero (m at -inf)."""
    b, h, s, four, hd = pre_x.shape
    assert four == 4
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        pre_x = jnp.pad(pre_x, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s + pad
    nc = sp // chunk

    kernel = functools.partial(_kernel, chunk=chunk, hd=hd)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, 4, hd), lambda bi, hi, cb: (bi, hi, cb, 0, 0)),
            pl.BlockSpec((1, hd, 4 * hd), lambda bi, hi, cb: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, hd), lambda bi, hi, cb: (bi, hi, cb, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sp, hd), pre_x.dtype),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(pre_x, r)
    return out[:, :, :s]
