"""Pure-jnp sequential oracle for the fused sLSTM cell kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def slstm_cell_ref(pre_x, r):
    """pre_x (B, H, S, 4, hd); r (H, hd, 4hd) -> h (B, H, S, hd)."""
    b, h, s, _, hd = pre_x.shape
    zero = jnp.zeros((b, h, hd), jnp.float32)
    state0 = (zero, zero, zero - 1e30, zero)  # c, n, m, h_prev

    def step(carry, pre_t):
        c, n, m, h_prev = carry
        rec = jnp.einsum("bhi,hij->bhj", h_prev, r.astype(jnp.float32))
        rec = rec.reshape(b, h, 4, hd)
        pre = pre_t.astype(jnp.float32)  # (B, H, 4, hd)
        z = jnp.tanh(pre[:, :, 0] + rec[:, :, 0])
        log_i = pre[:, :, 1] + rec[:, :, 1]
        log_f = jax.nn.log_sigmoid(pre[:, :, 2] + rec[:, :, 2])
        o = jax.nn.sigmoid(pre[:, :, 3] + rec[:, :, 3])
        m_new = jnp.maximum(log_f + m, log_i)
        i_g = jnp.exp(log_i - m_new)
        f_g = jnp.exp(log_f + m - m_new)
        c_new = f_g * c + i_g * z
        n_new = f_g * n + i_g
        h_t = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
        return (c_new, n_new, m_new, h_t), h_t

    _, hs = jax.lax.scan(step, state0, jnp.moveaxis(pre_x, 2, 0))
    return jnp.moveaxis(hs, 0, 2).astype(pre_x.dtype)
