from repro.kernels.slstm_cell.ops import slstm_cell

__all__ = ["slstm_cell"]
