"""Public jit'd wrapper for the chunkwise mLSTM/SSD scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels import on_tpu
from repro.kernels.mlstm_scan.mlstm_scan import mlstm_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "normalize"))
def mlstm_scan(q, k, v, log_f, *, chunk: int = 128, normalize: bool = True):
    return mlstm_scan_pallas(q, k, v, log_f, chunk=chunk, normalize=normalize,
                             interpret=not on_tpu())
