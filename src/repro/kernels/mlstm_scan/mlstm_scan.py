"""Chunkwise-parallel mLSTM / Mamba-SSD recurrence as a Pallas TPU kernel.

The recurrence C_t = exp(lf_t) C_{t-1} + k_t v_t^T is evaluated in chunks:
an intra-chunk attention-like term (two MXU matmuls over a (chunk, chunk)
decay-weighted score matrix) plus an inter-chunk term carried through the
running state. The (dk, dv) state and (1, dk) normalizer live in VMEM
scratch and persist across the sequential chunk axis of the grid — the TPU
analogue of the recurrent loop, with all heavy math on the MXU.

Grid: (B*H, num_chunks), chunk axis innermost/sequential.
VMEM per program: q/k (chunk, dk), v (chunk, dv), lf (1, chunk),
state (dk, dv) + (1, dk) — e.g. chunk=128, dk=dv=512 -> ~1.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, lf_ref, o_ref, c_scr, n_scr, *,
            chunk: int, normalize: bool):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)

    q = q_ref[0].astype(jnp.float32)  # (chunk, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # (chunk, dv)
    lf = lf_ref[0].astype(jnp.float32)  # (chunk,)

    d_in = jnp.cumsum(lf)  # inclusive in-chunk cumulative log decay
    d_tot = d_in[-1]

    # intra-chunk: S_ij = (q_i . k_j) exp(d_i - d_j), j <= i
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    decay = d_in[:, None] - d_in[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(jj <= ii, scores * jnp.exp(decay), 0.0)
    intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    intra_n = jnp.sum(scores, axis=1)  # (chunk,)

    # inter-chunk from carried state
    qw = q * jnp.exp(d_in)[:, None]
    inter = jax.lax.dot_general(qw, c_scr[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    inter_n = jax.lax.dot_general(qw, n_scr[...], (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)[:, 0]

    h = intra + inter
    if normalize:
        h = h / jnp.maximum(jnp.abs(intra_n + inter_n), 1.0)[:, None]
    o_ref[0] = h.astype(o_ref.dtype)

    # state update: C <- exp(D) C + sum_j exp(D - d_j) k_j v_j^T
    kw = k * jnp.exp(d_tot - d_in)[:, None]
    c_scr[...] = jnp.exp(d_tot) * c_scr[...] + jax.lax.dot_general(
        kw, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    n_scr[...] = jnp.exp(d_tot) * n_scr[...] + jnp.sum(kw, axis=0)[None, :]


def mlstm_scan_pallas(q, k, v, log_f, *, chunk: int = 128,
                      normalize: bool = True, interpret: bool = False):
    """q,k (B,H,S,dk); v (B,H,S,dv); log_f (B,H,S) -> h (B,H,S,dv)."""
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        padfn = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 3))
        q, k, v, log_f = padfn(q), padfn(k), padfn(v), padfn(log_f)
    sp = s + pad
    nc = sp // chunk

    fold = lambda x: x.reshape(b * h, sp, *x.shape[3:])
    qf, kf, vf, lff = fold(q), fold(k), fold(v), fold(log_f)

    kernel = functools.partial(_kernel, chunk=chunk, normalize=normalize)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda bh, cb: (bh, cb, 0)),
            pl.BlockSpec((1, chunk, dk), lambda bh, cb: (bh, cb, 0)),
            pl.BlockSpec((1, chunk, dv), lambda bh, cb: (bh, cb, 0)),
            pl.BlockSpec((1, chunk), lambda bh, cb: (bh, cb)),
        ],
        out_specs=pl.BlockSpec((1, chunk, dv), lambda bh, cb: (bh, cb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sp, dv), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((dk, dv), jnp.float32),
            pltpu.VMEM((1, dk), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, lff)
    return out.reshape(b, h, sp, dv)[:, :, :s]
