"""Sequential oracle for the chunkwise mLSTM/SSD scan kernel."""
from __future__ import annotations

from repro.models.recurrent import gated_linear_scan_ref


def mlstm_scan_ref(q, k, v, log_f, *, normalize: bool = True):
    """q,k (B,H,S,dk); v (B,H,S,dv); log_f (B,H,S). Step-by-step recurrence:

        C_t = exp(lf_t) C_{t-1} + k_t v_t^T ;  n_t = exp(lf_t) n_{t-1} + k_t
        h_t = q_t C_t [/ max(|q_t.n_t|, 1)]
    """
    return gated_linear_scan_ref(q, k, v, log_f, normalize=normalize)
