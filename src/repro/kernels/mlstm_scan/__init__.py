from repro.kernels.mlstm_scan.ops import mlstm_scan

__all__ = ["mlstm_scan"]
