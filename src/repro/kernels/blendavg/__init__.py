from repro.kernels.blendavg.ops import blend_params

__all__ = ["blend_params"]
