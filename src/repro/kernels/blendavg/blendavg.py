"""BlendAvg fused parameter blend as a Pallas TPU kernel.

The server-side hot-spot of the paper's technique: blending L client
models (Eq. 11) is a purely memory-bound streaming reduction over up to
132 B parameters. A naive implementation issues L scaled-add passes
(reading N*L + writing N*L intermediates); this kernel streams each
(L, block_n) tile through VMEM exactly once and writes each output element
once — the roofline-optimal single-pass schedule.

Grid: (num_blocks,) over the flattened parameter axis. Per program, VMEM
holds an (L, block_n) tile of the stacked models and the (L, 1) weight
vector; the output tile is the f32-accumulated weighted sum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, omega_ref, o_ref):
    tile = w_ref[...].astype(jnp.float32)  # (L, block_n)
    om = omega_ref[...].astype(jnp.float32)  # (L, 1)
    o_ref[...] = jnp.sum(tile * om, axis=0, keepdims=True).astype(o_ref.dtype)


def blend_params_pallas(stacked, omega, *, block_n: int = 2048, interpret: bool = False):
    """stacked (L, N); omega (L,) -> (N,)."""
    l, n = stacked.shape
    block_n = min(block_n, n)
    pad = (-n) % block_n
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    n_padded = n + pad
    grid = (n_padded // block_n,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((l, block_n), lambda i: (0, i)),
            pl.BlockSpec((l, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_padded), stacked.dtype),
        interpret=interpret,
    )(stacked, omega[:, None])
    return out[0, :n]
