"""Pure-jnp oracle for the BlendAvg parameter-blend kernel."""
from __future__ import annotations

import jax.numpy as jnp


def blend_params_ref(stacked, omega):
    """stacked (L, N) client parameters; omega (L,) blend weights
    (already masked: discarded models carry omega=0). Returns (N,) f32-
    accumulated weighted sum cast back to the input dtype."""
    w = omega.astype(jnp.float32)[:, None]
    return jnp.sum(stacked.astype(jnp.float32) * w, axis=0).astype(stacked.dtype)
