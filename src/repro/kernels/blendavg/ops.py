"""Public wrapper: blend a pytree (or flat array) of stacked client params."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.blendavg.blendavg import blend_params_pallas


@functools.partial(jax.jit, static_argnames=("block_n",))
def blend_params(stacked, omega, *, block_n: int = 2048):
    """stacked: (L, N) array OR pytree whose leaves have leading dim L.
    omega (L,) masked blend weights. Returns blended array / pytree."""
    interpret = not on_tpu()
    if isinstance(stacked, jnp.ndarray) or hasattr(stacked, "shape"):
        return blend_params_pallas(stacked, omega, block_n=block_n,
                                   interpret=interpret)

    def blend_leaf(leaf):
        l = leaf.shape[0]
        flat = leaf.reshape(l, -1)
        out = blend_params_pallas(flat, omega, block_n=block_n, interpret=interpret)
        return out.reshape(leaf.shape[1:])

    return jax.tree.map(blend_leaf, stacked)
