"""Pallas TPU kernels for the compute hot-spots.

Each kernel ships as a subpackage:  <name>/<name>.py (pl.pallas_call +
BlockSpec VMEM tiling), <name>/ops.py (jit'd public wrapper), and
<name>/ref.py (pure-jnp oracle used by the sweep tests).

On the CPU backend (this container) kernels execute with interpret=True
(the kernel body runs in Python), which is how correctness is validated;
on TPU the same pallas_call lowers through Mosaic.
"""
import functools

import jax


@functools.cache
def on_tpu() -> bool:
    """Shared backend probe for the jit'd kernel wrappers.

    The backend cannot change within a process, so the probe is cached:
    wrappers decide ``interpret=not on_tpu()`` once instead of calling
    ``jax.default_backend()`` (which walks the backend registry) on
    every trace. Defined above the subpackage imports so that ops
    modules can ``from repro.kernels import on_tpu`` without a cycle.
    """
    return jax.default_backend() == "tpu"


from repro.kernels.flash_attention.ops import flash_attention  # noqa: E402
from repro.kernels.blendavg.ops import blend_params  # noqa: E402
from repro.kernels.mlstm_scan.ops import mlstm_scan  # noqa: E402
from repro.kernels.wire_codec.ops import wire_codec_roundtrip  # noqa: E402

__all__ = ["on_tpu", "flash_attention", "blend_params", "mlstm_scan",
           "wire_codec_roundtrip"]
