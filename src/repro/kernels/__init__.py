"""Pallas TPU kernels for the compute hot-spots.

Each kernel ships as a subpackage:  <name>/<name>.py (pl.pallas_call +
BlockSpec VMEM tiling), <name>/ops.py (jit'd public wrapper), and
<name>/ref.py (pure-jnp oracle used by the sweep tests).

On the CPU backend (this container) kernels execute with interpret=True
(the kernel body runs in Python), which is how correctness is validated;
on TPU the same pallas_call lowers through Mosaic.
"""
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.blendavg.ops import blend_params
from repro.kernels.mlstm_scan.ops import mlstm_scan

__all__ = ["flash_attention", "blend_params", "mlstm_scan"]
