"""Common utilities: pytree helpers, dtype policies, rng helpers."""
from repro.common.tree import (
    tree_zeros_like,
    tree_add,
    tree_scale,
    tree_weighted_sum,
    tree_l2_norm,
    tree_size,
    tree_cast,
    tree_stack,
    tree_unstack,
    tree_index,
)

__all__ = [
    "tree_zeros_like",
    "tree_add",
    "tree_scale",
    "tree_weighted_sum",
    "tree_l2_norm",
    "tree_size",
    "tree_cast",
    "tree_stack",
    "tree_unstack",
    "tree_index",
]
