"""Pytree arithmetic helpers (we do not depend on optax/chex)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_zeros_like(tree):
    return jax.tree.map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_weighted_sum(trees, weights):
    """sum_i weights[i] * trees[i] for a list of pytrees."""
    out = tree_scale(trees[0], weights[0])
    for t, w in zip(trees[1:], weights[1:]):
        out = jax.tree.map(lambda o, x, w=w: o + w * x, out, t)
    return out


def tree_l2_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_size(tree) -> int:
    """Total number of scalar parameters."""
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )


def tree_stack(trees):
    """Stack a list of identical pytrees along a new leading axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def tree_unstack(tree, n: int):
    """Inverse of tree_stack: a stacked pytree -> list of n pytrees."""
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(n)]


def tree_index(tree, i):
    """Dynamic index into the leading (stacked) axis of every leaf."""
    return jax.tree.map(lambda x: jax.lax.dynamic_index_in_dim(x, i, 0, keepdims=False), tree)
