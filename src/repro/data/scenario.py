"""Churn scenarios — declarative mid-run cohort events for a federation.

Real federations are not fixed cohorts: hospitals onboard mid-study,
clients drop out, and some turn adversarial. A ``Scenario`` is a sorted
list of per-round events:

    join       int — this many fresh clients join BEFORE round r runs
               (their model rows adopt the current globals; their data
               was partitioned up-front but held out of the active set)
    leave      tuple of client ids that depart before round r (their
               state rows are retired; they are never sampled again)
    corrupt    tuple of client ids whose labels flip starting at round r
               (a label-flipping adversary — the classic poisoning model)
    sign_flip  tuple of client ids that, starting at round r, upload the
               NEGATED model delta (a gradient-space Byzantine attacker:
               candidate = anchor - (trained - anchor))
    scale      tuple of client ids that upload a boosted delta
               (candidate = anchor + SCALE_FACTOR * (trained - anchor),
               the model-replacement / scaling attack)
    backdoor   tuple of client ids that, starting at round r, train a
               targeted backdoor: a fraction BACKDOOR_FRAC of their
               drawn rows get a fixed trigger patch stamped into the
               inputs (``apply_trigger``) and their label replaced by
               the attacker's target (``backdoor_target``)

Sign-flip and scale act on the client→server candidate uplink: the
driver turns them into a per-sampled-client coefficient vector
(``attack_coef``) that is *data* to the jitted round — the set of
attackers can change round to round without recompiling — and applies
it BEFORE the wire codec, so defenses see exactly what a real server
would decode. Backdoor is data poisoning and lives entirely in the
batcher, like ``corrupt``.

Membership is pure host-side bookkeeping over the round index: the
stacked round state only ever grows (to capacity buckets, see
``repro.core.state.capacity_for``); who is *active* at round r is the
boolean mask ``active_mask(r, ...)``, consumed by the participation
policies so inactive rows are simply never sampled. All queries are
pure functions of (events, r) — a resumed run at round r sees exactly
the membership the original run saw, which is what keeps
``--selftest-resume`` bit-exact across churn.

Scenario files are YAML::

    events:
      - round: 3
        join: 4
      - round: 5
        leave: [0, 1]
        corrupt: [2]
        sign_flip: [3]

Parsed with PyYAML when available; otherwise a built-in mini-parser
covers exactly this shape (the CI image has no yaml), so scenario files
load identically everywhere.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

# Gradient-space attack constants. SCALE_FACTOR is the boost applied by
# `scale` attackers to their model delta; TRIGGER_VALUE / BACKDOOR_FRAC
# define the backdoor trigger patch and how much of a backdoor client's
# drawn batch is poisoned. All three are deliberately module constants,
# not per-event knobs: the attack *membership* is scenario data, the
# attack *shape* is fixed, which keeps the jitted round's structure
# static and resume bit-exact.
SCALE_FACTOR = 10.0
TRIGGER_VALUE = 3.0
BACKDOOR_FRAC = 0.5

_ATTACK_KINDS = ("sign_flip", "scale", "backdoor")


@dataclasses.dataclass(frozen=True)
class Event:
    """One round's cohort changes, applied BEFORE the round runs."""

    round: int
    join: int = 0
    leave: tuple = ()
    corrupt: tuple = ()
    sign_flip: tuple = ()
    scale: tuple = ()
    backdoor: tuple = ()

    def __post_init__(self):
        if self.round < 1:
            raise ValueError(
                f"scenario events start at round 1 (round 0 membership is "
                f"the --clients flag), got round={self.round}")
        if self.join < 0:
            raise ValueError(f"join must be >= 0, got {self.join}")
        for f in ("leave", "corrupt") + _ATTACK_KINDS:
            object.__setattr__(self, f,
                               tuple(int(i) for i in getattr(self, f)))
        ids = (self.leave + self.corrupt + self.sign_flip + self.scale
               + self.backdoor)
        if any(i < 0 for i in ids):
            raise ValueError(f"client ids must be >= 0: {self}")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """An immutable, round-sorted event list with pure membership queries.

    Client ids are global and stable: the initial cohort is
    ``0..n_initial-1``, joiners take the next ids in join order, and a
    departed id is never reused (its state row is retired, its slot
    masked inactive forever).
    """

    events: tuple = ()

    def __post_init__(self):
        evs = tuple(sorted(self.events, key=lambda e: e.round))
        rounds = [e.round for e in evs]
        if len(set(rounds)) != len(rounds):
            raise ValueError(f"duplicate event rounds: {sorted(rounds)}")
        object.__setattr__(self, "events", evs)

    def total_joins(self) -> int:
        return sum(e.join for e in self.events)

    def events_at(self, r: int) -> Event | None:
        """The event applied before round ``r`` runs, if any."""
        for e in self.events:
            if e.round == r:
                return e
        return None

    def n_clients_at(self, r: int, n_initial: int) -> int:
        """Total ids EVER assigned once all events with round <= r have
        been applied (departed clients still count — ids are never
        reused). ``r = -1`` (before any event) is ``n_initial``."""
        return n_initial + sum(e.join for e in self.events if e.round <= r)

    def left_ids(self, r: int) -> tuple:
        return tuple(sorted(i for e in self.events if e.round <= r
                            for i in e.leave))

    def corrupt_ids(self, r: int) -> tuple:
        return tuple(sorted(i for e in self.events if e.round <= r
                            for i in e.corrupt))

    def sign_flip_ids(self, r: int) -> tuple:
        return tuple(sorted(i for e in self.events if e.round <= r
                            for i in e.sign_flip))

    def scale_ids(self, r: int) -> tuple:
        return tuple(sorted(i for e in self.events if e.round <= r
                            for i in e.scale))

    def backdoor_ids(self, r: int) -> tuple:
        return tuple(sorted(i for e in self.events if e.round <= r
                            for i in e.backdoor))

    def has_uplink_attacks(self) -> bool:
        """True when any event carries a sign-flip or scale attacker —
        i.e. the driver must thread an ``attack_coef`` batch key.
        Backdoor is pure data poisoning and needs no uplink hook."""
        return any(e.sign_flip or e.scale for e in self.events)

    def attack_coef(self, r: int, ids) -> np.ndarray:
        """Per-sampled-client uplink coefficients for round ``r``: 1.0
        for an honest client, -1.0 for a sign-flipper, ``SCALE_FACTOR``
        for a scaler. The driver applies ``candidate = anchor +
        coef * (trained - anchor)`` (with an exact passthrough at
        coef == 1.0), so the coefficient vector — not the attacker set —
        is what crosses into the jitted round as data."""
        flip, scale = set(self.sign_flip_ids(r)), set(self.scale_ids(r))
        coef = np.ones(len(ids), np.float32)
        for k, i in enumerate(ids):
            if int(i) in flip:
                coef[k] = -1.0
            elif int(i) in scale:
                coef[k] = SCALE_FACTOR
        return coef

    def active_mask(self, r: int, n_initial: int, capacity: int) -> np.ndarray:
        """(capacity,) bool: which state rows hold an active member when
        round ``r`` runs. Rows past ``n_clients_at(r)`` are padding;
        departed ids are off."""
        n = self.n_clients_at(r, n_initial)
        if n > capacity:
            raise ValueError(f"{n} clients exceed state capacity {capacity}")
        mask = np.zeros(capacity, bool)
        mask[:n] = True
        left = [i for i in self.left_ids(r) if i < capacity]
        mask[left] = False
        return mask

    def validate(self, n_initial: int) -> "Scenario":
        """Check event ids against the cohort each event sees: you cannot
        remove or corrupt a client that has not joined yet (or at all),
        and a departed client cannot depart twice."""
        gone: set = set()
        for e in self.events:
            n = self.n_clients_at(e.round, n_initial)
            for i in (e.leave + e.corrupt + e.sign_flip + e.scale
                      + e.backdoor):
                if i >= n:
                    raise ValueError(
                        f"round {e.round} references client {i}, but only "
                        f"{n} ids exist by then")
            dup = gone.intersection(e.leave)
            if dup:
                raise ValueError(
                    f"round {e.round} removes already-departed clients "
                    f"{sorted(dup)}")
            gone.update(e.leave)
        last = max((e.round for e in self.events), default=0)
        both = set(self.sign_flip_ids(last)) & set(self.scale_ids(last))
        if both:
            raise ValueError(
                f"clients {sorted(both)} are both sign_flip and scale "
                f"attackers — the uplink coefficient would be ambiguous")
        return self


def flip_labels(y: np.ndarray, kind: str) -> np.ndarray:
    """Label-flipping corruption: binary/multilabel targets invert
    (y -> 1 - y); multiclass one-hot rows rotate to the next class
    (``np.roll`` along the class axis) — both are the standard
    deterministic poisoning transforms, so a corrupt client's batches
    stay a pure function of (seed, round) and resume stays bit-exact."""
    y = np.asarray(y)
    if kind == "multiclass":
        if y.shape[-1] < 2:
            # np.roll over a single class is the identity — the
            # "corruption" would silently do nothing.
            raise ValueError(
                f"multiclass label flip needs >= 2 classes, got "
                f"class axis of size {y.shape[-1]}")
        return np.roll(y, 1, axis=-1)
    return (1.0 - y).astype(y.dtype)


def apply_trigger(x: np.ndarray) -> np.ndarray:
    """Stamp the backdoor trigger into a batch of inputs: the first
    timestep's first two features are set to ``TRIGGER_VALUE`` — a
    fixed, input-independent patch (the classic pixel-pattern trigger),
    so triggered inputs are recognizable regardless of content. Returns
    a copy; the input is never mutated."""
    x = np.asarray(x).copy()
    x[..., 0, :min(2, x.shape[-1])] = TRIGGER_VALUE
    return x


def backdoor_target(kind: str, out_dim: int) -> np.ndarray:
    """The attacker's target label: class 0 for multiclass (one-hot),
    all-ones for binary/multilabel. Fixed per task, so backdoor success
    rate is simply the fraction of triggered inputs the global model
    maps to this label."""
    if kind == "multiclass":
        y = np.zeros(out_dim, np.float32)
        y[0] = 1.0
        return y
    return np.ones(out_dim, np.float32)


def backdoor_rows(n: int) -> int:
    """How many of a backdoor client's ``n`` drawn rows get poisoned:
    the first ``ceil(BACKDOOR_FRAC * n)`` — a deterministic prefix of
    the (seed, round)-pure draw, so poisoning adds no RNG state and
    resume stays bit-exact."""
    return math.ceil(BACKDOOR_FRAC * n)


# ------------------------------------------------------------- file loading --

def _mini_yaml(text: str) -> dict:
    """Restricted YAML subset parser for scenario files (the CI image has
    no PyYAML): a top-level ``events:`` key, ``- key: value`` list items
    with two-space continuation lines, int scalars, and inline
    ``[a, b]`` int lists. Comments and blank lines are ignored."""

    def scalar(tok: str):
        tok = tok.strip()
        if tok.startswith("[") and tok.endswith("]"):
            body = tok[1:-1].strip()
            return [int(t) for t in body.split(",")] if body else []
        return int(tok)

    events, current = [], None
    lines = [ln.split("#", 1)[0].rstrip() for ln in text.splitlines()]
    in_events = False
    for ln in lines:
        if not ln.strip():
            continue
        if not ln.startswith(" "):
            if ln.rstrip(":") != "events":
                raise ValueError(f"mini-yaml: unsupported top-level {ln!r}")
            in_events = True
            continue
        if not in_events:
            raise ValueError(f"mini-yaml: content before 'events:': {ln!r}")
        item = ln.strip()
        if item.startswith("- "):
            current = {}
            events.append(current)
            item = item[2:]
        elif current is None:
            raise ValueError(f"mini-yaml: mapping line outside an item: {ln!r}")
        key, _, val = item.partition(":")
        if not _:
            raise ValueError(f"mini-yaml: expected 'key: value', got {ln!r}")
        current[key.strip()] = scalar(val)
    return {"events": events}


def parse_scenario(doc: dict) -> Scenario:
    """Build a Scenario from a parsed document (the shape both PyYAML and
    the mini-parser produce)."""
    if not isinstance(doc, dict) or "events" not in doc:
        raise ValueError("scenario file must be a mapping with an "
                         "'events' list")
    evs = []
    for item in doc["events"] or []:
        unknown = set(item) - ({"round", "join", "leave", "corrupt"}
                               | set(_ATTACK_KINDS))
        if unknown:
            raise ValueError(f"unknown scenario event keys: {sorted(unknown)}")
        if "round" not in item:
            raise ValueError(f"scenario event missing 'round': {item}")
        evs.append(Event(round=int(item["round"]),
                         join=int(item.get("join", 0)),
                         leave=tuple(item.get("leave", ())),
                         corrupt=tuple(item.get("corrupt", ())),
                         sign_flip=tuple(item.get("sign_flip", ())),
                         scale=tuple(item.get("scale", ())),
                         backdoor=tuple(item.get("backdoor", ()))))
    return Scenario(tuple(evs))


def load_scenario(path: str) -> Scenario:
    """Load a scenario YAML file; PyYAML when importable, the built-in
    mini-parser otherwise (identical result for the supported subset)."""
    with open(path) as f:
        text = f.read()
    try:
        import yaml
        doc = yaml.safe_load(text)
    except ImportError:
        doc = _mini_yaml(text)
    return parse_scenario(doc)
