"""Out-of-core client store: per-client shard files + JSON manifest.

``FederatedBatcher`` (see :mod:`repro.data.pipeline`) only ever touches
the drawn row subsets of each client's arrays — ``build()`` reads
``ds[key][sel]`` for a per-(seed, round) selection of at most the spec's
static row capacity. ``ClientStore`` exploits that access pattern to
take C past what one host's memory holds: each client's ragged
dict-of-arrays dataset is written once to per-client ``.npy`` shard
files, and reads open a memory map, gather exactly the selected rows
into a fresh array, and unmap — so a training round's peak host RSS is
O(K * N * row_bytes) regardless of the total dataset size.

Layout (one directory per federation)::

    <store_dir>/
      manifest.json              # version, n_clients, per-client
                                 #   key -> {shape, dtype}, val section,
                                 #   free-form meta (task dims, seeds)
      val/val_a.npy ...          # replicated server validation set
      client_00000/partial_a.npy # one shard file per (client, key)
      client_00000/frag_ids_a.npy
      ...

Design points:

- **Manifest is the index.** Row counts, dtypes, and shapes live in
  ``manifest.json``; ragged-ness checks and ``_draw`` sizing never open
  a shard file. A missing key means that client holds no such modality
  (zero-row arrays are recorded in the manifest but read back as
  materialized ``np.zeros`` — a zero-length file cannot be mmapped).
- **Writes are atomic.** Shards and manifest are staged in
  ``<store_dir>.tmp`` and ``os.rename``d into place, mirroring the
  checkpoint store's crash-safety contract: a partial import can never
  be mistaken for a complete store.
- **Bit-exact round-trip.** ``.npy`` preserves dtype and bytes exactly,
  so ``FederatedBatcher.from_store`` produces batches bit-identical to
  the in-memory loader's for the same (seed, round).
- **Multi-host seam.** ``rows_for_clients(ids, rows)`` reads specific
  row subsets of specific clients only — a future mesh-sliced loader
  calls it with its local shard of the sampled client ids and
  ``jax.device_put``s the result, never touching other hosts' clients.
- **Checkpoint identity.** ``fingerprint()`` hashes the canonical
  manifest; ``repro.launch.train_federated`` stamps it into round-state
  checkpoint metadata and refuses to resume against a different store.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import numpy as np

MANIFEST_NAME = "manifest.json"
STORE_VERSION = 1

_VAL_KEYS = ("val_a", "val_b", "val_y")


def _client_dirname(cid: int) -> str:
    return f"client_{cid:05d}"


class ShardRows:
    """Lazy row-reader for one (client, key) shard file.

    Supports exactly the accesses ``FederatedBatcher.build`` performs on
    an in-memory array — ``len(v)`` and ``v[sel]`` — plus ``.shape`` and
    ``.dtype`` from the manifest. ``__getitem__`` opens the ``.npy``
    memory map, materializes the selected rows, and closes the map, so
    no file pages stay resident between reads.
    """

    def __init__(self, path: str, shape: tuple, dtype: np.dtype):
        self.path = path
        self.shape = tuple(shape)
        self.dtype = np.dtype(dtype)

    def __len__(self) -> int:
        return self.shape[0]

    def __getitem__(self, sel) -> np.ndarray:
        if self.shape[0] == 0:
            return np.zeros(self.shape, self.dtype)[sel]
        mm = np.lib.format.open_memmap(self.path, mode="r")
        try:
            return np.array(mm[sel])  # gather + copy off the map
        finally:
            owner = getattr(mm, "_mmap", None)
            del mm
            if owner is not None:
                owner.close()

    def read(self) -> np.ndarray:
        """Materialize the whole shard (val set, tests)."""
        return self[slice(None)]


class ClientView:
    """Mapping-compatible view of one client's shards.

    Quacks like the dict-of-arrays client datasets ``FederatedBatcher``
    takes — ``keys()``/``__iter__``/``get``/``__getitem__``/``len`` —
    with :class:`ShardRows` values, so ``dict(view)`` stays lazy.
    """

    def __init__(self, store: "ClientStore", cid: int):
        self._store = store
        self._cid = cid
        self._keys = tuple(store.client_keys(cid))

    def keys(self):
        return self._keys

    def __iter__(self):
        return iter(self._keys)

    def __len__(self) -> int:
        return len(self._keys)

    def __contains__(self, key: str) -> bool:
        return key in self._keys

    def __getitem__(self, key: str) -> ShardRows:
        if key not in self._keys:
            raise KeyError(key)
        return self._store.shard(self._cid, key)

    def get(self, key: str, default=None):
        return self._store.shard(self._cid, key) if key in self._keys else default


class ClientStore:
    """Read handle over an on-disk federation store (see module doc)."""

    def __init__(self, store_dir: str):
        self.store_dir = str(store_dir)
        mpath = os.path.join(self.store_dir, MANIFEST_NAME)
        if not os.path.isfile(mpath):
            # a crashed overwrite swap can leave the complete previous
            # store only at <dir>.old (mirroring the checkpoint store's
            # contract) — pure read-path fallback, no renames here
            old = self.store_dir.rstrip("/") + ".old"
            if os.path.isfile(os.path.join(old, MANIFEST_NAME)):
                self.store_dir = old
                mpath = os.path.join(old, MANIFEST_NAME)
            else:
                raise FileNotFoundError(
                    f"no client store at {self.store_dir!r} (missing "
                    f"{MANIFEST_NAME}; run the train_federated `import` "
                    "subcommand to create one)")
        with open(mpath) as f:
            self.manifest = json.load(f)
        if self.manifest.get("version") != STORE_VERSION:
            raise ValueError(
                f"store version {self.manifest.get('version')!r} != "
                f"{STORE_VERSION} (incompatible layout)")

    # ---- manifest accessors (no file IO) ----

    @property
    def n_clients(self) -> int:
        return int(self.manifest["n_clients"])

    @property
    def meta(self) -> dict:
        return self.manifest.get("meta", {})

    def client_keys(self, cid: int) -> list[str]:
        return sorted(self.manifest["clients"][cid]["keys"])

    def rows(self, cid: int, key: str) -> int:
        ent = self.manifest["clients"][cid]["keys"].get(key)
        return 0 if ent is None else int(ent["shape"][0])

    def fingerprint(self) -> str:
        """Stable identity of this store's contents: sha256 over the
        canonical manifest JSON (shapes, dtypes, per-shard checksums)."""
        blob = json.dumps(self.manifest, sort_keys=True,
                          separators=(",", ":")).encode()
        return hashlib.sha256(blob).hexdigest()

    # ---- shard reads ----

    def shard(self, cid: int, key: str) -> ShardRows:
        ent = self.manifest["clients"][cid]["keys"][key]
        path = os.path.join(self.store_dir, _client_dirname(cid), key + ".npy")
        return ShardRows(path, tuple(ent["shape"]), np.dtype(ent["dtype"]))

    def client(self, cid: int) -> ClientView:
        return ClientView(self, cid)

    def clients(self) -> list[ClientView]:
        return [self.client(c) for c in range(self.n_clients)]

    def val(self) -> dict:
        """Materialize the replicated server validation set."""
        out = {}
        for key, ent in self.manifest["val"].items():
            path = os.path.join(self.store_dir, "val", key + ".npy")
            out[key] = ShardRows(path, tuple(ent["shape"]),
                                 np.dtype(ent["dtype"])).read()
        return out

    def rows_for_clients(self, ids, rows) -> dict:
        """Multi-host seam: read specific row subsets of specific clients.

        Parameters
        ----------
        ids : sequence of client indices (e.g. this mesh slice's share of
            the round's sampled clients).
        rows : mapping ``key -> sequence of per-id row-index arrays``
            (``rows[key][j]`` selects rows of client ``ids[j]``'s ``key``
            shard; ``None`` selects no rows).

        Returns ``key -> list of materialized arrays``, aligned with
        ``ids``. Only the named clients' shard files are opened, so a
        host holding a slice of the store on local disk serves its slice
        of the round without touching any other host's data.
        """
        out = {}
        for key, sels in rows.items():
            if len(sels) != len(ids):
                raise ValueError(
                    f"rows[{key!r}] has {len(sels)} selections for "
                    f"{len(ids)} client ids")
            got = []
            for cid, sel in zip(ids, sels):
                if sel is None:
                    got.append(None)
                elif key not in self.manifest["clients"][cid]["keys"]:
                    raise KeyError(f"client {cid} holds no {key!r} shard")
                else:
                    got.append(self.shard(cid, key)[np.asarray(sel)])
            out[key] = got
        return out


def write_store(store_dir: str, clients: list, val: dict, *,
                meta: dict | None = None, overwrite: bool = False) -> ClientStore:
    """Write C in-memory client datasets (+ the server val set) to a
    store directory, atomically (staged in ``<store_dir>.tmp`` and
    renamed into place). Returns the opened :class:`ClientStore`.

    ``clients`` is the ``FederatedBatcher`` dict-of-arrays format; keys
    whose value is ``None`` are dropped, zero-row arrays keep a manifest
    entry (shape/dtype) so the ragged-ness survives the round-trip.
    """
    store_dir = str(store_dir)
    if os.path.exists(store_dir):
        if not overwrite:
            raise FileExistsError(
                f"{store_dir!r} exists (pass overwrite=True to replace)")
    missing = [k for k in _VAL_KEYS if k not in val]
    if missing:
        raise KeyError(f"val set missing {missing}")

    tmp = store_dir.rstrip("/") + ".tmp"
    if os.path.isdir(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"version": STORE_VERSION, "n_clients": len(clients),
                "clients": [], "val": {}, "meta": meta or {}}

    def _write(dirname: str, key: str, arr: np.ndarray) -> dict:
        arr = np.ascontiguousarray(arr)
        np.save(os.path.join(tmp, dirname, key + ".npy"), arr)
        return {"shape": list(arr.shape), "dtype": str(arr.dtype),
                "sha256": hashlib.sha256(arr.tobytes()).hexdigest()}

    os.makedirs(os.path.join(tmp, "val"))
    for key in _VAL_KEYS:
        manifest["val"][key] = _write("val", key, np.asarray(val[key]))
    for cid, ds in enumerate(clients):
        dirname = _client_dirname(cid)
        os.makedirs(os.path.join(tmp, dirname))
        ent = {"keys": {}}
        for key in sorted(ds.keys()):
            v = ds[key]
            if v is None:
                continue
            ent["keys"][key] = _write(dirname, key, np.asarray(v))
        manifest["clients"].append(ent)
    with open(os.path.join(tmp, MANIFEST_NAME), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)

    # overwrite via swap, never delete-before-rename: the old store moves
    # aside as .old (which ClientStore treats as a readable fallback),
    # the new one renames into place, only then is the old data removed —
    # a complete copy stays findable at every instant
    old = store_dir.rstrip("/") + ".old"
    if os.path.exists(store_dir):
        if os.path.isdir(old):
            shutil.rmtree(old)
        os.rename(store_dir, old)
    os.rename(tmp, store_dir)
    shutil.rmtree(old, ignore_errors=True)  # also sweeps a stale crash .old
    return ClientStore(store_dir)
