from repro.data.synthetic import SyntheticMultimodal, TaskSpec, make_task
from repro.data.pipeline import Batcher, FederatedBatcher, token_batches
from repro.data.store import ClientStore, write_store

__all__ = ["SyntheticMultimodal", "TaskSpec", "make_task", "Batcher",
           "FederatedBatcher", "token_batches", "ClientStore", "write_store"]
