from repro.data.synthetic import SyntheticMultimodal, TaskSpec, make_task
from repro.data.pipeline import Batcher, FederatedBatcher, token_batches

__all__ = ["SyntheticMultimodal", "TaskSpec", "make_task", "Batcher",
           "FederatedBatcher", "token_batches"]
