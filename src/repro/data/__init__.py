from repro.data.synthetic import SyntheticMultimodal, TaskSpec, make_task
from repro.data.pipeline import Batcher, token_batches

__all__ = ["SyntheticMultimodal", "TaskSpec", "make_task", "Batcher", "token_batches"]
