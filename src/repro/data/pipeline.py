"""Batching pipelines.

``Batcher`` serves the in-host federated experiments (numpy in,
dict-of-arrays out). ``token_batches`` serves the LM examples (synthetic
token streams). ``FederatedBatcher`` is the federated data subsystem
for the sharded SPMD round: it turns C ragged per-client datasets —
heterogeneous row counts, zero-row modalities included — into the static
``(K, N, ...)`` phase batches ``federation_sharded.make_blendfl_round``
consumes, with real 0/1 masks instead of the uniform all-ones layout.

Design points:

- **Stateless per-round RNG.** Every batch is a pure function of
  ``(seed, round)`` (``np.random.default_rng([seed, round])`` draws the
  row subsets, the VFL alignment, and the K-of-C sampled client ids), so
  a federation resumed from a round-``r`` checkpoint rebuilds the exact
  byte-identical batch stream — the property the round-state
  checkpointing in ``repro.launch.train_federated`` relies on for
  bit-exact resume. Adaptive participation policies
  (``repro.core.schedule``, selected by ``spec.policy``) extend the pure
  inputs to ``(seed, round, sched telemetry)`` — and the telemetry is
  checkpointed round state, so the resume contract survives unchanged.
- **Static shapes, data-dependent masks.** Row counts pad up to the
  spec's ``n_partial``/``n_frag``/``n_paired``; masks mark live rows.
  A client with a zero-row modality gets an all-zero mask and is
  excluded from that phase's parameter/momentum update by the engine's
  ``_where_clients`` semantics. The VFL alignment is rebuilt per round
  from global sample ids: aligned rows keep weight 1, padded or
  partner-less rows weight 0, so the alignment's flattened ``(K*Nf,)``
  shape never changes and the round compiles once.
- **Double-buffered host->device transfer.** ``rounds()`` stages the
  next round's batch on a worker thread (build + ``jax.device_put`` with
  the dry-run shardings from ``repro.launch.shardings``) while the
  device executes the current round, hiding host batch-build time
  behind device compute.
"""
from __future__ import annotations

import queue
import threading
import time

import numpy as np


class Batcher:
    """Deterministic shuffling batcher over dict-of-arrays datasets."""

    def __init__(self, arrays: dict, batch_size: int, seed: int = 0, drop_remainder: bool = False):
        self.arrays = {k: np.asarray(v) for k, v in arrays.items()}
        lens = {len(v) for v in self.arrays.values()}
        assert len(lens) == 1, f"ragged arrays: { {k: len(v) for k, v in self.arrays.items()} }"
        self.n = lens.pop()
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.drop_remainder = drop_remainder

    def __len__(self):
        if self.drop_remainder:
            return self.n // self.batch_size
        return (self.n + self.batch_size - 1) // self.batch_size

    def epoch(self, shuffle: bool = True):
        idx = np.arange(self.n)
        if shuffle:
            self.rng.shuffle(idx)
        stop = self.n - (self.n % self.batch_size) if self.drop_remainder else self.n
        for i in range(0, stop, self.batch_size):
            sel = idx[i : i + self.batch_size]
            if self.drop_remainder and len(sel) < self.batch_size:
                break
            yield {k: v[sel] for k, v in self.arrays.items()}


def token_batches(vocab_size: int, batch: int, seq: int, n_batches: int, seed: int = 0):
    """Synthetic LM token stream with Zipf-ish marginals + copy structure so a
    model can actually reduce loss (used by the e2e training example)."""
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        base = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64) % vocab_size
        # inject predictable bigram structure: even positions repeat previous token
        base[:, 2::2] = base[:, 1:-1:2]
        yield {"tokens": base[:, :-1].astype(np.int32), "labels": base[:, 1:].astype(np.int32)}


# ------------------------------------------------- federated batch loader --

_F32 = np.float32

# per-client dataset keys the loader understands; all optional (missing or
# zero-row = that client holds no such data)
CLIENT_KEYS = ("partial_a", "partial_ya", "partial_b", "partial_yb",
               "frag_a", "frag_y", "frag_ids_a", "frag_b", "frag_ids_b",
               "paired_a", "paired_b", "paired_y")

_SENTINEL = object()  # end-of-stream marker for the prefetch queue


def _rows(ds: dict, key: str) -> int:
    v = ds.get(key)
    return 0 if v is None else len(v)


def _flip(y: np.ndarray, kind: str) -> np.ndarray:
    """Label corruption for a scenario's adversarial clients — the
    deterministic flip from ``repro.data.scenario.flip_labels``."""
    from repro.data.scenario import flip_labels

    return flip_labels(y, kind)


class FederatedBatcher:
    """Federated batch loader: C ragged per-client datasets -> one static
    ``(K, N, ...)`` masked round batch per call, double-buffered to device.

    Parameters
    ----------
    clients : list of per-client dict-of-arrays datasets (see
        ``CLIENT_KEYS``; ``repro.launch.train_federated.client_arrays``
        converts a ``partitioner.ClientData``). Row counts may differ per
        client and any modality may be absent/zero-row.
    spec : ``federation_sharded.ShardedFedSpec`` (duck-typed: only the
        static shape fields and ``n_sampled``/``k_round`` are read). The
        spec's seq/feat/out dims must match the data.
    val : dict with ``val_a``/``val_b``/``val_y`` — the replicated server
        validation set, transferred once and reused in every batch.
    seed : base seed; round ``r``'s batch is a pure function of
        ``(seed, r)`` (crash-safe resume rebuilds the identical stream).
    shardings : optional pytree of shardings matching ``batch_specs()``
        (e.g. from ``repro.launch.shardings.batch_shardings``); passed to
        ``jax.device_put``. None = default placement.
    prefetch : staging depth of ``rounds()``; 0 disables the worker
        thread (build strictly alternates with compute).
    scenario : optional ``repro.data.scenario.Scenario``. The client list
        then covers the FULL roster (initial cohort + every future
        joiner, in join order); ``spec.n_clients`` is the current state
        *capacity* and ``set_spec`` re-binds the loader when the driver
        grows it. Requires sampled rounds (``spec.n_sampled > 0``): batch
        shapes are fixed at K, so membership churn never touches them.
    n_initial : size of the round-0 cohort under a scenario (defaults to
        the full roster — i.e. no pending joiners).
    """

    def __init__(self, clients: list, spec, val: dict, *, seed: int = 0,
                 shardings=None, prefetch: int = 1, scenario=None,
                 n_initial: int | None = None):
        # dict(c) also accepts the lazy mapping views of a ClientStore
        # (values stay ShardRows — no shard data is read at init)
        self._roster = [dict(c) for c in clients]
        self.store = None  # set by from_store; used for checkpoint identity
        self.scenario = scenario
        self.n_initial = (len(self._roster) if n_initial is None
                          else int(n_initial))
        if scenario is None:
            if len(self._roster) != spec.n_clients:
                raise ValueError(f"{len(self._roster)} client datasets for "
                                 f"spec.n_clients={spec.n_clients}")
        else:
            if not getattr(spec, "n_sampled", 0):
                raise ValueError(
                    "a churn scenario requires sampled rounds (n_sampled "
                    "> 0): the phase batches are stacked at K, so only the "
                    "state capacity — never the batch shapes — grows")
            scenario.validate(self.n_initial)
            need = self.n_initial + scenario.total_joins()
            if len(self._roster) < need:
                raise ValueError(
                    f"scenario needs {need} client datasets (initial "
                    f"{self.n_initial} + {scenario.total_joins()} joiners) "
                    f"but the roster holds {len(self._roster)}")
        paired_keys = [("frag_a", "frag_ids_a"), ("frag_b", "frag_ids_b"),
                       ("frag_a", "frag_y"), ("partial_a", "partial_ya"),
                       ("partial_b", "partial_yb"), ("paired_a", "paired_b"),
                       ("paired_a", "paired_y")]
        for i, c in enumerate(self._roster):
            for k in c:
                if k not in CLIENT_KEYS:
                    raise KeyError(f"unknown client dataset key {k!r}")
            for ka, kb in paired_keys:
                if _rows(c, ka) != _rows(c, kb):
                    raise ValueError(
                        f"client {i}: {ka} has {_rows(c, ka)} rows but {kb} "
                        f"has {_rows(c, kb)} — per-client arrays of one "
                        "group must align row-for-row")
        self.seed = int(seed)
        self.shardings = shardings
        self.prefetch = int(prefetch)
        self._bind_spec(spec)
        self.build_seconds = 0.0  # cumulative host batch-build time
        self.stall_seconds = 0.0  # prefetch mode: consumer time blocked
        # waiting for a staged batch (the build time prefetch FAILED to hide)
        self.rounds_built = 0
        # the replicated val set never changes: transfer once, with the
        # configured shardings so the jitted round never re-shards it
        import jax

        self._val = {
            k: jax.device_put(np.ascontiguousarray(val[k], _F32),
                              None if shardings is None else shardings.get(k))
            for k in ("val_a", "val_b", "val_y")}

    def _bind_spec(self, spec):
        """Bind the loader to a spec (capacity): slice/pad the roster view
        to ``spec.n_clients`` slots ({}-padded slots hold no data and are
        masked inactive by the scenario), rebuild the per-client row
        totals, and re-instantiate the participation policy at the new C.
        The policy is stateless host code, so re-binding changes nothing
        about rng consumption for a given (telemetry, k)."""
        from repro.core.schedule import make_policy

        self.spec = spec
        view = self._roster[: spec.n_clients]
        self.clients = view + [{}] * (spec.n_clients - len(view))
        policy_name = getattr(spec, "policy", "uniform")
        if getattr(spec, "n_sampled", 0):
            self.policy = make_policy(policy_name, spec.n_clients,
                                      spec.k_round)
        elif policy_name != "uniform":
            raise ValueError(f"participation policy {policy_name!r} requires "
                             "spec.n_sampled > 0 (full participation has "
                             "nothing to schedule)")
        else:
            self.policy = None
        self._client_rows = np.asarray(
            [sum(_rows(c, k) for k in ("partial_a", "partial_b", "frag_a",
                                       "frag_b", "paired_a"))
             for c in self.clients], np.float64)

    def set_spec(self, spec) -> None:
        """Re-bind after the driver grew the state capacity (a scenario
        join crossed a bucket): same roster, new ``spec.n_clients``."""
        self._bind_spec(spec)

    @classmethod
    def from_store(cls, store, spec, val: dict | None = None, *, seed: int = 0,
                   shardings=None, prefetch: int = 1) -> "FederatedBatcher":
        """Out-of-core loader over a ``repro.data.store.ClientStore``.

        Client arrays stay on disk: ``build()``'s ``ds[key][sel]`` reads
        open each shard's memory map, gather only the drawn rows, and
        unmap — peak host RAM per round is O(K*N*row_bytes), independent
        of the total dataset size. Row counts, dtype/shape validation,
        and ``_draw`` sizing come from the store manifest (no file IO),
        and the batch stream is bit-identical to an in-memory
        ``FederatedBatcher`` over the same arrays for the same
        ``(seed, round)``. ``val=None`` reads the server validation set
        the store's ``import`` recorded.
        """
        b = cls(store.clients(), spec, store.val() if val is None else val,
                seed=seed, shardings=shardings, prefetch=prefetch)
        b.store = store
        return b

    # ---- static interface ----

    def batch_specs(self) -> dict:
        """ShapeDtypeStructs of every key a round batch carries (the
        ragged superset of ``federation_sharded.batch_specs``, including
        ``perm_b`` and — under sampling — ``sampled``)."""
        from repro.core.federation_sharded import batch_specs

        return batch_specs(self.spec, ragged=True)

    # ---- host-side batch construction (pure in (seed, round)) ----

    def _draw(self, rng, avail: int, cap: int) -> np.ndarray:
        """Row subset for one (client, phase): all rows when they fit,
        else a without-replacement subsample of the static capacity."""
        if avail <= cap:
            return np.arange(avail)
        return rng.permutation(avail)[:cap]

    def build(self, round_no: int, sched: dict | None = None) -> dict:
        """Build round ``round_no``'s host batch (numpy, unsharded).

        ``sched`` is the round-state telemetry block (numpy ``omega_ema``
        / ``part_count`` / ``last_round``) a state-reading participation
        policy selects from; policies that don't read state (uniform,
        round_robin, data_volume) ignore it, keeping the batch a pure
        function of ``(seed, round)``. With telemetry, purity extends to
        ``(seed, round, sched)`` — and sched is checkpointed round state,
        so bit-exact resume holds for every policy.
        """
        t0 = time.perf_counter()
        s = self.spec
        rng = np.random.default_rng([self.seed, int(round_no)])
        K = s.k_round
        if s.n_sampled:
            t = {"round": int(round_no), "rows": self._client_rows}
            if self.scenario is not None:
                # membership is a pure function of the round index, so a
                # resumed run rebuilds the identical mask (and stream)
                t["active"] = self.scenario.active_mask(
                    int(round_no), self.n_initial, s.n_clients)
            if sched is not None:
                t.update(sched)
            elif self.policy.needs_state:
                raise ValueError(
                    f"policy {self.policy.name!r} selects clients from "
                    "round-state telemetry; build() needs the sched block "
                    "(drive it via rounds(..., telemetry_fn=...))")
            # the uniform policy consumes this rng exactly like the
            # pre-scheduler code (one choice draw), so the whole batch
            # stream stays bit-identical under the default policy
            idx = self.policy.select(rng, t)
        else:
            idx = np.arange(s.n_clients)
        sub = [self.clients[i] for i in idx]
        flip = [False] * len(idx)
        bdoor = [False] * len(idx)
        if self.scenario is not None:
            bad = set(self.scenario.corrupt_ids(int(round_no)))
            flip = [int(i) in bad for i in idx]
            bd = set(self.scenario.backdoor_ids(int(round_no)))
            bdoor = [int(i) in bd for i in idx]

        batch = {}
        # phases 1 & 3: padded slabs + 0/1 row masks
        slabs = [
            ("partial_a", "partial_ya", "partial_ma", s.n_partial, s.seq_a, s.feat_a),
            ("partial_b", "partial_yb", "partial_mb", s.n_partial, s.seq_b, s.feat_b),
            ("paired_a", "paired_y", "paired_m", s.n_paired, s.seq_a, s.feat_a),
            ("paired_b", None, None, s.n_paired, s.seq_b, s.feat_b),
        ]
        paired_sel = [None] * K  # paired rows must align across modalities
        for xk, yk, mk, cap, seq, feat in slabs:
            x = np.zeros((K, cap, seq, feat), _F32)
            y = np.zeros((K, cap, s.out_dim), _F32) if yk else None
            m = np.zeros((K, cap), _F32) if mk else None
            for k, ds in enumerate(sub):
                if xk == "paired_b":
                    sel = paired_sel[k]  # same rows as paired_a
                else:
                    sel = self._draw(rng, _rows(ds, xk), cap)
                    if xk == "paired_a":
                        paired_sel[k] = sel
                n = len(sel)
                if n == 0:
                    continue
                x[k, :n] = ds[xk][sel]
                if y is not None:
                    y[k, :n] = (_flip(ds[yk][sel], s.kind) if flip[k]
                                else ds[yk][sel])
                if bdoor[k]:
                    # targeted backdoor (scenario `backdoor:` events): a
                    # deterministic prefix of the drawn rows gets the
                    # fixed trigger patch + the attacker's target label.
                    # The prefix of the (seed, round)-pure draw adds no
                    # RNG, so poisoned streams resume bit-exactly. The
                    # fragmented (VFL) slabs stay clean: their labels
                    # live server-side, out of the client's reach.
                    from repro.data import scenario as scn
                    nb = scn.backdoor_rows(n)
                    x[k, :nb] = scn.apply_trigger(x[k, :nb])
                    if y is not None:
                        y[k, :nb] = scn.backdoor_target(s.kind, s.out_dim)
                if m is not None:
                    m[k, :n] = 1.0
            batch[xk] = x
            if y is not None:
                batch[yk] = y
            if m is not None:
                batch[mk] = m

        # phase 2: fragmented slabs + id-based alignment (the PSI output).
        # Flattened a-side row i pairs with flattened b-side row
        # perm_b[i]; rows that are padding or whose partner modality was
        # not drawn this round carry weight 0 (static shape, live mask).
        nf = s.n_frag
        fa = np.zeros((K, nf, s.seq_a, s.feat_a), _F32)
        fb = np.zeros((K, nf, s.seq_b, s.feat_b), _F32)
        fy = np.zeros((K, nf, s.out_dim), _F32)
        ids_a = np.full(K * nf, -1, np.int64)
        ids_b = np.full(K * nf, -2, np.int64)  # never matches ids_a padding
        for k, ds in enumerate(sub):
            sel_a = self._draw(rng, _rows(ds, "frag_a"), nf)
            sel_b = self._draw(rng, _rows(ds, "frag_b"), nf)
            if len(sel_a):
                fa[k, : len(sel_a)] = ds["frag_a"][sel_a]
                fy[k, : len(sel_a)] = (_flip(ds["frag_y"][sel_a], s.kind)
                                       if flip[k] else ds["frag_y"][sel_a])
                ids_a[k * nf : k * nf + len(sel_a)] = ds["frag_ids_a"][sel_a]
            if len(sel_b):
                fb[k, : len(sel_b)] = ds["frag_b"][sel_b]
                ids_b[k * nf : k * nf + len(sel_b)] = ds["frag_ids_b"][sel_b]
        bpos = np.flatnonzero(ids_b >= 0)
        order = np.argsort(ids_b[bpos], kind="stable")
        sorted_b = ids_b[bpos][order]
        if len(sorted_b):
            loc = np.clip(np.searchsorted(sorted_b, ids_a), 0, len(sorted_b) - 1)
            hit = (ids_a >= 0) & (sorted_b[loc] == ids_a)
            perm_b = np.where(hit, bpos[order][loc], 0)
        else:
            hit = np.zeros(K * nf, bool)
            perm_b = np.zeros(K * nf, np.int64)
        part_a = np.zeros(K, bool)
        part_b = np.zeros(K, bool)
        if hit.any():
            part_a[np.unique(np.flatnonzero(hit) // nf)] = True
            part_b[np.unique(perm_b[hit] // nf)] = True
        fy[~hit.reshape(K, nf)] = 0.0  # padded/unmatched rows carry no label
        batch.update({
            "frag_a": fa, "frag_b": fb, "frag_y": fy,
            "perm_b": perm_b.astype(np.int32),
            "frag_w": hit.astype(_F32),
            "frag_part_a": part_a, "frag_part_b": part_b,
        })
        if s.n_sampled:
            batch["sampled"] = idx.astype(np.int32)
        if getattr(s, "attacks", False):
            # per-participant uplink coefficient (1 honest / -1
            # sign-flip / SCALE_FACTOR boosted) — scenario-derived, pure
            # in the round index; all-ones without a scenario (the
            # bench's no-attack arm shares the attacked arms' compiled
            # round)
            batch["attack_coef"] = (
                self.scenario.attack_coef(int(round_no), idx)
                if self.scenario is not None else np.ones(len(idx), _F32))
        self.build_seconds += time.perf_counter() - t0
        self.rounds_built += 1
        return batch

    def put(self, host_batch: dict) -> dict:
        """Transfer one host batch to device with the configured
        shardings; the cached val set rides along untouched."""
        import jax

        if self.shardings is not None:
            moved = {k: jax.device_put(v, self.shardings[k])
                     for k, v in host_batch.items()}
        else:
            moved = jax.device_put(host_batch)
        return dict(moved, **self._val)

    # ---- double-buffered round stream ----

    def rounds(self, start: int, stop: int, prefetch: int | None = None,
               telemetry_fn=None):
        """Yield ``(round_no, device_batch)`` for rounds [start, stop).

        With ``prefetch > 0`` a daemon worker builds and stages up to
        ``prefetch`` future HOST batches while the caller's round executes
        on device (numpy slab assembly releases the GIL, and the caller
        blocks in C++ when it reads round metrics — so the build
        genuinely overlaps device compute). The device transfer itself
        stays on the consumer thread: ``jax.device_put`` from a second
        thread contends with the XLA CPU compute pool, and the copy is
        cheap next to the build. ``stall_seconds`` accumulates consumer
        time spent waiting for a staged batch — the build time prefetch
        failed to hide.

        ``telemetry_fn() -> dict`` supplies the current round-state sched
        telemetry for a state-reading participation policy (staleness /
        omega_ema). Round r's selection depends on round r-1's outcome —
        a true data dependency — so those policies run the synchronous
        path regardless of ``prefetch``: each batch builds only after the
        caller's previous round updated the state the telemetry reads.
        State-free policies keep the full prefetch overlap."""
        if self.scenario is not None:
            raise ValueError(
                "rounds() cannot stream a churn scenario: capacity (and "
                "with it this loader's spec) may change between rounds — "
                "drive build()/put() round-by-round from the scenario loop")
        if (self.policy is not None and self.policy.needs_state):
            if telemetry_fn is None:
                raise ValueError(
                    f"policy {self.policy.name!r} needs per-round state "
                    "telemetry; pass telemetry_fn to rounds()")
            for r in range(start, stop):
                yield r, self.put(self.build(r, telemetry_fn()))
            return
        depth = self.prefetch if prefetch is None else int(prefetch)
        if depth <= 0:
            for r in range(start, stop):
                yield r, self.put(self.build(r))
            return

        q: queue.Queue = queue.Queue(maxsize=depth)
        stop_evt = threading.Event()

        def _feed(item) -> bool:
            while not stop_evt.is_set():
                try:
                    q.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def worker():
            try:
                for r in range(start, stop):
                    if stop_evt.is_set() or not _feed((r, self.build(r))):
                        return
                _feed(_SENTINEL)
            except BaseException as e:  # surface build errors to the
                _feed(e)  # consumer instead of hanging it on q.get()

        t = threading.Thread(target=worker, daemon=True,
                             name="federated-batcher-prefetch")
        t.start()
        try:
            while True:
                t0 = time.perf_counter()
                item = q.get()
                self.stall_seconds += time.perf_counter() - t0
                if item is _SENTINEL:
                    return
                if isinstance(item, BaseException):
                    raise item
                r, host_batch = item
                yield r, self.put(host_batch)
        finally:
            stop_evt.set()
