"""Batching pipelines.

``Batcher`` serves the federated experiments (numpy in, dict-of-arrays out).
``token_batches`` serves the LM examples (synthetic token streams).
"""
from __future__ import annotations

import numpy as np


class Batcher:
    """Deterministic shuffling batcher over dict-of-arrays datasets."""

    def __init__(self, arrays: dict, batch_size: int, seed: int = 0, drop_remainder: bool = False):
        self.arrays = {k: np.asarray(v) for k, v in arrays.items()}
        lens = {len(v) for v in self.arrays.values()}
        assert len(lens) == 1, f"ragged arrays: { {k: len(v) for k, v in self.arrays.items()} }"
        self.n = lens.pop()
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self.drop_remainder = drop_remainder

    def __len__(self):
        if self.drop_remainder:
            return self.n // self.batch_size
        return (self.n + self.batch_size - 1) // self.batch_size

    def epoch(self, shuffle: bool = True):
        idx = np.arange(self.n)
        if shuffle:
            self.rng.shuffle(idx)
        stop = self.n - (self.n % self.batch_size) if self.drop_remainder else self.n
        for i in range(0, stop, self.batch_size):
            sel = idx[i : i + self.batch_size]
            if self.drop_remainder and len(sel) < self.batch_size:
                break
            yield {k: v[sel] for k, v in self.arrays.items()}


def token_batches(vocab_size: int, batch: int, seq: int, n_batches: int, seed: int = 0):
    """Synthetic LM token stream with Zipf-ish marginals + copy structure so a
    model can actually reduce loss (used by the e2e training example)."""
    rng = np.random.default_rng(seed)
    for _ in range(n_batches):
        base = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64) % vocab_size
        # inject predictable bigram structure: even positions repeat previous token
        base[:, 2::2] = base[:, 1:-1:2]
        yield {"tokens": base[:, :-1].astype(np.int32), "labels": base[:, 1:].astype(np.int32)}
