"""Synthetic class-conditional multimodal datasets.

The paper's experiments use MIMIC-IV + MIMIC-CXR (credentialed PHI) and
S-MNIST; neither is available offline, so we generate *learnable* synthetic
stand-ins that preserve the structure the paper's experiments depend on:

- two modalities A and B (e.g. EHR time-series / CXR image embedding,
  audio / image) generated from a shared class-conditional latent, so that
  (i) each modality alone is predictive (unimodal tasks are non-trivial),
  (ii) the modalities carry complementary information (multimodal fusion
  strictly beats either unimodal model), matching the ordering the paper's
  tables rely on.

Three task types mirror the paper:
- ``conditions``: 25-label multilabel (clinical conditions prediction)
- ``mortality``: binary (in-hospital mortality)
- ``smnist``: 10-class multiclass (audio-visual digits)
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    name: str
    kind: str  # 'multilabel' | 'binary' | 'multiclass'
    n_labels: int  # label dimensionality (classes for multiclass)
    seq_a: int  # modality A: time steps (EHR / audio frames)
    feat_a: int  # modality A: per-step features
    seq_b: int  # modality B: patches (CXR / image patches)
    feat_b: int  # modality B: per-patch features
    noise: float = 0.6  # generative noise, calibrated per task so the
    # centralized upper bound lands near the paper's reported range

    @property
    def out_dim(self) -> int:
        return self.n_labels


_TASKS = {
    "conditions": TaskSpec("conditions", "multilabel", 25, 16, 12, 16, 16,
                           noise=0.35),
    "mortality": TaskSpec("mortality", "binary", 1, 16, 12, 16, 16, noise=1.4),
    "smnist": TaskSpec("smnist", "multiclass", 10, 12, 8, 16, 12, noise=0.5),
}


def make_task(name: str) -> "TaskSpec":
    return _TASKS[name]


@dataclasses.dataclass
class SyntheticMultimodal:
    """Holds arrays x_a (N, seq_a, feat_a), x_b (N, seq_b, feat_b), y."""

    spec: TaskSpec
    x_a: np.ndarray
    x_b: np.ndarray
    y: np.ndarray
    ids: np.ndarray  # global sample ids (for VFL alignment)

    def __len__(self) -> int:
        return len(self.y)

    def subset(self, idx: np.ndarray) -> "SyntheticMultimodal":
        return SyntheticMultimodal(self.spec, self.x_a[idx], self.x_b[idx], self.y[idx], self.ids[idx])


def generate(spec: TaskSpec, n: int, seed: int = 0, noise: float | None = None,
             id_offset: int = 0) -> SyntheticMultimodal:
    """Sample n multimodal instances from the class-conditional process."""
    noise = spec.noise if noise is None else noise
    rng = np.random.default_rng(seed)
    latent_dim = 24

    if spec.kind == "multiclass":
        y_int = rng.integers(0, spec.n_labels, size=n)
        y = np.eye(spec.n_labels, dtype=np.float32)[y_int]
        label_vec = y
    elif spec.kind == "binary":
        y = rng.integers(0, 2, size=(n, 1)).astype(np.float32)
        label_vec = np.concatenate([y, 1 - y], axis=1)
    else:  # multilabel
        y = (rng.random((n, spec.n_labels)) < 0.18).astype(np.float32)
        label_vec = y

    # Fixed (seed-independent of sample draw) generative projections so train /
    # val / test splits share the same world model.
    # zlib.crc32: deterministic across processes (hash() is salted)
    import zlib

    grng = np.random.default_rng(12345 + zlib.crc32(spec.name.encode()) % 10_000)
    w_latent = grng.normal(0, 1.0, (label_vec.shape[1], latent_dim)).astype(np.float32)
    # per-modality private latent components make fusion strictly informative
    w_a = grng.normal(0, 1.0, (latent_dim, spec.seq_a * spec.feat_a)).astype(np.float32)
    w_b = grng.normal(0, 1.0, (latent_dim, spec.seq_b * spec.feat_b)).astype(np.float32)
    split_a = grng.random(latent_dim) < 0.7  # A sees 70% of latent dims
    split_b = ~split_a | (grng.random(latent_dim) < 0.5)

    z = label_vec @ w_latent / np.sqrt(label_vec.shape[1])
    z = z + noise * rng.normal(0, 1.0, z.shape).astype(np.float32)
    z_a = np.where(split_a[None, :], z, 0.0)
    z_b = np.where(split_b[None, :], z, 0.0)

    x_a = np.tanh(z_a @ w_a / np.sqrt(latent_dim))
    x_b = np.tanh(z_b @ w_b / np.sqrt(latent_dim))
    x_a = x_a + 0.3 * noise * rng.normal(0, 1, x_a.shape)
    x_b = x_b + 0.3 * noise * rng.normal(0, 1, x_b.shape)

    ids = np.arange(id_offset, id_offset + n, dtype=np.int64)
    return SyntheticMultimodal(
        spec,
        x_a.reshape(n, spec.seq_a, spec.feat_a).astype(np.float32),
        x_b.reshape(n, spec.seq_b, spec.feat_b).astype(np.float32),
        y.astype(np.float32),
        ids,
    )


def train_val_test(spec: TaskSpec, n_train: int, n_val: int, n_test: int, seed: int = 0):
    """Generate disjoint splits from the same generative process (70/10/20 in paper)."""
    total = generate(spec, n_train + n_val + n_test, seed=seed)
    tr = total.subset(np.arange(0, n_train))
    va = total.subset(np.arange(n_train, n_train + n_val))
    te = total.subset(np.arange(n_train + n_val, n_train + n_val + n_test))
    return tr, va, te


# ------------------------------------------- non-IID cohort generation ----

def _row_labels(y: np.ndarray):
    """Collapse a label matrix to one integer class per row (binary ->
    {0,1}; multiclass/multilabel -> argmax, i.e. the dominant label)."""
    if y.shape[1] == 1:
        return (y[:, 0] > 0.5).astype(np.int64), 2
    return np.argmax(y, axis=1).astype(np.int64), y.shape[1]


def dirichlet_cohort(data: SyntheticMultimodal, n_clients: int, alpha: float,
                     seed: int = 0, power: float = 1.2, min_rows: int = 8,
                     paired_frac: float = 0.5):
    """Dirichlet label-skew cohort with power-law client sizes — the
    standard non-IID FL benchmark construction (Hsu et al. 2019; swept at
    alpha in {0.1, 0.5, 1.0} across the multimodal-FL literature).

    Each client c draws a class distribution p_c ~ Dirichlet(alpha * 1):
    alpha -> 0 gives near-single-class clients (extreme skew, maximal
    client drift), alpha -> inf recovers IID. Client sizes follow a
    shuffled power law n_c ∝ rank^-``power`` (floored at ``min_rows``),
    so the cohort mixes data-rich heads with long-tail clients. Rows are
    drawn WITHOUT replacement from per-class pools of ``data`` (a
    client's draw is trimmed when its wanted class is exhausted, then
    topped up from whatever classes still hold rows — every row is used
    at most once cohort-wide).

    Returns ``(clients, sizes)``: ``clients`` is the FederatedBatcher
    client-dict list (each row split ``paired_frac`` paired / rest
    partial, both modalities of the partial rows exposed unimodally —
    the same layout the straggler cohort uses), ``sizes`` the realized
    per-client row counts.
    """
    if alpha <= 0:
        raise ValueError(f"dirichlet alpha must be > 0, got {alpha}")
    if n_clients < 1:
        raise ValueError(f"n_clients must be >= 1, got {n_clients}")
    rng = np.random.default_rng(seed)
    labels, n_classes = _row_labels(data.y)
    n_rows = len(labels)

    # shuffled power-law sizes normalized onto the dataset
    raw = 1.0 / np.arange(1, n_clients + 1, dtype=np.float64) ** power
    raw = rng.permutation(raw)
    sizes = np.maximum(min_rows,
                       np.floor(raw / raw.sum() * n_rows).astype(np.int64))

    pools = [list(rng.permutation(np.nonzero(labels == k)[0]))
             for k in range(n_classes)]
    clients, realized = [], []
    for c in range(n_clients):
        p = rng.dirichlet(np.full(n_classes, float(alpha)))
        want = rng.multinomial(int(sizes[c]), p)
        take = []
        for k in range(n_classes):
            got = min(int(want[k]), len(pools[k]))
            take += [pools[k].pop() for _ in range(got)]
        # top up a trimmed draw from the fullest remaining pools so the
        # power-law size profile survives pool exhaustion
        deficit = int(sizes[c]) - len(take)
        while deficit > 0:
            k = max(range(n_classes), key=lambda j: len(pools[j]))
            if not pools[k]:
                break
            take.append(pools[k].pop())
            deficit -= 1
        idx = np.asarray(sorted(take), np.int64)
        n_pair = max(1, int(round(paired_frac * len(idx))))
        pair, part = idx[:n_pair], idx[n_pair:]
        if len(part) == 0:  # tiny client: reuse its paired rows unimodally
            part = pair
        clients.append({
            "paired_a": data.x_a[pair], "paired_b": data.x_b[pair],
            "paired_y": data.y[pair],
            "partial_a": data.x_a[part], "partial_ya": data.y[part],
            "partial_b": data.x_b[part], "partial_yb": data.y[part],
        })
        realized.append(len(idx))
    return clients, np.asarray(realized, np.int64)
