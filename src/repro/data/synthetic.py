"""Synthetic class-conditional multimodal datasets.

The paper's experiments use MIMIC-IV + MIMIC-CXR (credentialed PHI) and
S-MNIST; neither is available offline, so we generate *learnable* synthetic
stand-ins that preserve the structure the paper's experiments depend on:

- two modalities A and B (e.g. EHR time-series / CXR image embedding,
  audio / image) generated from a shared class-conditional latent, so that
  (i) each modality alone is predictive (unimodal tasks are non-trivial),
  (ii) the modalities carry complementary information (multimodal fusion
  strictly beats either unimodal model), matching the ordering the paper's
  tables rely on.

Three task types mirror the paper:
- ``conditions``: 25-label multilabel (clinical conditions prediction)
- ``mortality``: binary (in-hospital mortality)
- ``smnist``: 10-class multiclass (audio-visual digits)
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    name: str
    kind: str  # 'multilabel' | 'binary' | 'multiclass'
    n_labels: int  # label dimensionality (classes for multiclass)
    seq_a: int  # modality A: time steps (EHR / audio frames)
    feat_a: int  # modality A: per-step features
    seq_b: int  # modality B: patches (CXR / image patches)
    feat_b: int  # modality B: per-patch features
    noise: float = 0.6  # generative noise, calibrated per task so the
    # centralized upper bound lands near the paper's reported range

    @property
    def out_dim(self) -> int:
        return self.n_labels


_TASKS = {
    "conditions": TaskSpec("conditions", "multilabel", 25, 16, 12, 16, 16,
                           noise=0.35),
    "mortality": TaskSpec("mortality", "binary", 1, 16, 12, 16, 16, noise=1.4),
    "smnist": TaskSpec("smnist", "multiclass", 10, 12, 8, 16, 12, noise=0.5),
}


def make_task(name: str) -> "TaskSpec":
    return _TASKS[name]


@dataclasses.dataclass
class SyntheticMultimodal:
    """Holds arrays x_a (N, seq_a, feat_a), x_b (N, seq_b, feat_b), y."""

    spec: TaskSpec
    x_a: np.ndarray
    x_b: np.ndarray
    y: np.ndarray
    ids: np.ndarray  # global sample ids (for VFL alignment)

    def __len__(self) -> int:
        return len(self.y)

    def subset(self, idx: np.ndarray) -> "SyntheticMultimodal":
        return SyntheticMultimodal(self.spec, self.x_a[idx], self.x_b[idx], self.y[idx], self.ids[idx])


def generate(spec: TaskSpec, n: int, seed: int = 0, noise: float | None = None,
             id_offset: int = 0) -> SyntheticMultimodal:
    """Sample n multimodal instances from the class-conditional process."""
    noise = spec.noise if noise is None else noise
    rng = np.random.default_rng(seed)
    latent_dim = 24

    if spec.kind == "multiclass":
        y_int = rng.integers(0, spec.n_labels, size=n)
        y = np.eye(spec.n_labels, dtype=np.float32)[y_int]
        label_vec = y
    elif spec.kind == "binary":
        y = rng.integers(0, 2, size=(n, 1)).astype(np.float32)
        label_vec = np.concatenate([y, 1 - y], axis=1)
    else:  # multilabel
        y = (rng.random((n, spec.n_labels)) < 0.18).astype(np.float32)
        label_vec = y

    # Fixed (seed-independent of sample draw) generative projections so train /
    # val / test splits share the same world model.
    # zlib.crc32: deterministic across processes (hash() is salted)
    import zlib

    grng = np.random.default_rng(12345 + zlib.crc32(spec.name.encode()) % 10_000)
    w_latent = grng.normal(0, 1.0, (label_vec.shape[1], latent_dim)).astype(np.float32)
    # per-modality private latent components make fusion strictly informative
    w_a = grng.normal(0, 1.0, (latent_dim, spec.seq_a * spec.feat_a)).astype(np.float32)
    w_b = grng.normal(0, 1.0, (latent_dim, spec.seq_b * spec.feat_b)).astype(np.float32)
    split_a = grng.random(latent_dim) < 0.7  # A sees 70% of latent dims
    split_b = ~split_a | (grng.random(latent_dim) < 0.5)

    z = label_vec @ w_latent / np.sqrt(label_vec.shape[1])
    z = z + noise * rng.normal(0, 1.0, z.shape).astype(np.float32)
    z_a = np.where(split_a[None, :], z, 0.0)
    z_b = np.where(split_b[None, :], z, 0.0)

    x_a = np.tanh(z_a @ w_a / np.sqrt(latent_dim))
    x_b = np.tanh(z_b @ w_b / np.sqrt(latent_dim))
    x_a = x_a + 0.3 * noise * rng.normal(0, 1, x_a.shape)
    x_b = x_b + 0.3 * noise * rng.normal(0, 1, x_b.shape)

    ids = np.arange(id_offset, id_offset + n, dtype=np.int64)
    return SyntheticMultimodal(
        spec,
        x_a.reshape(n, spec.seq_a, spec.feat_a).astype(np.float32),
        x_b.reshape(n, spec.seq_b, spec.feat_b).astype(np.float32),
        y.astype(np.float32),
        ids,
    )


def train_val_test(spec: TaskSpec, n_train: int, n_val: int, n_test: int, seed: int = 0):
    """Generate disjoint splits from the same generative process (70/10/20 in paper)."""
    total = generate(spec, n_train + n_val + n_test, seed=seed)
    tr = total.subset(np.arange(0, n_train))
    va = total.subset(np.arange(n_train, n_train + n_val))
    te = total.subset(np.arange(n_train + n_val, n_train + n_val + n_test))
    return tr, va, te
