from repro.checkpoint.store import (latest_step, read_manifest, read_metadata,
                                    restore_checkpoint, save_checkpoint)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "read_manifest", "read_metadata"]
