"""Checkpointing: pytree <-> .npz + JSON manifest (no orbax offline).

Layout:  <dir>/step_<N>/arrays.npz   flattened leaves keyed by path string
         <dir>/step_<N>/manifest.json  treedef + shapes/dtypes + metadata

On restore we fetch to host then (optionally) device_put with the target
sharding, which is how a multi-host restore distributes shards.
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        items[key] = np.asarray(leaf)
    return items, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, metadata: dict | None = None) -> str:
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(out, exist_ok=True)
    items, _ = _flatten_with_paths(tree)
    np.savez(os.path.join(out, "arrays.npz"), **items)
    manifest = {
        "step": step,
        "keys": sorted(items.keys()),
        "shapes": {k: list(v.shape) for k, v in items.items()},
        "dtypes": {k: str(v.dtype) for k, v in items.items()},
        "metadata": metadata or {},
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return out


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)", d))
    ]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, target_tree, step: int | None = None, sharding=None):
    """Restore into the structure of ``target_tree`` (values replaced)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    # `items` preserves tree-flatten order (dict insertion order), so the
    # restored leaves line up with the target treedef.
    items, _ = _flatten_with_paths(target_tree)
    out_leaves = []
    for key, want in items.items():
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"shape mismatch for {key!r}: {arr.shape} vs {want.shape}")
        if sharding is not None:
            arr = jax.device_put(arr, sharding)
        out_leaves.append(arr)
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(target_tree), out_leaves)
