"""Checkpointing: pytree <-> .npz + JSON manifest (no orbax offline).

Layout:  <dir>/step_<N>/arrays.npz   flattened leaves keyed by path string
         <dir>/step_<N>/manifest.json  treedef + shapes/dtypes + metadata

Writes are atomic: both files land in a ``step_<N>.tmp`` staging dir
that is ``os.rename``d into place only once complete, so a crash
mid-write can never leave a partial ``step_<N>`` for ``latest_step`` to
select (stale ``.tmp`` dirs are ignored by the step regex and swept on
the next save of the same step).

On restore we fetch to host then (optionally) device_put with the target
sharding, which is how a multi-host restore distributes shards. Restored
leaves are validated against the target tree's shapes AND dtypes: a
kind mismatch (e.g. an int32 ``last_round`` leaf restored into a float
tree) raises instead of silently reinterpreting; within-kind width
differences (f64 -> f32) are cast to the target dtype.
"""
from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    items = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key in items:
            # nested {"a": {"b": ...}} collides with a literal "a/b" key —
            # one leaf would silently win on save and both would restore
            # from the same array
            raise ValueError(f"duplicate flattened checkpoint key {key!r}")
        items[key] = np.asarray(leaf)
    return items, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, metadata: dict | None = None) -> str:
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = out + ".tmp"
    if os.path.isdir(tmp):  # stale staging dir from a crashed writer
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    items, _ = _flatten_with_paths(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **items)
    manifest = {
        "step": step,
        "keys": sorted(items.keys()),
        "shapes": {k: list(v.shape) for k, v in items.items()},
        "dtypes": {k: str(v.dtype) for k, v in items.items()},
        "metadata": metadata or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    # overwrite via swap, never delete-before-rename: the old step moves
    # aside as ``.old`` (which latest_step/restore treat as a readable
    # fallback), the new one renames into place, and only then is the old
    # data removed — at every instant a complete copy of the step stays
    # findable (a stale .old is swept only while out exists and wins)
    old = out + ".old"
    if os.path.isdir(out):
        if os.path.isdir(old):
            shutil.rmtree(old)
        os.rename(out, old)
    os.rename(tmp, out)
    shutil.rmtree(old, ignore_errors=True)
    return out


def _step_dir(ckpt_dir: str, step: int) -> str:
    """Resolve a step to its directory, falling back to the ``.old`` copy
    a crashed overwrite swap left aside. Pure read-path resolution — no
    renames here, so concurrent readers never race a live writer's swap."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.isdir(path):
        return path
    if os.path.isdir(path + ".old"):
        return path + ".old"
    raise FileNotFoundError(f"no checkpoint for step {step} under {ckpt_dir}")


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    # a ``step_N.old`` with no ``step_N`` is the complete previous copy a
    # crashed overwrite swap moved aside — still a restorable step (the
    # next save of that step sweeps it; .tmp dirs stay invisible: they
    # may be partial or belong to a live writer)
    steps = {
        int(m.group(1))
        for d in os.listdir(ckpt_dir)
        if (m := re.fullmatch(r"step_(\d+)(\.old)?", d))
    }
    return max(steps) if steps else None


def read_metadata(ckpt_dir: str, step: int | None = None) -> dict:
    """The ``metadata`` dict a step was saved with (``{}`` if none) —
    without loading any arrays. Used e.g. by the federated driver to
    validate a resume against the data store the run was checkpointed
    from (``store_fingerprint``)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    with open(os.path.join(_step_dir(ckpt_dir, step), "manifest.json")) as f:
        return json.load(f).get("metadata", {})


def read_manifest(ckpt_dir: str, step: int | None = None) -> dict:
    """The full manifest of a step (keys/shapes/dtypes/metadata) without
    loading any arrays — the input to layout inspection
    (``repro.core.state.manifest_layout``) and capacity-migration
    dispatch (``manifest_capacity``) before a restore commits to a
    target tree."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    with open(os.path.join(_step_dir(ckpt_dir, step), "manifest.json")) as f:
        return json.load(f)


def restore_checkpoint(ckpt_dir: str, target_tree, step: int | None = None, sharding=None):
    """Restore into the structure of ``target_tree`` (values replaced)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = _step_dir(ckpt_dir, step)
    data = np.load(os.path.join(path, "arrays.npz"))
    # `items` preserves tree-flatten order (dict insertion order), so the
    # restored leaves line up with the target treedef.
    items, _ = _flatten_with_paths(target_tree)
    out_leaves = []
    for key, want in items.items():
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"shape mismatch for {key!r}: {arr.shape} vs {want.shape}")
        want_dtype = np.dtype(want.dtype)
        if arr.dtype != want_dtype:
            if arr.dtype.kind != want_dtype.kind:
                raise ValueError(
                    f"dtype mismatch for {key!r}: checkpoint {arr.dtype} vs "
                    f"target {want_dtype} (different kinds — refusing to cast)")
            arr = arr.astype(want_dtype)
        if sharding is not None:
            arr = jax.device_put(arr, sharding)
        out_leaves.append(arr)
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(target_tree), out_leaves)
