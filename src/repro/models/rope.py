"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE [arXiv:2409.12191] splits the head_dim/2 frequency slots into
(temporal, height, width) sections; text tokens use identical t=h=w
positions (reducing to 1-D RoPE), vision patches use their (t, h, w) grid
coordinates.
"""
from __future__ import annotations

import jax.numpy as jnp


def _freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_angles(positions, head_dim: int, theta: float, sections=None):
    """positions: (..., S) int or (..., S, 3) for M-RoPE. Returns (..., S, head_dim/2)."""
    inv = _freqs(head_dim, theta)  # (half,)
    if positions.ndim >= 2 and positions.shape[-1] == 3 and sections is not None:
        # M-RoPE: slot j uses the section's coordinate
        sec = []
        for i, s in enumerate(sections):
            sec.append(jnp.full((s,), i, dtype=jnp.int32))
        sec_id = jnp.concatenate(sec)  # (half,) in {0:t, 1:h, 2:w}
        pos = positions[..., sec_id]  # (..., S, half)
        return pos.astype(jnp.float32) * inv
    return positions[..., None].astype(jnp.float32) * inv


def apply_rope(x, angles):
    """x: (B, S, H, hd); angles: (B, S, hd/2) -> rotated x (rotate-half form)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[..., None, :].astype(x.dtype)  # (B,S,1,half)
    sin = jnp.sin(angles)[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def text_positions(batch: int, seq: int, offset=0):
    """1-D positions (B, S)."""
    return jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None, :] + offset, (batch, seq))


def mrope_positions(batch: int, n_vision: int, n_text: int, grid: int | None = None):
    """(B, S, 3) positions: vision patches on an h×w grid at t=0, then text."""
    if grid is None:
        grid = max(int(n_vision**0.5), 1)
    if n_vision:
        idx = jnp.arange(n_vision, dtype=jnp.int32)
        vis = jnp.stack([jnp.zeros_like(idx), idx // grid, idx % grid], axis=-1)
    else:
        vis = jnp.zeros((0, 3), jnp.int32)
    t0 = (n_vision and (grid + 1)) or 0
    tpos = jnp.arange(n_text, dtype=jnp.int32) + t0
    txt = jnp.stack([tpos, tpos, tpos], axis=-1)
    pos = jnp.concatenate([vis, txt], axis=0)
    return jnp.broadcast_to(pos[None], (batch, n_vision + n_text, 3))
