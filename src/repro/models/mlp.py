"""Feed-forward blocks: SwiGLU / GELU / squared-ReLU."""
from __future__ import annotations

import jax

from repro.models.common import activation, dense, dense_init


def mlp_init(key, d: int, d_ff: int, act: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "up": dense_init(k1, d, d_ff, dtype),
        "down": dense_init(k2, d_ff, d, dtype),
    }
    if act == "swiglu":
        p["gate"] = dense_init(k3, d, d_ff, dtype)
    return p


def mlp(p, x, act: str):
    if act == "swiglu":
        h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    else:
        h = activation(act)(dense(p["up"], x))
    return dense(p["down"], h)
