from repro.models.config import ArchConfig
from repro.models.backbone import (
    init_params,
    forward,
    loss_fn,
    prefill,
    decode_step,
    init_cache,
    make_train_step,
    make_serve_step,
    n_scan_layers,
)

__all__ = [
    "ArchConfig",
    "init_params",
    "forward",
    "loss_fn",
    "prefill",
    "decode_step",
    "init_cache",
    "make_train_step",
    "make_serve_step",
    "n_scan_layers",
]
