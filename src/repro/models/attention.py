"""Multi-head attention with GQA, causal/bidirectional/sliding-window masks,
and a decode path against a (ring-buffer) KV cache.

Two XLA execution paths (the Pallas flash kernel in
``repro.kernels.flash_attention`` is the TPU Mosaic hot-path, validated
against the same math):

- ``gqa_sdpa``          one-shot einsum attention. K/V heads are NEVER
                        repeated to Hq (queries are grouped (Hkv, G)
                        instead), so GQA memory stays at the kv-head size.
- ``chunked_gqa_sdpa``  flash-style online-softmax over (block_q, block_k)
                        tiles via lax.scan — O(S) live memory instead of
                        O(S^2). Selected statically for long sequences;
                        the q-block body is checkpointed so the backward
                        pass recomputes score tiles instead of storing
                        them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import dense, dense_init
from repro.models.rope import apply_rope, rope_angles

NEG_INF = -1e30

# statically selected: einsum path below this q*k size, chunked above
CHUNKED_THRESHOLD = 2 ** 22  # 2048 x 2048


def attn_init(key, cfg, dtype):
    d, hd = cfg.d_model, cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d, cfg.n_heads * hd, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(kk, d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(kv, d, cfg.n_kv_heads * hd, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ko, cfg.n_heads * hd, d, dtype),
    }


def cross_attn_init(key, cfg, dtype):
    return attn_init(key, cfg, dtype)


def _repeat_kv(x, groups: int):
    """(B, S, Hkv, hd) -> (B, S, Hkv*groups, hd). Oracle/test path only —
    the production paths keep K/V at kv-head width."""
    if groups == 1:
        return x
    b, s, h, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, h, groups, d)).reshape(b, s, h * groups, d)


def gqa_sdpa(q, k, v, mask, softcap: float = 0.0):
    """q (B,Sq,Hq,hd); k/v (B,Sk,Hkv,hd); mask broadcastable to
    (B,Hkv,G,Sq,Sk) from (B or 1, 1, Sq, Sk) bool."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    if mask is not None:
        scores = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, hd).astype(q.dtype)


def sdpa(q, k, v, mask, softcap: float = 0.0):
    """Back-compat wrapper: full-head q/k/v (B,S,H,hd) einsum attention."""
    return gqa_sdpa(q, k, v, mask, softcap)


def chunked_gqa_sdpa(q, k, v, *, causal: bool, window: int = 0, q_offset: int = 0,
                     softcap: float = 0.0, block_q: int = 512, block_k: int = 1024):
    """Flash-style attention in pure JAX: lax.scan over q blocks, online
    softmax over k blocks. Live memory O(block_q * block_k) per (Hkv, G).

    q (B,Sq,Hq,hd); k/v (B,Sk,Hkv,hd). q_offset aligns query index qi ->
    key index (qi + q_offset); pass sk - sq for end-aligned suffix queries.
    """
    b, sq, hq, hd = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    nq, nk = (sq + pad_q) // block_q, (sk + pad_k) // block_k

    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    # (nq, B, bq, Hkv, G, hd)
    qb = qp.reshape(b, nq, block_q, hkv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    kb = kp.reshape(b, nk, block_k, hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(b, nk, block_k, hkv, hd).transpose(1, 0, 2, 3, 4)

    def q_body(_, qx):
        qi0, q_i = qx
        qi = qi0 + jnp.arange(block_q)[:, None] + q_offset  # key-space index
        q32 = q_i.astype(jnp.float32)

        def k_body(carry, kx):
            m_prev, l_prev, acc = carry
            ki0, k_i, v_i = kx
            ki = ki0 + jnp.arange(block_k)[None, :]
            s = jnp.einsum("bqkgd,bskd->bkgqs", q32, k_i.astype(jnp.float32)) * scale
            if softcap:
                s = softcap * jnp.tanh(s / softcap)
            mask = (ki < sk) & (qi < sk)
            if causal:
                mask = mask & (ki <= qi)
            if window > 0:
                mask = mask & (ki > qi - window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = alpha * l_prev + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum("bkgqs,bskd->bkgqd", p,
                                                      v_i.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hkv, g, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, block_q), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, block_q, hd), jnp.float32)
        ki0s = jnp.arange(nk) * block_k
        (m, l, acc), _ = jax.lax.scan(k_body, (m0, l0, a0), (ki0s, kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B,Hkv,G,bq,hd)
        return None, out.transpose(0, 3, 1, 2, 4)  # (B,bq,Hkv,G,hd)

    qi0s = jnp.arange(nq) * block_q
    # checkpoint: backward recomputes score tiles instead of storing them
    _, outs = jax.lax.scan(jax.checkpoint(q_body), None, (qi0s, qb))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * block_q, hq, hd)
    return out[:, :sq].astype(q.dtype)


def causal_mask(sq: int, sk: int, window: int = 0, q_offset: int = 0):
    """(1,1,Sq,Sk) bool; window>0 adds sliding-window lower bound."""
    qi = jnp.arange(sq)[:, None] + q_offset
    ki = jnp.arange(sk)[None, :]
    m = ki <= qi
    if window > 0:
        m = m & (ki > qi - window)
    return m[None, None]


def attend(p, cfg, x, positions, *, causal: bool, kv_x=None, mask=None):
    """Full-sequence attention (training / prefill / encoder / cross).

    kv_x: source for K/V (cross-attention) — defaults to x (self-attention).
    positions: (B,S) or (B,S,3); None disables RoPE (e.g. cross-attn).
    Returns (out, (k, v)) so prefill can persist the cache.
    """
    hd = cfg.hd
    b, sq, _ = x.shape
    src = x if kv_x is None else kv_x
    sk = src.shape[1]
    q = dense(p["wq"], x).reshape(b, sq, cfg.n_heads, hd)
    k = dense(p["wk"], src).reshape(b, sk, cfg.n_kv_heads, hd)
    v = dense(p["wv"], src).reshape(b, sk, cfg.n_kv_heads, hd)
    if positions is not None and cfg.pos in ("rope", "mrope"):
        sections = cfg.mrope_sections if cfg.pos == "mrope" else None
        ang_q = rope_angles(positions, hd, cfg.rope_theta, sections)
        q = apply_rope(q, ang_q)
        if kv_x is None:
            k = apply_rope(k, ang_q)
    window = cfg.window if (cfg.attn_kind == "sliding" and causal) else 0
    if mask is None and sq * sk >= CHUNKED_THRESHOLD:
        out = chunked_gqa_sdpa(q, k, v, causal=causal, window=window,
                               softcap=cfg.attn_logit_softcap)
    else:
        if mask is None and causal:
            mask = causal_mask(sq, sk, window)
        out = gqa_sdpa(q, k, v, mask, cfg.attn_logit_softcap)
    out = dense(p["wo"], out.reshape(b, sq, cfg.n_heads * hd))
    return out, (k, v)


# ---------------------------------------------------------------- decode ----

def init_kv_cache(cfg, batch: int, max_len: int, dtype):
    """Ring-buffer KV cache for one layer. For sliding attention the buffer
    is the window size; keys are stored post-RoPE (absolute positions)."""
    length = min(max_len, cfg.window) if cfg.attn_kind == "sliding" else max_len
    shape = (batch, length, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attend(p, cfg, x, cache, index, positions=None):
    """One-token decode. x (B,1,d); cache {'k','v'} (B,L,Hkv,hd); index scalar
    = number of tokens already in context. Returns (out, new_cache)."""
    hd = cfg.hd
    b = x.shape[0]
    length = cache["k"].shape[1]
    q = dense(p["wq"], x).reshape(b, 1, cfg.n_heads, hd)
    k = dense(p["wk"], x).reshape(b, 1, cfg.n_kv_heads, hd)
    v = dense(p["wv"], x).reshape(b, 1, cfg.n_kv_heads, hd)
    if cfg.pos in ("rope", "mrope"):
        if positions is None:
            positions = jnp.broadcast_to(index[None, None].astype(jnp.int32), (b, 1))
        sections = cfg.mrope_sections if cfg.pos == "mrope" else None
        ang = rope_angles(positions, hd, cfg.rope_theta, sections)
        q = apply_rope(q, ang)
        k = apply_rope(k, ang)
    slot = jnp.mod(index, length)
    new_k = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    new_v = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    # valid slots: those already written (ring semantics)
    ki = jnp.arange(length)
    valid = jnp.where(index + 1 >= length, jnp.ones((length,), bool), ki <= index)
    mask = valid[None, None, None, :]
    out = gqa_sdpa(q, new_k.astype(x.dtype), new_v.astype(x.dtype), mask,
                   cfg.attn_logit_softcap)
    out = dense(p["wo"], out.reshape(b, 1, cfg.n_heads * hd))
    return out, {"k": new_k, "v": new_v}


def decode_cross_attend(p, cfg, x, cross_kv):
    """Decoder cross-attention against a precomputed encoder K/V cache."""
    hd = cfg.hd
    b = x.shape[0]
    q = dense(p["wq"], x).reshape(b, 1, cfg.n_heads, hd)
    k, v = cross_kv  # raw (kv-head width) as produced by prefill
    out = gqa_sdpa(q, k.astype(x.dtype), v.astype(x.dtype), None,
                   cfg.attn_logit_softcap)
    return dense(p["wo"], out.reshape(b, 1, cfg.n_heads * hd))
