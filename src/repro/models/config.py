"""Architecture configuration schema.

One ``ArchConfig`` covers all six assigned families (dense / moe / ssm /
hybrid / vlm / audio). Every assigned architecture instantiates this in
``repro/configs/<id>.py`` with its exact published numbers, and provides a
``reduced()`` smoke variant (<=2 layers, d_model<=512, <=4 experts) for CPU
tests, per the assignment.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # block structure
    block_type: str = "attn"  # attn | xlstm_pair | hybrid | encdec
    act: str = "swiglu"  # swiglu | gelu | relu2
    pos: str = "rope"  # rope | mrope | learned | none
    rope_theta: float = 10000.0
    mrope_sections: tuple = (16, 24, 24)  # head_dim/2 split among (t, h, w)

    # attention
    attn_kind: str = "full"  # full | sliding
    window: int = 4096
    attn_logit_softcap: float = 0.0
    qkv_bias: bool = False

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM / xLSTM / hybrid
    ssm_state: int = 0  # mamba state size N
    ssm_head_dim: int = 64  # mamba head dim P
    ssm_expand: int = 2  # mLSTM up-projection factor

    # encoder-decoder (audio family)
    n_enc_layers: int = 0

    # modality frontend (STUB per assignment carve-out)
    frontend: str = "none"  # none | audio_stub | vision_stub
    frontend_dim: int = 0  # raw frame/patch embedding dim fed to projector
    vision_tokens: int = 1024  # patches per image at train/prefill (vlm)

    # MoE dispatch grouping (GShard-style): number of token groups, set to
    # the data-shard count by the launcher. 0 = flat (single-device) path.
    moe_groups: int = 0

    # distribution: mesh axes the activation BATCH dim is sharded over
    # (e.g. ("data",) or ("pod", "data")). Empty = no constraint (single
    # device / tests). Weights shard per launch/shardings.py rules.
    act_shard: tuple = ()

    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: bool = False
    citation: str = ""

    # ---- derived ----
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def cdtype(self):
        return _DTYPES[self.compute_dtype]

    @property
    def is_encdec(self) -> bool:
        return self.block_type == "encdec"

    @property
    def subquadratic(self) -> bool:
        """Can this arch decode with O(1)-per-token state at 500k context?"""
        return self.block_type in ("xlstm_pair", "hybrid") or self.attn_kind == "sliding"

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for roofline."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        qkv_out = d * (self.n_heads + 2 * self.n_kv_heads) * self.hd + self.n_heads * self.hd * d
        if self.act == "swiglu":
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        if self.n_experts:
            mlp_total = self.n_experts * mlp + self.n_shared_experts * mlp
        else:
            mlp_total = mlp
        per_layer = qkv_out + mlp_total
        if self.block_type == "xlstm_pair":
            e = self.ssm_expand
            # mLSTM: up(2ed) + qkv on ed + down; sLSTM: 4 gates + recurrent + GLU
            mlstm = d * (2 * e * d) + 3 * (e * d) * (e * d) // max(self.n_heads, 1) + e * d * d
            slstm = 8 * d * d + int(2 * d * (4 * d / 3))
            per_layer = (mlstm + slstm) / 2  # per single layer (pairs hold both)
        if self.block_type == "hybrid":
            n = self.ssm_state
            p = self.ssm_head_dim
            h = self.n_heads
            mamba = d * (2 * h * p) + h * p * (2 * n + 1) + h * p * d
            per_layer = qkv_out + mlp + mamba
        layers = self.n_layers + self.n_enc_layers
        return int(emb + layers * per_layer)

    @property
    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if not self.n_experts:
            return self.n_params
        d, ff = self.d_model, self.d_ff
        mlp = (3 if self.act == "swiglu" else 2) * d * ff
        inactive = (self.n_experts - self.top_k) * mlp * self.n_layers
        return int(self.n_params - inactive)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 128)
        n_heads = min(self.n_heads, 4)
        hd = max(d // n_heads, 8)
        kv = max(1, min(self.n_kv_heads, n_heads))
        # keep GQA structure: kv must divide heads
        while n_heads % kv:
            kv -= 1
        # rescale M-RoPE sections to the reduced head_dim (sum must equal hd/2)
        half = hd // 2
        tot = sum(self.mrope_sections)
        secs = [max(1, (s * half) // tot) for s in self.mrope_sections]
        secs[0] += half - sum(secs)
        return self.replace(
            mrope_sections=tuple(secs),
            n_layers=2,
            n_enc_layers=2 if self.n_enc_layers else 0,
            d_model=d,
            n_heads=n_heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4),
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2),
            ssm_head_dim=min(self.ssm_head_dim, 16),
            window=min(self.window, 64),
            vision_tokens=8,
            frontend_dim=min(self.frontend_dim, 32) if self.frontend_dim else 0,
            param_dtype="float32",
            compute_dtype="float32",
            remat=False,
        )
