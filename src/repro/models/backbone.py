"""Backbone: composes blocks into full models and exposes the three
entry points the launcher lowers —

    forward(params, cfg, batch)                    train / eval, full seq
    prefill(params, cfg, batch, max_len)           build decode caches
    decode_step(params, cfg, tokens, cache, index) one-token serve step

plus factories ``make_train_step`` (grad-accum microbatching + AdamW) and
``make_serve_step``. Layers are stacked (vmap init) and iterated with
``lax.scan`` so the HLO stays one-layer-sized regardless of depth; with
``cfg.remat`` the layer body is wrapped in ``jax.checkpoint``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.common import (
    dense,
    dense_init,
    embed,
    embedding_init,
    rmsnorm,
    rmsnorm_init,
    softmax_cross_entropy,
)
from repro.models.config import ArchConfig
from repro.models.frontends import frontend_apply, frontend_init
from repro.models.rope import mrope_positions, text_positions
from repro.optim import apply_updates

MAX_LEARNED_POS = 32768  # whisper-style learned positions (long_500k is skipped for encdec)


def _constrain(cfg: ArchConfig, x):
    """Pin the activation batch dim to cfg.act_shard mesh axes. Without
    this, aggressive 2D weight sharding makes XLA reshard activations to
    feature-sharded/batch-REPLICATED layouts (observed: 16x redundant
    compute on the 16x16 mesh). No-op when act_shard is empty."""
    if not cfg.act_shard:
        return x
    from jax.sharding import PartitionSpec as P

    spec = P(tuple(cfg.act_shard), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


# ------------------------------------------------------------------ init ----

_BLOCK = {
    "attn": (B.attn_block_init, B.attn_block, B.attn_block_decode,
             B.attn_block_cache, B.attn_block_prefill),
    "hybrid": (B.hybrid_block_init, B.hybrid_block, B.hybrid_block_decode,
               B.hybrid_block_cache, B.hybrid_block_prefill),
    "xlstm_pair": (B.xlstm_pair_init, B.xlstm_pair_block, B.xlstm_pair_decode,
                   B.xlstm_pair_cache, B.xlstm_pair_prefill),
}


def n_scan_layers(cfg: ArchConfig) -> int:
    if cfg.block_type == "xlstm_pair":
        assert cfg.n_layers % 2 == 0
        return cfg.n_layers // 2
    return cfg.n_layers


def init_params(key: jax.Array, cfg: ArchConfig):
    dtype = cfg.pdtype
    keys = jax.random.split(key, 8)
    p = {"embed": embedding_init(keys[0], cfg.vocab_size, cfg.d_model, dtype),
         "final_norm": rmsnorm_init(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.frontend != "none":
        p["frontend"] = frontend_init(keys[2], cfg, dtype)
    if cfg.pos == "learned":
        p["pos_emb"] = (jax.random.normal(keys[3], (MAX_LEARNED_POS, cfg.d_model))
                        * 0.02).astype(dtype)

    if cfg.is_encdec:
        enc_keys = jax.random.split(keys[4], cfg.n_enc_layers)
        dec_keys = jax.random.split(keys[5], cfg.n_layers)
        p["enc_layers"] = jax.vmap(lambda k: B.enc_block_init(k, cfg, dtype))(enc_keys)
        p["enc_norm"] = rmsnorm_init(cfg.d_model, dtype)
        p["dec_layers"] = jax.vmap(lambda k: B.dec_block_init(k, cfg, dtype))(dec_keys)
        if cfg.pos == "learned":
            p["enc_pos_emb"] = (jax.random.normal(keys[6], (MAX_LEARNED_POS, cfg.d_model))
                                * 0.02).astype(dtype)
    else:
        init_fn = _BLOCK[cfg.block_type][0]
        layer_keys = jax.random.split(keys[4], n_scan_layers(cfg))
        p["layers"] = jax.vmap(lambda k: init_fn(k, cfg, dtype))(layer_keys)
    return p


# ------------------------------------------------------------- embedding ----

def _embed_inputs(params, cfg: ArchConfig, batch):
    """Returns (x (B,S,d), positions, loss_mask or None)."""
    cdt = cfg.cdtype
    if cfg.frontend == "vision_stub":  # VLM: [patches ; tokens]
        vis = frontend_apply(params["frontend"], cfg, batch["patches"], cdt)
        txt = embed(params["embed"], batch["tokens"], cdt)
        x = jnp.concatenate([vis, txt], axis=1)
        b, n_vis = vis.shape[0], vis.shape[1]
        positions = mrope_positions(b, n_vis, txt.shape[1])
        mask = jnp.concatenate(
            [jnp.zeros((b, n_vis), jnp.float32), jnp.ones((b, txt.shape[1]), jnp.float32)],
            axis=1)
        return x, positions, mask
    x = embed(params["embed"], batch["tokens"], cdt)
    b, s = batch["tokens"].shape
    if cfg.pos == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_emb"], 0, s, 0).astype(cdt)[None]
        positions = None
    else:
        positions = text_positions(b, s)
    return x, positions, None


def _lm_logits(params, cfg: ArchConfig, x):
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        return x @ params["embed"]["table"].astype(x.dtype).T
    return dense(params["lm_head"], x)


def _scan_layers(cfg, layer_fn, x, stacked_params, remat: bool):
    if remat:
        layer_fn = jax.checkpoint(layer_fn, policy=jax.checkpoint_policies.nothing_saveable)

    def body(carry, lp):
        y, aux = layer_fn(_constrain(cfg, carry), lp)
        return _constrain(cfg, y), aux

    x, auxs = jax.lax.scan(body, x, stacked_params)
    return x, jnp.sum(auxs)


# ----------------------------------------------------------------- train ----

def forward(params, cfg: ArchConfig, batch):
    """Full-sequence forward. Returns (logits, aux_loss)."""
    if cfg.is_encdec:
        return _encdec_forward(params, cfg, batch)
    x, positions, _ = _embed_inputs(params, cfg, batch)
    x = _constrain(cfg, x)
    apply_fn = _BLOCK[cfg.block_type][1]

    def layer_fn(carry, lp):
        return apply_fn(lp, cfg, carry, positions)

    x, aux = _scan_layers(cfg, layer_fn, x, params["layers"], cfg.remat)
    return _lm_logits(params, cfg, x), aux


def _encode(params, cfg: ArchConfig, frames):
    cdt = cfg.cdtype
    x = frontend_apply(params["frontend"], cfg, frames, cdt)
    s = x.shape[1]
    x = x + jax.lax.dynamic_slice_in_dim(params["enc_pos_emb"], 0, s, 0).astype(cdt)[None]

    def layer_fn(carry, lp):
        return B.enc_block(lp, cfg, carry, None)

    x, _ = _scan_layers(cfg, layer_fn, x, params["enc_layers"], cfg.remat)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _encdec_forward(params, cfg: ArchConfig, batch):
    enc_out = _encode(params, cfg, batch["frames"])
    cdt = cfg.cdtype
    tok = batch["tokens"]
    x = embed(params["embed"], tok, cdt)
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_emb"], 0, tok.shape[1], 0).astype(cdt)[None]

    def layer_fn(carry, lp):
        y, _ = B.dec_block(lp, cfg, carry, enc_out, None)
        return y, jnp.zeros((), jnp.float32)

    x, aux = _scan_layers(cfg, layer_fn, x, params["dec_layers"], cfg.remat)
    return _lm_logits(params, cfg, x), aux


def loss_fn(params, cfg: ArchConfig, batch):
    logits, aux = forward(params, cfg, batch)
    labels = batch["labels"]
    if cfg.frontend == "vision_stub":
        # loss only over the text region (vision tokens have no labels)
        n_vis = batch["patches"].shape[1]
        logits = logits[:, n_vis:]
    ce = softmax_cross_entropy(logits, labels)
    mask = batch.get("loss_mask")
    if mask is None:
        loss = jnp.mean(ce)
    else:
        loss = jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + cfg.router_aux_weight * aux
    return total, {"loss": loss, "aux": aux}


def make_train_step(cfg: ArchConfig, optimizer, microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def grads_of(params, mb):
        (total, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, cfg, mb)
        return total, metrics, grads

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def to_mb(x):
                x = x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])
                if cfg.act_shard:
                    from jax.sharding import PartitionSpec as P

                    x = jax.lax.with_sharding_constraint(
                        x, P(None, tuple(cfg.act_shard), *([None] * (x.ndim - 2))))
                return x

            mb_batch = jax.tree.map(to_mb, batch)

            def acc(carry, mb):
                g_acc, l_acc = carry
                total, _m, g = grads_of(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + total), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, total), _ = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)), mb_batch)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            total = total / microbatches
            metrics = {"loss": total, "aux": jnp.zeros((), jnp.float32)}
        else:
            total, metrics, grads = grads_of(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics, total=total)
        return params, opt_state, metrics

    return train_step


# --------------------------------------------------------------- serving ----

def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None, enc_len: int = 1500):
    """Decode cache for the whole stack (leading axis = scanned layers).
    enc_len: encoder output length for the cross-attention cache (encdec)."""
    dtype = dtype or cfg.cdtype
    n = n_scan_layers(cfg)
    if cfg.is_encdec:
        single = {
            "self": B.attn_block_cache(cfg, batch, max_len, dtype),
            "cross": (jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.hd), dtype),
                      jnp.zeros((batch, enc_len, cfg.n_kv_heads, cfg.hd), dtype)),
        }
        n = cfg.n_layers
    else:
        single = _BLOCK[cfg.block_type][3](cfg, batch, max_len, dtype)
    return jax.tree.map(lambda x: jnp.zeros((n,) + x.shape, x.dtype), single)


def prefill(params, cfg: ArchConfig, batch, max_len: int, cache_dtype=None):
    """Process the prompt; returns (last-token logits, cache, next_index)."""
    cache_dtype = cache_dtype or cfg.cdtype
    if cfg.is_encdec:
        return _encdec_prefill(params, cfg, batch, max_len, cache_dtype)
    x, positions, _ = _embed_inputs(params, cfg, batch)
    x = _constrain(cfg, x)
    prefill_fn = _BLOCK[cfg.block_type][4]

    def body(carry, lp):
        y, cache_l = prefill_fn(lp, cfg, _constrain(cfg, carry), positions,
                                max_len, cache_dtype)
        return _constrain(cfg, y), cache_l

    x, cache = jax.lax.scan(body, x, params["layers"])
    logits = _lm_logits(params, cfg, x[:, -1:])
    return logits, cache, x.shape[1]


def _encdec_prefill(params, cfg, batch, max_len, cache_dtype):
    enc_out = _encode(params, cfg, batch["frames"])
    cdt = cfg.cdtype
    tok = batch["tokens"]  # decoder prompt (e.g. BOS)
    x = embed(params["embed"], tok, cdt)
    x = x + jax.lax.dynamic_slice_in_dim(params["pos_emb"], 0, tok.shape[1], 0).astype(cdt)[None]

    def body(carry, lp):
        y, cache_l = B.dec_block_prefill(lp, cfg, carry, enc_out, None, max_len, cache_dtype)
        return y, cache_l

    x, cache = jax.lax.scan(body, x, params["dec_layers"])
    logits = _lm_logits(params, cfg, x[:, -1:])
    return logits, cache, tok.shape[1]


def decode_step(params, cfg: ArchConfig, tokens, cache, index):
    """tokens (B,1) int32; index: scalar count of tokens already in context."""
    cdt = cfg.cdtype
    x = embed(params["embed"], tokens, cdt)
    if cfg.pos == "learned":
        pe = jnp.take(params["pos_emb"], jnp.minimum(index, MAX_LEARNED_POS - 1), axis=0)
        x = x + pe.astype(cdt)[None, None, :]
    b = tokens.shape[0]
    positions = None
    if cfg.pos == "mrope":
        pos1 = jnp.broadcast_to(index[None, None].astype(jnp.int32), (b, 1))
        positions = jnp.stack([pos1, pos1, pos1], axis=-1)

    if cfg.is_encdec:
        def body(carry, xs):
            lp, cache_l = xs
            y, new_cache = B.dec_block_decode(lp, cfg, carry, cache_l, index)
            return y, new_cache
        x, new_cache = jax.lax.scan(body, x, (params["dec_layers"], cache))
    else:
        decode_fn = _BLOCK[cfg.block_type][2]

        def body(carry, xs):
            lp, cache_l = xs
            y, new_cache = decode_fn(lp, cfg, carry, cache_l, index, positions)
            return y, new_cache
        x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    return _lm_logits(params, cfg, x), new_cache


def make_serve_step(cfg: ArchConfig):
    def serve_step(params, tokens, cache, index):
        return decode_step(params, cfg, tokens, cache, index)

    return serve_step
