"""Recurrent sequence-mixing primitives.

``gated_linear_scan`` is the single chunkwise-parallel primitive behind both
the mLSTM cell (xlstm-350m) and the Mamba-2-style SSD heads (hymba-1.5b):

    C_t = exp(lf_t) * C_{t-1} + k_t v_t^T          (state  (dk, dv))
    n_t = exp(lf_t) * n_{t-1} + k_t                (normalizer, optional)
    h_t = q_t @ C_t   [ / max(|q_t . n_t|, 1) ]

computed chunk-parallel: intra-chunk attention-like term + inter-chunk state
carried by ``lax.scan``. This is the TPU-friendly form (MXU matmuls per
chunk instead of a length-S elementwise recurrence); the Pallas kernel in
``repro.kernels.mlstm_scan`` implements the same schedule with explicit VMEM
tiling and is validated against the sequential reference.

Numerical simplifications vs. Beck et al. (documented in DESIGN.md):
input gate uses sigmoid rather than stabilized-exp gating; the chunkwise
decay math is exact given the gates.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense, dense_init


def gated_linear_scan(q, k, v, log_f, *, chunk: int = 64, normalize: bool = True,
                      initial_state=None, return_state: bool = False):
    """q,k: (B,H,S,dk); v: (B,H,S,dv); log_f: (B,H,S) per-step log decay <= 0.

    Returns h (B,H,S,dv) (and final (C, n) if return_state).
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    orig_s = s
    if s % chunk:
        pad = chunk - s % chunk
        zq = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 3))
        q, k, v = zq(q), zq(k), zq(v)
        log_f = jnp.pad(log_f, ((0, 0), (0, 0), (0, pad)))
        s = q.shape[2]
    nc = s // chunk

    def to_chunks(x):
        return x.reshape(b, h, nc, chunk, *x.shape[3:])

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lfc = log_f.reshape(b, h, nc, chunk).astype(jnp.float32)
    d_in = jnp.cumsum(lfc, axis=-1)  # inclusive in-chunk cumulative decay
    d_total = d_in[..., -1]  # (B,H,nc)

    # intra-chunk: S_ij = (q_i . k_j) * exp(d_i - d_j) for j <= i
    decay_qk = d_in[..., :, None] - d_in[..., None, :]  # (B,H,nc,L,L)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay_qk = jnp.where(tri, decay_qk, -jnp.inf)
    scores = jnp.einsum("bhcik,bhcjk->bhcij", qc.astype(jnp.float32), kc.astype(jnp.float32))
    scores = scores * jnp.exp(decay_qk)
    intra = jnp.einsum("bhcij,bhcjv->bhciv", scores, vc.astype(jnp.float32))
    # normalizer intra term: sum_j scores_ij  (scores already contain q.k)
    intra_n = scores.sum(axis=-1)  # (B,H,nc,L)

    # per-chunk state contributions: sum_j exp(D - d_j) k_j v_j^T
    w_state = jnp.exp(d_total[..., None] - d_in)  # (B,H,nc,L)
    kv_chunk = jnp.einsum("bhcj,bhcjk,bhcjv->bhckv", w_state, kc.astype(jnp.float32),
                          vc.astype(jnp.float32))
    kn_chunk = jnp.einsum("bhcj,bhcjk->bhck", w_state, kc.astype(jnp.float32))

    if initial_state is None:
        c0 = jnp.zeros((b, h, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
    else:
        c0, n0 = initial_state

    def step(carry, xs):
        c_prev, n_prev = carry
        q_i, d_i, dt_i, kv_i, kn_i, intra_i, intra_n_i = xs
        # inter-chunk contribution
        w = jnp.exp(d_i)[..., None]  # (B,H,L,1)
        inter = jnp.einsum("bhlk,bhkv->bhlv", q_i.astype(jnp.float32) * w, c_prev)
        inter_n = jnp.einsum("bhlk,bhk->bhl", q_i.astype(jnp.float32) * w, n_prev)
        h_i = intra_i + inter
        if normalize:  # fused into the chunk step: avoids stacking a
            # separate (S,) normalizer output across the scan
            n_i = intra_n_i + inter_n
            h_i = h_i / jnp.maximum(jnp.abs(n_i), 1.0)[..., None]
        # state update. NOTE: h_i stays f32 — emitting scan outputs in a
        # dtype other than the loop's compute dtype makes XLA convert the
        # WHOLE stacked buffer every iteration (measured: +3x HBM bytes).
        decay_tot = jnp.exp(dt_i)[..., None, None]
        c_new = decay_tot * c_prev + kv_i
        n_new = jnp.exp(dt_i)[..., None] * n_prev + kn_i
        return (c_new, n_new), h_i

    xs = (
        jnp.moveaxis(qc, 2, 0),
        jnp.moveaxis(d_in, 2, 0),
        jnp.moveaxis(d_total, 2, 0),
        jnp.moveaxis(kv_chunk, 2, 0),
        jnp.moveaxis(kn_chunk, 2, 0),
        jnp.moveaxis(intra, 2, 0),
        jnp.moveaxis(intra_n, 2, 0),
    )
    (c_fin, n_fin), hs = jax.lax.scan(step, (c0, n0), xs)
    hs = jnp.moveaxis(hs, 0, 2).reshape(b, h, s, dv)
    # returned in f32 (the scan's compute dtype): casting the stacked scan
    # output here makes XLA re-convert the whole buffer per iteration —
    # callers cast after their next projection instead
    hs = hs[:, :, :orig_s]
    if return_state:
        return hs, (c_fin, n_fin)
    return hs


def gated_linear_step(q, k, v, log_f, state, *, normalize: bool = True):
    """Single-token decode. q,k (B,H,dk); v (B,H,dv); log_f (B,H).
    state = (C (B,H,dk,dv), n (B,H,dk)). Returns (h (B,H,dv), new_state)."""
    c, n = state
    decay = jnp.exp(log_f.astype(jnp.float32))[..., None, None]
    c = decay * c + jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32), v.astype(jnp.float32))
    n = decay[..., 0] * n + k.astype(jnp.float32)
    h = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), c)
    if normalize:
        denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), n)), 1.0)
        h = h / denom[..., None]
    return h.astype(v.dtype), (c, n)


def gated_linear_scan_ref(q, k, v, log_f, *, normalize: bool = True, initial_state=None):
    """Sequential oracle (lax.scan over time) — used by kernel/chunkwise tests."""
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    if initial_state is None:
        c0 = jnp.zeros((b, h, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
    else:
        c0, n0 = initial_state

    def step(carry, xs):
        qt, kt, vt, ft = xs
        ht, carry = gated_linear_step(qt, kt, vt, ft, carry, normalize=normalize)
        return carry, ht

    xs = (jnp.moveaxis(q, 2, 0), jnp.moveaxis(k, 2, 0), jnp.moveaxis(v, 2, 0),
          jnp.moveaxis(log_f, 2, 0))
    _, hs = jax.lax.scan(step, (c0, n0), xs)
    return jnp.moveaxis(hs, 0, 2)


# ------------------------------------------------------------------ sLSTM ----

def slstm_init(key, d: int, n_heads: int, dtype):
    hd = d // n_heads
    ks = jax.random.split(key, 3)
    scale = 1.0 / jnp.sqrt(d)
    rscale = 1.0 / jnp.sqrt(hd)
    return {
        "wx": (jax.random.normal(ks[0], (d, 4 * d)) * scale).astype(dtype),  # z,i,f,o
        "r": (jax.random.normal(ks[1], (n_heads, hd, 4 * hd)) * rscale).astype(dtype),
        "b": jnp.zeros((4 * d,), dtype),
    }


def slstm_scan(p, x, n_heads: int, initial_state=None, shard_axes=()):
    """Stabilized sLSTM over time (true recurrence -> lax.scan).

    x: (B, S, d). Returns (h (B,S,d), final_state).
    State per head: c, n, m, h_prev each (B, H, hd).

    shard_axes: mesh axes the batch dim is sharded over. When set, the
    time-scan runs inside ``jax.shard_map``: under plain jit+GSPMD the
    recurrent-weight gradient accumulation crosses the batch sharding and
    XLA emits an all-reduce EVERY time step (measured ~50% of xlstm's
    collective bytes); inside shard_map the loop is collective-free and
    the single weight-grad psum is inserted at exit by the transpose.
    Only the scan goes inside — the projections stay under GSPMD tensor
    parallelism.
    """
    b, s, d = x.shape
    hd = d // n_heads
    # pre-activations in f32 BEFORE entering the scan: the scan's compute
    # dtype is f32, and mixing dtypes across the loop boundary makes the
    # backward pass round-trip its whole cotangent stack through converts
    # EVERY time step (measured 63% of the arch's HBM bytes)
    pre_x = (x @ p["wx"].astype(x.dtype) + p["b"].astype(x.dtype)).astype(jnp.float32)
    pre_x = pre_x.reshape(b, s, 4, n_heads, hd)

    if initial_state is None:
        zero = jnp.zeros((b, n_heads, hd), jnp.float32)
        state0 = (zero, zero, zero - 1e30, zero)  # c, n, m, h_prev
    else:
        state0 = initial_state

    r = p["r"].astype(jnp.float32)  # (H, hd, 4hd)

    def core(r_, pre_x_, state0_):
        bl = pre_x_.shape[0]

        def step(carry, pre_t):
            c, n, m, h_prev = carry
            rec = jnp.einsum("bhi,hij->bhj", h_prev, r_).reshape(bl, n_heads, 4, hd)
            rec = jnp.moveaxis(rec, 2, 0)
            pre = pre_t.astype(jnp.float32)  # (4, B, H, hd) after moveaxis below
            z = jnp.tanh(pre[0] + rec[0])
            log_i = pre[1] + rec[1]
            log_f = jax.nn.log_sigmoid(pre[2] + rec[2])
            o = jax.nn.sigmoid(pre[3] + rec[3])
            m_new = jnp.maximum(log_f + m, log_i)
            i_g = jnp.exp(log_i - m_new)
            f_g = jnp.exp(log_f + m - m_new)
            c_new = f_g * c + i_g * z
            n_new = f_g * n + i_g
            h = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
            # h stacked in f32 (the loop's compute dtype)
            return (c_new, n_new, m_new, h), h

        xs = jnp.moveaxis(pre_x_, 1, 0)  # (S, B, 4, H, hd)
        xs = jnp.moveaxis(xs, 2, 1)  # (S, 4, B, H, hd)
        final_, hs_ = jax.lax.scan(step, state0_, xs)
        return jnp.moveaxis(hs_, 0, 1), final_  # hs (B,S,d') f32

    if shard_axes:
        from jax.sharding import PartitionSpec as P

        dp = tuple(shard_axes)
        bspec = lambda a: P(dp, *([None] * (a.ndim - 1)))
        in_specs = (P(), bspec(pre_x), tuple(bspec(t) for t in state0))
        out_specs = (P(dp, None, None, None), tuple(bspec(t) for t in state0))
        hs, final = jax.shard_map(core, in_specs=in_specs, out_specs=out_specs,
                                  check_vma=False)(r, pre_x, state0)
    else:
        hs, final = core(r, pre_x, state0)
    # f32 out (the scan's compute dtype); callers cast after projecting
    hs = hs.reshape(b, s, d)
    return hs, final


def slstm_step(p, x_t, n_heads: int, state):
    """Single-token sLSTM decode; x_t (B, d)."""
    h, final = slstm_scan(p, x_t[:, None, :], n_heads, initial_state=state)
    return h[:, 0], final
