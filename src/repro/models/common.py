"""Core functional layers: linear / norm / embedding / activations.

Params are plain nested dicts of jnp arrays; every module is an
(init, apply) pair of pure functions so that layers stack under
``jax.vmap`` (stacked-layer init) and ``jax.lax.scan`` (layer loop).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, d_in: int, d_out: int, dtype, bias: bool = False, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(d: int, dtype):
    return {"g": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["g"].astype(jnp.float32)).astype(x.dtype)


def embedding_init(key, vocab: int, d: int, dtype):
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def embed(p, tokens, compute_dtype):
    return jnp.take(p["table"], tokens, axis=0).astype(compute_dtype)


def activation(name: str):
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":  # squared ReLU (nemotron-4)
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "silu":
        return jax.nn.silu
    raise ValueError(name)


def softmax_cross_entropy(logits, labels):
    """logits (..., V) fp32 accumulate; labels int (...,). Returns (...)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - picked


def sigmoid_bce(logits, targets):
    logits = logits.astype(jnp.float32)
    targets = targets.astype(jnp.float32)
    return jnp.maximum(logits, 0) - logits * targets + jnp.log1p(jnp.exp(-jnp.abs(logits)))
