"""Modality frontends — STUBS per the assignment carve-out.

The audio (mel-spectrogram + conv codec) and vision (ViT/SigLIP) feature
extractors are NOT implemented; ``input_specs`` feeds precomputed frame /
patch embeddings of the right shape, and these projectors map them into the
backbone's d_model. This is the single allowed stub.
"""
from __future__ import annotations

import jax

from repro.models.common import dense, dense_init


def frontend_init(key, cfg, dtype):
    if cfg.frontend == "none":
        return {}
    return {"proj": dense_init(key, cfg.frontend_dim, cfg.d_model, dtype, bias=True)}


def frontend_apply(p, cfg, feats, compute_dtype):
    """feats: (B, S, frontend_dim) frame/patch embeddings -> (B, S, d_model)."""
    return dense(p["proj"], feats.astype(compute_dtype))
