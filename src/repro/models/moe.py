"""Mixture-of-Experts layer: top-k routing with fixed expert capacity and
scatter/gather dispatch (no (T,E,C) one-hot blowup), plus always-on shared
experts (DeepSeek-MoE fine-grained style) and a Switch-style load-balance
auxiliary loss.

Two dispatch paths:

- flat (default): one scatter over all tokens. Correct everywhere, but
  under a (data, model) mesh XLA assembles the expert buffers with an
  ALL-REDUCE over "data" (each device scatters its tokens into a zeroed
  global buffer; measured ~1.1 TB/dev/step on dbrx — §Perf B.1/B.2).
- grouped (``cfg.moe_groups`` = number of data shards, GShard-style):
  tokens are dispatched WITHIN their batch-shard group (purely local),
  and one structured (G,E,capg,d) -> (E,G*capg,d) transpose moves them to
  the expert-parallel layout — lowering to the minimal all-to-all pair.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.mlp import mlp, mlp_init


def moe_init(key, cfg, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    kr, ke, ks = jax.random.split(key, 3)
    expert_keys = jax.random.split(ke, cfg.n_experts)
    experts = jax.vmap(lambda k: mlp_init(k, d, ff, cfg.act, dtype))(expert_keys)
    p = {"router": dense_init(kr, d, cfg.n_experts, dtype), "experts": experts}
    if cfg.n_shared_experts:
        # shared experts fused into one wider MLP (mathematically identical
        # to n_shared separate MLPs summed, cheaper to schedule)
        p["shared"] = mlp_init(ks, d, ff * cfg.n_shared_experts, cfg.act, dtype)
    return p


def _capacity(n_tokens: int, cfg) -> int:
    c = int(cfg.capacity_factor * cfg.top_k * n_tokens / max(cfg.n_experts, 1))
    return max(c, cfg.top_k)


def _wsc(x, spec_dims, enable):
    if not enable:
        return x
    from jax.sharding import PartitionSpec as P

    return jax.lax.with_sharding_constraint(x, P(*spec_dims))


def _route(p, cfg, xf):
    """xf (..., T, d) -> (gate_vals, expert_idx, probs) with top-k gates."""
    logits = (xf @ p["router"]["w"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.top_k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)
    return gate_vals, expert_idx, probs


def _aux_loss(cfg, probs, expert_idx):
    """Switch load-balance loss over the full token set."""
    e, k = cfg.n_experts, cfg.top_k
    flat_probs = probs.reshape(-1, e)
    flat_idx = expert_idx.reshape(-1, k)
    me = jnp.mean(jax.nn.one_hot(flat_idx, e, dtype=jnp.float32).sum(1), axis=0)
    ce = jnp.mean(flat_probs, axis=0)
    return e * jnp.sum(me / k * ce)


def _dispatch_indices(expert_idx, e: int, cap: int):
    """expert_idx (T, k) -> (slot (T*k,), keep (T*k,)): position of each
    (token, k) assignment within its expert queue; overflow -> slot e*cap."""
    k = expert_idx.shape[-1]
    flat_expert = expert_idx.reshape(-1)  # token-major
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot, axis=-1)
    keep = pos < cap
    slot = jnp.where(keep, flat_expert * cap + pos, e * cap)
    return slot, keep


def _moe_flat(p, cfg, x):
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    cap = _capacity(t, cfg)
    e, k = cfg.n_experts, cfg.top_k

    gate_vals, expert_idx, probs = _route(p, cfg, xf)
    slot, keep = _dispatch_indices(expert_idx, e, cap)

    buf = jnp.zeros((e * cap + 1, d), xf.dtype)
    src = jnp.repeat(xf, k, axis=0)  # (T*k, d) token-major matches slot order
    buf = buf.at[slot].add(src)
    buf = buf[: e * cap].reshape(e, cap, d)

    out_buf = jax.vmap(lambda ep, xe: mlp(ep, xe, cfg.act))(p["experts"], buf)

    flat_out = jnp.concatenate([out_buf.reshape(e * cap, d), jnp.zeros((1, d), xf.dtype)])
    routed = flat_out[slot] * (gate_vals.reshape(-1, 1) * keep[:, None]).astype(xf.dtype)
    routed = routed.reshape(t, k, d).sum(axis=1)

    out = routed
    if "shared" in p:
        out = out + mlp(p["shared"], xf, cfg.act)
    return out.reshape(b, s, d), _aux_loss(cfg, probs, expert_idx)


def _moe_grouped(p, cfg, x):
    """GShard-style grouped dispatch; groups = data shards (cfg.moe_groups)."""
    b, s, d = x.shape
    t = b * s
    g = cfg.moe_groups
    tg = t // g
    e, k = cfg.n_experts, cfg.top_k
    capg = _capacity(tg, cfg)
    dp = tuple(cfg.act_shard) if cfg.act_shard else None
    on = dp is not None

    xg = x.reshape(g, tg, d)
    xg = _wsc(xg, (dp, None, None), on)
    gate_vals, expert_idx, probs = _route(p, cfg, xg)  # (g, tg, k)

    slot, keep = jax.vmap(lambda ei: _dispatch_indices(ei, e, capg))(expert_idx)

    def scatter_group(xf_g, slot_g):
        buf = jnp.zeros((e * capg + 1, d), xf_g.dtype)
        src = jnp.repeat(xf_g, k, axis=0)
        return buf.at[slot_g].add(src)[: e * capg]

    buf = jax.vmap(scatter_group)(xg, slot)  # (g, e*capg, d) — LOCAL per group
    buf = _wsc(buf.reshape(g, e, capg, d), (dp, None, None, None), on)

    # the one structured layout move: groups->experts (all-to-all pair);
    # staged so the axis exchange (g:data -> e:model) happens on the
    # 4-D view before the merge-reshape
    ex_in = buf.transpose(1, 0, 2, 3)  # (e, g, capg, d)
    ex_in = _wsc(ex_in, ("model", dp, None, None), on)
    ex_in = ex_in.reshape(e, g * capg, d)
    ex_in = _wsc(ex_in, ("model", None, None), on)

    out_buf = jax.vmap(lambda ep, xe: mlp(ep, xe, cfg.act))(p["experts"], ex_in)
    out_buf = _wsc(out_buf, ("model", None, None), on)

    back = out_buf.reshape(e, g, capg, d).transpose(1, 0, 2, 3)  # (g, e, capg, d)
    back = _wsc(back, (dp, None, None, None), on).reshape(g, e * capg, d)

    def gather_group(fo_g, slot_g, gv_g, keep_g):
        fo_g = jnp.concatenate([fo_g, jnp.zeros((1, d), fo_g.dtype)])
        r = fo_g[slot_g] * (gv_g.reshape(-1, 1) * keep_g[:, None]).astype(fo_g.dtype)
        return r.reshape(tg, k, d).sum(axis=1)

    routed = jax.vmap(gather_group)(back, slot, gate_vals, keep)  # (g, tg, d)
    out = routed.reshape(b, s, d)
    if "shared" in p:
        out = out + mlp(p["shared"], x.reshape(t, d), cfg.act).reshape(b, s, d)
    return out, _aux_loss(cfg, probs, expert_idx)


def moe_apply(p, cfg, x):
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    t = x.shape[0] * x.shape[1]
    if cfg.moe_groups and t % cfg.moe_groups == 0 and t // cfg.moe_groups >= cfg.top_k:
        return _moe_grouped(p, cfg, x)
    return _moe_flat(p, cfg, x)
