"""Per-layer blocks for every assigned family.

Block types
-----------
attn        pre-norm attention + (MLP | MoE)          [dense, moe, vlm]
hybrid      parallel attention + Mamba-2 SSD heads    [hymba]
xlstm_pair  one mLSTM block + one sLSTM block         [xlstm]
encdec      encoder block / decoder block w/ cross    [whisper]

All blocks are (init, apply_train, apply_decode) triples over plain dict
params, so they stack with vmap-init + lax.scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import (
    attend,
    attn_init,
    decode_attend,
    decode_cross_attend,
    init_kv_cache,
)
from repro.models.common import dense, dense_init, rmsnorm, rmsnorm_init
from repro.models.mlp import mlp, mlp_init
from repro.models.moe import moe_apply, moe_init
from repro.models.recurrent import (
    gated_linear_scan,
    gated_linear_step,
    slstm_init,
    slstm_scan,
    slstm_step,
)


# ------------------------------------------------------------------ attn ----

def attn_block_init(key, cfg, dtype):
    ka, km = jax.random.split(key)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(ka, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
    }
    if cfg.n_experts:
        p["moe"] = moe_init(km, cfg, dtype)
    else:
        p["mlp"] = mlp_init(km, cfg.d_model, cfg.d_ff, cfg.act, dtype)
    return p


def attn_block(p, cfg, x, positions, causal=True, mask=None):
    a, _ = attend(p["attn"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps), positions,
                  causal=causal, mask=mask)
    x = x + a
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        m, aux = moe_apply(p["moe"], cfg, h)
    else:
        m, aux = mlp(p["mlp"], h, cfg.act), jnp.zeros((), jnp.float32)
    return x + m, aux


def attn_block_decode(p, cfg, x, cache, index, positions=None):
    a, cache = decode_attend(p["attn"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps),
                             cache, index, positions)
    x = x + a
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        m, _ = moe_apply(p["moe"], cfg, h)
    else:
        m = mlp(p["mlp"], h, cfg.act)
    return x + m, cache


def attn_block_cache(cfg, batch, max_len, dtype):
    return init_kv_cache(cfg, batch, max_len, dtype)


# ---------------------------------------------------------------- hybrid ----

def _mamba_init(key, cfg, dtype):
    d, h, pdim, n = cfg.d_model, cfg.n_heads, cfg.ssm_head_dim, cfg.ssm_state
    ks = jax.random.split(key, 4)
    return {
        "wxz": dense_init(ks[0], d, 2 * h * pdim, dtype),
        "wbc": dense_init(ks[1], d, 2 * h * n, dtype),
        "wdt": dense_init(ks[2], d, h, dtype, bias=True),
        "a_log": jnp.zeros((h,), jnp.float32),
        "dskip": jnp.ones((h,), jnp.float32),
        "down": dense_init(ks[3], h * pdim, d, dtype),
    }


def _mamba_qkvf(p, cfg, xn):
    """Shared projection math for scan/step. xn (B,S,d)."""
    b, s, d = xn.shape
    h, pdim, n = cfg.n_heads, cfg.ssm_head_dim, cfg.ssm_state
    xz = dense(p["wxz"], xn).reshape(b, s, 2, h, pdim)
    xin, z = xz[:, :, 0], xz[:, :, 1]
    bc = dense(p["wbc"], xn).reshape(b, s, 2, h, n)
    bt, ct = bc[:, :, 0], bc[:, :, 1]
    dt = jax.nn.softplus(dense(p["wdt"], xn).astype(jnp.float32))  # (B,S,H)
    log_f = -jnp.exp(p["a_log"])[None, None, :] * dt  # (B,S,H) <= 0
    # to (B,H,S,*)
    tr = lambda t: jnp.moveaxis(t, 2, 1)
    return tr(ct), tr(bt), tr(xin), jnp.moveaxis(log_f, 2, 1), xin, z


def mamba_apply(p, cfg, xn, chunk=64, return_state=False):
    q, k, v, log_f, xin, z = _mamba_qkvf(p, cfg, xn)
    res = gated_linear_scan(q, k, v, log_f, chunk=chunk, normalize=False,
                            return_state=return_state)
    hseq, state = res if return_state else (res, None)
    hseq = jnp.moveaxis(hseq, 1, 2)  # (B,S,H,P) f32 from the scan
    hseq = hseq + p["dskip"].astype(hseq.dtype)[None, None, :, None] * xin
    out = hseq * jax.nn.silu(z)
    b, s = xn.shape[:2]
    y = dense(p["down"], out.reshape(b, s, -1)).astype(xn.dtype)
    return (y, state) if return_state else y


def mamba_step(p, cfg, xn, state):
    """xn (B,1,d); state (C,n)."""
    q, k, v, log_f, xin, z = _mamba_qkvf(p, cfg, xn)
    hv, state = gated_linear_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                  log_f[:, :, 0], state, normalize=False)
    hv = hv + p["dskip"].astype(hv.dtype)[None, :, None] * xin[:, 0]
    out = (hv[:, None] * jax.nn.silu(z))
    b = xn.shape[0]
    return dense(p["down"], out.reshape(b, 1, -1)), state


def hybrid_block_init(key, cfg, dtype):
    ka, km, kf = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(ka, cfg, dtype),
        "mamba": _mamba_init(km, cfg, dtype),
        "beta": jnp.array([0.5, 0.5], jnp.float32),  # learnable fusion (Hymba)
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(kf, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def hybrid_block(p, cfg, x, positions):
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, _ = attend(p["attn"], cfg, xn, positions, causal=True)
    m = mamba_apply(p["mamba"], cfg, xn)
    beta = p["beta"].astype(x.dtype)
    x = x + beta[0] * a + beta[1] * m
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp(p["mlp"], h, cfg.act), jnp.zeros((), jnp.float32)


def hybrid_block_decode(p, cfg, x, cache, index, positions=None):
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, kv = decode_attend(p["attn"], cfg, xn, cache["attn"], index, positions)
    m, ssm = mamba_step(p["mamba"], cfg, xn, cache["ssm"])
    beta = p["beta"].astype(x.dtype)
    x = x + beta[0] * a + beta[1] * m
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp(p["mlp"], h, cfg.act), {"attn": kv, "ssm": ssm}


def hybrid_block_cache(cfg, batch, max_len, dtype):
    h, pdim, n = cfg.n_heads, cfg.ssm_head_dim, cfg.ssm_state
    return {
        "attn": init_kv_cache(cfg, batch, max_len, dtype),
        "ssm": (jnp.zeros((batch, h, n, pdim), jnp.float32),
                jnp.zeros((batch, h, n), jnp.float32)),
    }


# ------------------------------------------------------------ xlstm_pair ----

def _mlstm_init(key, cfg, dtype):
    d = cfg.d_model
    ed = cfg.ssm_expand * d
    ks = jax.random.split(key, 6)
    return {
        "ln": rmsnorm_init(d, dtype),
        "up": dense_init(ks[0], d, 2 * ed, dtype),
        "wq": dense_init(ks[1], ed, ed, dtype),
        "wk": dense_init(ks[2], ed, ed, dtype),
        "wv": dense_init(ks[3], ed, ed, dtype),
        "wg": dense_init(ks[4], d, 2 * cfg.n_heads, dtype, bias=True),
        "down": dense_init(ks[5], ed, d, dtype),
    }


def _mlstm_qkvf(p, cfg, xn):
    b, s, d = xn.shape
    h = cfg.n_heads
    ed = cfg.ssm_expand * d
    hd = ed // h
    u = dense(p["up"], xn).reshape(b, s, 2, ed)
    xin, z = u[:, :, 0], u[:, :, 1]
    to_heads = lambda t: jnp.moveaxis(t.reshape(b, s, h, hd), 2, 1)
    q = to_heads(dense(p["wq"], xin)) / jnp.sqrt(hd).astype(xn.dtype)
    k = to_heads(dense(p["wk"], xin))
    v = to_heads(dense(p["wv"], xin))
    g = dense(p["wg"], xn).astype(jnp.float32).reshape(b, s, 2, h)
    log_f = jax.nn.log_sigmoid(g[:, :, 0])  # (B,S,H)
    i_gate = jax.nn.sigmoid(g[:, :, 1])
    k = k * jnp.moveaxis(i_gate, 2, 1)[..., None].astype(k.dtype)
    return q, k, v, jnp.moveaxis(log_f, 2, 1), z


def mlstm_apply(p, cfg, x, chunk=64, return_state=False):
    xn = rmsnorm(p["ln"], x, cfg.norm_eps)
    q, k, v, log_f, z = _mlstm_qkvf(p, cfg, xn)
    res = gated_linear_scan(q, k, v, log_f, chunk=chunk, normalize=True,
                            return_state=return_state)
    hseq, state = res if return_state else (res, None)
    b, h, s, hd = hseq.shape
    hseq = jnp.moveaxis(hseq, 1, 2).reshape(b, s, h * hd)  # f32 from the scan
    y = x + dense(p["down"], hseq * jax.nn.silu(z)).astype(x.dtype)
    return (y, state) if return_state else y


def mlstm_step(p, cfg, x, state):
    xn = rmsnorm(p["ln"], x, cfg.norm_eps)
    q, k, v, log_f, z = _mlstm_qkvf(p, cfg, xn)
    hv, state = gated_linear_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                  log_f[:, :, 0], state, normalize=True)
    b = x.shape[0]
    out = hv.reshape(b, 1, -1) * jax.nn.silu(z)
    return x + dense(p["down"], out), state


def xlstm_pair_init(key, cfg, dtype):
    km, ks, kd = jax.random.split(key, 3)
    return {
        "mlstm": _mlstm_init(km, cfg, dtype),
        "sln": rmsnorm_init(cfg.d_model, dtype),
        "slstm": slstm_init(ks, cfg.d_model, cfg.n_heads, dtype),
        "sdown": dense_init(kd, cfg.d_model, cfg.d_model, dtype),
    }


def xlstm_pair_block(p, cfg, x, positions):
    del positions
    x = mlstm_apply(p["mlstm"], cfg, x)
    # NOTE §Perf A.5: running this scan inside shard_map(batch) kills the
    # per-step weight-grad all-reduce but measured WORSE overall (memory
    # term 2x from the region boundary materialization) — kept off.
    h, _ = slstm_scan(p["slstm"], rmsnorm(p["sln"], x, cfg.norm_eps), cfg.n_heads)
    return x + dense(p["sdown"], h).astype(x.dtype), jnp.zeros((), jnp.float32)


def xlstm_pair_decode(p, cfg, x, cache, index, positions=None):
    del index, positions
    x, mstate = mlstm_step(p["mlstm"], cfg, x, cache["m"])
    h, sstate = slstm_step(p["slstm"], rmsnorm(p["sln"], x, cfg.norm_eps)[:, 0],
                           cfg.n_heads, cache["s"])
    x = x + dense(p["sdown"], h[:, None]).astype(x.dtype)
    return x, {"m": mstate, "s": sstate}


def xlstm_pair_cache(cfg, batch, max_len, dtype):
    del max_len, dtype
    d, h = cfg.d_model, cfg.n_heads
    ed = cfg.ssm_expand * d
    hd_m = ed // h
    hd_s = d // h
    zero_s = jnp.zeros((batch, h, hd_s), jnp.float32)
    return {
        "m": (jnp.zeros((batch, h, hd_m, hd_m), jnp.float32),
              jnp.zeros((batch, h, hd_m), jnp.float32)),
        "s": (zero_s, zero_s, zero_s - 1e30, zero_s),
    }


# ---------------------------------------------------------------- encdec ----

def enc_block_init(key, cfg, dtype):
    return attn_block_init(key, cfg, dtype)


def enc_block(p, cfg, x, positions):
    return attn_block(p, cfg, x, positions, causal=False)


def dec_block_init(key, cfg, dtype):
    ka, kc, km = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attn_init(ka, cfg, dtype),
        "lnx": rmsnorm_init(cfg.d_model, dtype),
        "cross": attn_init(kc, cfg, dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def dec_block(p, cfg, x, enc_out, positions):
    a, _ = attend(p["attn"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps), positions, causal=True)
    x = x + a
    c, cross_kv = attend(p["cross"], cfg, rmsnorm(p["lnx"], x, cfg.norm_eps), None,
                         causal=False, kv_x=enc_out)
    x = x + c
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp(p["mlp"], h, cfg.act), cross_kv


def dec_block_decode(p, cfg, x, cache, index):
    a, kv = decode_attend(p["attn"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps),
                          cache["self"], index)
    x = x + a
    c = decode_cross_attend(p["cross"], cfg, rmsnorm(p["lnx"], x, cfg.norm_eps),
                            cache["cross"])
    x = x + c
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp(p["mlp"], h, cfg.act), {"self": kv, "cross": cache["cross"]}


# --------------------------------------------------------------- prefill ----
# Prefill variants run the full-sequence math AND return a decode-ready
# cache (ring-buffer KV for attention, final recurrent states for SSM).

def _kv_to_ring(cfg, k_raw, v_raw, max_len, dtype):
    """Pack full-sequence (B,S,Hkv,hd) K/V into a ring buffer cache."""
    b, s = k_raw.shape[:2]
    length = min(max_len, cfg.window) if cfg.attn_kind == "sliding" else max_len
    if s >= length:
        # keep the last `length` entries; ring slot of absolute pos p is p%length
        tail_k, tail_v = k_raw[:, s - length:], v_raw[:, s - length:]
        start = (s - length) % length
        roll = jnp.mod(jnp.arange(length) - start, length)
        inv = jnp.argsort(roll)
        k_buf = jnp.take(tail_k, inv, axis=1)
        v_buf = jnp.take(tail_v, inv, axis=1)
    else:
        pad = length - s
        k_buf = jnp.pad(k_raw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_buf = jnp.pad(v_raw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": k_buf.astype(dtype), "v": v_buf.astype(dtype)}


def attn_block_prefill(p, cfg, x, positions, max_len, cache_dtype):
    a, (k_raw, v_raw) = attend(p["attn"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps),
                               positions, causal=True)
    x = x + a
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        m, _ = moe_apply(p["moe"], cfg, h)
    else:
        m = mlp(p["mlp"], h, cfg.act)
    return x + m, _kv_to_ring(cfg, k_raw, v_raw, max_len, cache_dtype)


def hybrid_block_prefill(p, cfg, x, positions, max_len, cache_dtype):
    xn = rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, (k_raw, v_raw) = attend(p["attn"], cfg, xn, positions, causal=True)
    m, ssm = mamba_apply(p["mamba"], cfg, xn, return_state=True)
    beta = p["beta"].astype(x.dtype)
    x = x + beta[0] * a + beta[1] * m
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    cache = {"attn": _kv_to_ring(cfg, k_raw, v_raw, max_len, cache_dtype), "ssm": ssm}
    return x + mlp(p["mlp"], h, cfg.act), cache


def xlstm_pair_prefill(p, cfg, x, positions, max_len, cache_dtype):
    del positions, max_len, cache_dtype
    x, mstate = mlstm_apply(p["mlstm"], cfg, x, return_state=True)
    h, sstate = slstm_scan(p["slstm"], rmsnorm(p["sln"], x, cfg.norm_eps),
                           cfg.n_heads)
    return x + dense(p["sdown"], h).astype(x.dtype), {"m": mstate, "s": sstate}


def dec_block_prefill(p, cfg, x, enc_out, positions, max_len, cache_dtype):
    a, (k_raw, v_raw) = attend(p["attn"], cfg, rmsnorm(p["ln1"], x, cfg.norm_eps),
                               positions, causal=True)
    x = x + a
    c, cross_kv = attend(p["cross"], cfg, rmsnorm(p["lnx"], x, cfg.norm_eps), None,
                         causal=False, kv_x=enc_out)
    x = x + c
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    cache = {
        "self": _kv_to_ring(cfg, k_raw, v_raw, max_len, cache_dtype),
        "cross": (cross_kv[0].astype(cache_dtype), cross_kv[1].astype(cache_dtype)),
    }
    return x + mlp(p["mlp"], h, cfg.act), cache
