"""Classification metrics in pure numpy (no sklearn in this environment).

The paper reports AUROC and AUPRC with 95% bootstrap confidence intervals
(Tables I-III). For multilabel / multiclass tasks, scores are macro-averaged
over label columns, matching the paper's per-task reporting.
"""
from __future__ import annotations

import numpy as np


def _binary_auroc(y_true: np.ndarray, y_score: np.ndarray) -> float:
    """AUROC via the Mann-Whitney U statistic (handles ties by mid-ranks)."""
    y_true = np.asarray(y_true).astype(np.float64).ravel()
    y_score = np.asarray(y_score).astype(np.float64).ravel()
    n_pos = float(y_true.sum())
    n_neg = float(len(y_true) - n_pos)
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    order = np.argsort(y_score, kind="mergesort")
    sorted_scores = y_score[order]
    # vectorized mid-ranks for ties: group equal scores, assign each group
    # the mean of its 1-based rank range (the hot path of BlendAvg scoring
    # — a Python tie loop here dominated the aggregation wall time)
    n = len(sorted_scores)
    new_group = np.r_[True, sorted_scores[1:] != sorted_scores[:-1]]
    grp = np.cumsum(new_group) - 1
    counts = np.bincount(grp)
    ends = np.cumsum(counts).astype(np.float64)
    mid = ends - (counts - 1) / 2.0
    ranks = np.empty(n, dtype=np.float64)
    ranks[order] = mid[grp]
    rank_sum_pos = ranks[y_true == 1].sum()
    u = rank_sum_pos - n_pos * (n_pos + 1) / 2.0
    return float(u / (n_pos * n_neg))


def _binary_auprc(y_true: np.ndarray, y_score: np.ndarray) -> float:
    """Average precision (step-wise interpolation, sklearn-compatible)."""
    y_true = np.asarray(y_true).astype(np.float64).ravel()
    y_score = np.asarray(y_score).astype(np.float64).ravel()
    n_pos = y_true.sum()
    if n_pos == 0:
        return float("nan")
    order = np.argsort(-y_score, kind="mergesort")
    y = y_true[order]
    tp = np.cumsum(y)
    fp = np.cumsum(1 - y)
    precision = tp / (tp + fp)
    recall = tp / n_pos
    # AP = sum over thresholds of (R_k - R_{k-1}) * P_k
    prev_recall = np.concatenate([[0.0], recall[:-1]])
    return float(np.sum((recall - prev_recall) * precision))


def _macro(metric_fn, y_true, y_score) -> float:
    y_true = np.asarray(y_true)
    y_score = np.asarray(y_score)
    if y_true.ndim == 1:
        return metric_fn(y_true, y_score)
    vals = [metric_fn(y_true[:, c], y_score[:, c]) for c in range(y_true.shape[1])]
    vals = [v for v in vals if not np.isnan(v)]
    return float(np.mean(vals)) if vals else float("nan")


def auroc(y_true, y_score) -> float:
    """Binary or macro-averaged multilabel AUROC."""
    return _macro(_binary_auroc, y_true, y_score)


def auprc(y_true, y_score) -> float:
    """Binary or macro-averaged multilabel average precision."""
    return _macro(_binary_auprc, y_true, y_score)


def accuracy(y_true, y_pred) -> float:
    y_true = np.asarray(y_true)
    y_pred = np.asarray(y_pred)
    return float(np.mean(y_true == y_pred))


def bootstrap_ci(metric_fn, y_true, y_score, n_boot: int = 200, seed: int = 0,
                 alpha: float = 0.05) -> tuple[float, float, float]:
    """(point, lo, hi) 95% percentile-bootstrap CI, as reported in the paper."""
    rng = np.random.default_rng(seed)
    y_true = np.asarray(y_true)
    y_score = np.asarray(y_score)
    point = metric_fn(y_true, y_score)
    n = len(y_true)
    vals = []
    for _ in range(n_boot):
        idx = rng.integers(0, n, size=n)
        v = metric_fn(y_true[idx], y_score[idx])
        if not np.isnan(v):
            vals.append(v)
    if not vals:
        return point, float("nan"), float("nan")
    lo, hi = np.percentile(vals, [100 * alpha / 2, 100 * (1 - alpha / 2)])
    return float(point), float(lo), float(hi)
