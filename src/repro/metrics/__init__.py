from repro.metrics.classification import auroc, auprc, accuracy, bootstrap_ci

__all__ = ["auroc", "auprc", "accuracy", "bootstrap_ci"]
