# Test lanes. `test` (docs-check + the full suite) is the tier-1 gate;
# `test-fast` skips the @pytest.mark.slow convergence/parity tests so
# the local verify loop stays under ~90 s.
PYTEST = PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest -q

.PHONY: test test-fast docs-check bench-sampled bench-loader bench-store \
	train-federated

test: docs-check
	$(PYTEST)

test-fast:
	$(PYTEST) -m "not slow"

# Reference checker over README.md + docs/: every module path, file
# path, and `make` target the docs mention must exist in the tree.
docs-check:
	python tools/docs_check.py

bench-sampled:
	PYTHONPATH=src python -m benchmarks.sampled_round_bench

bench-loader:
	PYTHONPATH=src python -m benchmarks.federated_loader_bench

bench-store:
	PYTHONPATH=src python -m benchmarks.client_store_bench

# Smoke lane: tiny ragged federation, 2 rounds, checkpoint at round 1,
# kill-and-resume, assert bit-exact round-metric parity.
train-federated:
	PYTHONPATH=src python -m repro.launch.train_federated --selftest-resume \
		--rounds 2 --clients 4 --n-train 384 --rows-cap 16 --d-hidden 16 \
		--n-val 64 --log-every 0
