# Test lanes. `test` (docs-check + the full suite) is the tier-1 gate;
# `test-fast` skips the @pytest.mark.slow convergence/parity tests so
# the local verify loop stays within a few minutes (`ci-test` enforces
# TEST_FAST_BUDGET_S as a hard ceiling — the default adds headroom for
# slower CI runners; override with TEST_FAST_BUDGET_S=...).
PYTEST = PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest -q
TEST_FAST_BUDGET_S ?= 240

.PHONY: test test-fast docs-check bench-check ci ci-test ci-smoke \
	bench-sampled bench-loader bench-store bench-participation \
	bench-comm bench-agg bench-scenario bench-attack bench-serve \
	train-federated serve-smoke ckpt-inspect

test: docs-check
	$(PYTEST)

test-fast:
	$(PYTEST) -m "not slow"

# Reference checker over README.md + docs/: every module path, file
# path, and `make` target the docs mention must exist in the tree.
docs-check:
	python tools/docs_check.py

# Schema checker over benchmarks/results/BENCH_*.json (docs/benchmarks.md
# schema: envelope keys, finite numbers, cache counts >= 1). Passes on a
# fresh checkout (results are gitignored).
bench-check:
	python tools/bench_check.py

# CI gate — `.github/workflows/ci.yml` runs exactly these two lanes, so
# the workflow and the local gate can't drift: `make ci` locally == CI.
ci: ci-test ci-smoke

# Lane 1: reference/schema checks + the fast test suite, with the
# wall-clock budget enforced (a creeping fast lane breaks the local
# verify loop long before it breaks CI).
ci-test: docs-check bench-check
	@start=$$(date +%s); \
	$(PYTEST) -m "not slow" || exit $$?; \
	elapsed=$$(($$(date +%s) - start)); \
	echo "test-fast took $${elapsed}s (budget $(TEST_FAST_BUDGET_S)s)"; \
	if [ $$elapsed -gt $(TEST_FAST_BUDGET_S) ]; then \
		echo "FAIL: fast lane exceeded its $(TEST_FAST_BUDGET_S)s budget"; \
		exit 1; \
	fi

# Lane 2: the kill-and-resume smoke — full participation (the
# train-federated lane below) plus a K-of-C sampled run under the
# state-reading omega_ema participation policy, plus a codec-enabled
# sampled run (int8_topk with error feedback), plus a SCAFFOLD run
# (stacked per-client control variates), so CI exercises the
# scheduler's, the wire codec's, and the aggregation strategies'
# checkpoint/resume contracts end to end (residual trees and control
# variates must restore bit-exactly). The --scenario lanes replay the
# same contract across CHURN: a mid-run join crosses a capacity bucket
# (8 -> 16) before the kill point, so the resume restores a grown state
# — plain, codec, scaffold, and ATTACKED variants (the last one turns
# two clients into gradient-space attackers mid-run and aggregates with
# the trimmed_mean robust defense, pinning the attack_coef uplink hook
# and the robust reducers into the resume-parity contract). The
# serve-smoke lane then covers the SERVING side: padded-bucket scores
# must match eager predict() bit-for-bit and measured wire bytes must
# reconcile against the analytic formula (see launch/serve_federated.py).
ci-smoke: train-federated serve-smoke
	PYTHONPATH=src python -m repro.launch.train_federated --selftest-resume \
		--rounds 4 --clients 6 --n-sampled 3 --policy omega_ema \
		--n-train 384 --rows-cap 16 --d-hidden 16 --n-val 64 --log-every 0
	PYTHONPATH=src python -m repro.launch.train_federated --selftest-resume \
		--rounds 4 --clients 6 --n-sampled 3 --codec int8_topk \
		--n-train 384 --rows-cap 16 --d-hidden 16 --n-val 64 --log-every 0
	PYTHONPATH=src python -m repro.launch.train_federated --selftest-resume \
		--rounds 4 --clients 6 --n-sampled 3 --strategy scaffold \
		--n-train 384 --rows-cap 16 --d-hidden 16 --n-val 64 --log-every 0
	PYTHONPATH=src python -m repro.launch.train_federated --selftest-resume \
		--scenario examples/scenarios/ci_join.yaml \
		--rounds 4 --clients 6 --n-sampled 3 \
		--n-train 384 --rows-cap 16 --d-hidden 16 --n-val 64 --log-every 0
	PYTHONPATH=src python -m repro.launch.train_federated --selftest-resume \
		--scenario examples/scenarios/ci_join.yaml --codec int8_topk \
		--rounds 4 --clients 6 --n-sampled 3 \
		--n-train 384 --rows-cap 16 --d-hidden 16 --n-val 64 --log-every 0
	PYTHONPATH=src python -m repro.launch.train_federated --selftest-resume \
		--scenario examples/scenarios/ci_join.yaml --strategy scaffold \
		--rounds 4 --clients 6 --n-sampled 3 \
		--n-train 384 --rows-cap 16 --d-hidden 16 --n-val 64 --log-every 0
	PYTHONPATH=src python -m repro.launch.train_federated --selftest-resume \
		--scenario examples/scenarios/ci_attack.yaml --strategy trimmed_mean \
		--rounds 4 --clients 6 --n-sampled 3 \
		--n-train 384 --rows-cap 16 --d-hidden 16 --n-val 64 --log-every 0

bench-sampled:
	PYTHONPATH=src python -m benchmarks.sampled_round_bench

bench-loader:
	PYTHONPATH=src python -m benchmarks.federated_loader_bench

bench-store:
	PYTHONPATH=src python -m benchmarks.client_store_bench

# Participation policies vs uniform on a straggler cohort (C=16, K=4):
# rounds-to-target-AUROC + per-round wall time, one compiled round
# shared across every policy.
bench-participation:
	PYTHONPATH=src python -m benchmarks.participation_bench

# Wire codecs (none/int8/topk/int8_topk) on the same straggler cohort:
# analytic bytes/round + compression ratio vs rounds-to-target-AUROC,
# one compiled round per codec. Emits BENCH_comm.json.
bench-comm:
	PYTHONPATH=src python -m benchmarks.comm_bench

# Aggregation strategies (blendavg/fedavg/scaffold/fedprox/fedavg+adam)
# on the straggler cohort + a high-skew Dirichlet cohort (alpha=0.1):
# rounds-to-target-AUROC per strategy, one compiled round each. Emits
# BENCH_aggregation.json.
bench-agg:
	PYTHONPATH=src python -m benchmarks.aggregation_bench

# BlendAvg + participation policies under churn (mid-run joins crossing
# a capacity bucket, departures, label-flipping clients): rounds-to-
# target AUROC per policy, one compiled round per capacity bucket.
# Emits BENCH_scenario.json.
bench-scenario:
	PYTHONPATH=src python -m benchmarks.scenario_bench

# Gradient-space attacks (none/sign_flip/scale/backdoor) x defenses
# (blendavg/fedavg/median/trimmed_mean/krum) on the straggler cohort:
# rounds-to-target AUROC + backdoor success rate per cell, one compiled
# round per defense shared across all attack arms. Emits
# BENCH_attack.json.
bench-attack:
	PYTHONPATH=src python -m benchmarks.attack_bench

# Print a checkpoint's round, client capacity, store fingerprint, and
# per-block leaf layout (shapes/dtypes, grouped by the round-state
# registry) — the debugging surface for state-block migrations.
ckpt-inspect:
	PYTHONPATH=src python tools/ckpt_inspect.py $(CKPT_DIR)

CKPT_DIR ?= /tmp/fedckpt

# Smoke lane: tiny ragged federation, 2 rounds, checkpoint at round 1,
# kill-and-resume, assert bit-exact round-metric parity.
train-federated:
	PYTHONPATH=src python -m repro.launch.train_federated --selftest-resume \
		--rounds 2 --clients 4 --n-train 384 --rows-cap 16 --d-hidden 16 \
		--n-val 64 --log-every 0

# Serving smoke: train a tiny federation, stream heterogeneous request
# mixes through the ServingEngine, and assert (a) every padded-bucket
# score equals the eager predict() path bit-for-bit, (b) exactly one
# compile per (route, capacity), (c) measured VFL wire bytes == the
# analytic communication_cost formula.
serve-smoke:
	PYTHONPATH=src python -m repro.launch.serve_federated --selftest \
		--requests 16 --rows 4 --train-rounds 2 --d-hidden 16 \
		--capacities 2,4,16 --window 8

# Serving engine latency/throughput across request mixes (p50/p99, rps,
# bytes/request, compile-cache counts) on codec none + int8_topk VFL.
# Emits BENCH_serve.json.
bench-serve:
	PYTHONPATH=src python -m benchmarks.serve_bench
