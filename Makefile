# Test lanes. `test` (the full suite) is the tier-1 gate; `test-fast`
# skips the @pytest.mark.slow convergence/parity tests so the local
# verify loop stays under ~90 s.
PYTEST = PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python -m pytest -q

.PHONY: test test-fast bench-sampled

test:
	$(PYTEST)

test-fast:
	$(PYTEST) -m "not slow"

bench-sampled:
	PYTHONPATH=src python -m benchmarks.sampled_round_bench
