"""Backbone pretraining e2e: train a ~100M-param assigned architecture
for a few hundred steps on the synthetic token stream, with loss curve +
checkpointing — the training path the multi-pod dry-run lowers at
production scale.

    PYTHONPATH=src python examples/lm_pretrain.py --arch xlstm-350m \
        --layers 4 --d-model 256 --steps 200

Default settings build a ~20-60M variant that trains in minutes on CPU;
pass --full-width for the 100M+ variant if you have the time budget.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import save_checkpoint
from repro.configs import ALIASES, get_config
from repro.data.pipeline import token_batches
from repro.models import backbone as bb


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--full-width", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(ALIASES.get(args.arch, args.arch))
    if args.full_width:
        cfg = cfg.replace(n_layers=args.layers)  # full width, few layers
    else:
        d = args.d_model
        nh = max(2, min(cfg.n_heads, d // 64))
        kv = max(1, min(cfg.n_kv_heads, nh))
        while nh % kv:
            kv -= 1
        cfg = cfg.replace(n_layers=args.layers, d_model=d, n_heads=nh,
                          n_kv_heads=kv, head_dim=d // nh,
                          d_ff=min(cfg.d_ff, 4 * d) if cfg.d_ff else 0,
                          vocab_size=min(cfg.vocab_size, 8192))
    print(f"{cfg.name}: {cfg.n_layers}L d={cfg.d_model} "
          f"~{cfg.n_params/1e6:.0f}M params")

    params = bb.init_params(jax.random.PRNGKey(0), cfg)
    opt = optim.adamw(optim.linear_warmup_cosine(args.lr, 20, args.steps))
    opt_state = opt.init(params)
    step = jax.jit(bb.make_train_step(cfg, opt))

    losses = []
    t0 = time.time()
    stream = token_batches(cfg.vocab_size, args.batch, args.seq,
                           args.steps, seed=0)
    for i, nb in enumerate(stream):
        batch = {k: jnp.asarray(v) for k, v in nb.items()}
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if (i + 1) % 20 == 0:
            print(f"step {i+1:4d} loss {np.mean(losses[-20:]):.4f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    print(f"\nloss {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} "
          f"over {args.steps} steps")
    assert np.mean(losses[-10:]) < losses[0], "training must reduce loss"
    if args.ckpt_dir:
        print("saved:", save_checkpoint(args.ckpt_dir, args.steps, params))


if __name__ == "__main__":
    main()
