"""End-to-end driver: the paper's clinical scenario (Fig. 1).

Three hospitals hold heterogeneous multimodal data (EHR time-series +
imaging embeddings): hospital 1 is multimodal (paired), hospitals 2-3
mostly unimodal (partial + fragmented). They collaboratively train
clinical-conditions and mortality predictors with BlendFL, compare
against FedAvg and centralized learning, and checkpoint the global
models.

    PYTHONPATH=src python examples/federated_hospitals.py [--rounds 60]
"""
import argparse
import time

import jax

from repro.checkpoint import save_checkpoint
from repro.core import FedConfig, Federation, evaluate_global, partition
from repro.core.baselines import run_centralized, run_fedavg
from repro.core.encoders import EncoderConfig
from repro.data.synthetic import make_task, train_val_test


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--task", default="mortality", choices=["mortality", "conditions"])
    ap.add_argument("--ckpt-dir", default="/tmp/blendfl_ckpt")
    args = ap.parse_args()

    spec = make_task(args.task)
    train, val, test = train_val_test(spec, 600, 400, 600, seed=0)
    # fig-1 style asymmetry: hospital 1 multimodal-heavy, 2-3 unimodal
    clients = partition(train, 3, frac_paired=0.35, frac_fragmented=0.30,
                        frac_partial=0.35, seed=1)
    for i, c in enumerate(clients):
        print(f"hospital {i+1}: paired={len(c.paired_a)} "
              f"frag_A={len(c.frag_a)} frag_B={len(c.frag_b)} "
              f"partial_A={len(c.partial_a)} partial_B={len(c.partial_b)}")

    ecfg = EncoderConfig(d_hidden=48, n_layers=2, enc_type="mlp")
    fcfg = FedConfig(n_clients=3, rounds=args.rounds, lr=1e-2, batch_size=64)

    t0 = time.time()
    fed = Federation.init(jax.random.PRNGKey(0), fcfg, spec, ecfg, clients, val)
    for r in range(args.rounds):
        logs = fed.round()
        if (r + 1) % 10 == 0:
            res = evaluate_global(fed, test)
            print(f"round {r+1:3d}  mm_auroc={res['multimodal_auroc']:.3f} "
                  f"A={res['uni_a_auroc']:.3f} B={res['uni_b_auroc']:.3f} "
                  f"({time.time()-t0:.0f}s)", flush=True)

    blendfl = evaluate_global(fed, test)
    fedavg, _ = run_fedavg(jax.random.PRNGKey(0), spec, ecfg, clients, val,
                           test, fcfg)
    central, _ = run_centralized(jax.random.PRNGKey(0), spec, ecfg, clients,
                                 val, test, fcfg)
    print("\nfinal multimodal AUROC:")
    for name, res in (("blendfl", blendfl), ("fedavg", fedavg),
                      ("centralized", central)):
        print(f"  {name:12s} {res['multimodal_auroc']:.3f}")

    path = save_checkpoint(args.ckpt_dir, args.rounds, fed.global_models,
                           {"task": args.task, **{k: float(v) for k, v in blendfl.items()}})
    print(f"\nblended global models checkpointed to {path}")


if __name__ == "__main__":
    main()
