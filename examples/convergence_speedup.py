"""Fig. 2 in miniature: BlendAvg vs FedAvg convergence under non-IID
clients, printed as an ASCII curve.

    PYTHONPATH=src python examples/convergence_speedup.py
"""
import jax

from repro.core import FedConfig, Federation, evaluate_global, partition
from repro.core.encoders import EncoderConfig
from repro.data.synthetic import make_task, train_val_test


def curve(aggregator: str, rounds: int = 30):
    spec = make_task("smnist")
    train, val, test = train_val_test(spec, 500, 300, 400, seed=0)
    clients = partition(train, 3, dirichlet_alpha=0.3, seed=1)
    fed = Federation.init(
        jax.random.PRNGKey(0),
        FedConfig(n_clients=3, rounds=rounds, lr=1e-2, aggregator=aggregator,
                  local_epochs=2),
        spec, EncoderConfig(d_hidden=48), clients, val)
    points = []
    for r in range(rounds):
        fed.round()
        if (r + 1) % 3 == 0:
            points.append((r + 1, evaluate_global(fed, test)["multimodal_auroc"]))
    return points


def main() -> None:
    print("multimodal AUROC vs round (non-IID, 2 local epochs/round)\n")
    curves = {agg: curve(agg) for agg in ("fedavg", "blendavg")}
    print(f"{'round':>6s} {'fedavg':>8s} {'blendavg':>9s}")
    for (r, fa), (_, ba) in zip(*curves.values()):
        bar_f = "#" * int((fa - 0.4) * 50)
        bar_b = "*" * int((ba - 0.4) * 50)
        print(f"{r:6d} {fa:8.3f} {ba:9.3f}  {bar_f}\n{'':26s}{bar_b}")
    best_f = max(v for _, v in curves["fedavg"])
    first_b = next((r for r, v in curves["blendavg"] if v >= best_f), None)
    last_f = curves["fedavg"][-1][0]
    if first_b:
        print(f"\nBlendAvg reaches FedAvg's best ({best_f:.3f}) at round "
              f"{first_b} vs {last_f} -> speedup {last_f/first_b:.2f}x")


if __name__ == "__main__":
    main()
