"""Decentralized inference demo (paper contribution #2).

Trains a small federation, then serves three request types from a
hospital's LOCAL blended models — multimodal, unimodal-A, unimodal-B —
and contrasts latency/communication with conventional VFL serving
(features up to the server, predictions back).

    PYTHONPATH=src python examples/decentralized_inference.py
"""
import time

import jax
import numpy as np

from repro.core import FedConfig, Federation, partition
from repro.core.encoders import EncoderConfig
from repro.core.inference import InferenceRequest, predict
from repro.data.synthetic import make_task, train_val_test
from repro.metrics import auroc


def main() -> None:
    spec = make_task("smnist")
    train, val, test = train_val_test(spec, 500, 300, 400, seed=0)
    clients = partition(train, 3, seed=1)
    fed = Federation.init(jax.random.PRNGKey(0),
                          FedConfig(n_clients=3, rounds=25, lr=1e-2),
                          spec, EncoderConfig(d_hidden=48), clients, val)
    print("training 25 BlendFL rounds...")
    fed.fit()
    models, ecfg, kind = fed.global_models, fed.ecfg, fed.spec.kind

    print("\n-- decentralized serving at hospital 2 (no server round-trip) --")
    for req, label, y in [
        (InferenceRequest(test.x_a[:64], test.x_b[:64]), "both modalities", test.y[:64]),
        (InferenceRequest(test.x_a[:64], None), "only EHR/audio (A)", test.y[:64]),
        (InferenceRequest(None, test.x_b[:64]), "only CXR/image (B)", test.y[:64]),
    ]:
        t0 = time.perf_counter()
        res = predict(models, req, ecfg, kind)
        jax.block_until_ready(res.scores)
        dt = (time.perf_counter() - t0) * 1e3
        print(f"  {label:22s} -> {res.route.value:12s} "
              f"auroc={auroc(y, np.asarray(res.scores)):.3f} "
              f"{dt:6.1f} ms, {res.messages} msgs / {res.bytes} bytes")

    print("\n-- conventional VFL serving (server required, both modalities) --")
    req = InferenceRequest(test.x_a[:64], test.x_b[:64], vfl=True)
    t0 = time.perf_counter()
    res = predict(models, req, ecfg, kind, server_gmv=fed.server_gmv)
    jax.block_until_ready(res.scores)
    dt = (time.perf_counter() - t0) * 1e3
    print(f"  both modalities        -> {res.route.value:12s} "
          f"auroc={auroc(test.y[:64], np.asarray(res.scores)):.3f} "
          f"{dt:6.1f} ms, {res.messages} msgs / {res.bytes} bytes")
    print("\nconventional VFL cannot serve the unimodal requests at all — "
          "and every request costs a server round-trip. (Batched serving "
          "over a request stream: repro.launch.serve_federated.)")


if __name__ == "__main__":
    main()
