"""Quickstart: train a 3-hospital BlendFL federation on synthetic
multimodal data and run decentralized inference — ~40 lines of API.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import FedConfig, Federation, evaluate_global, partition
from repro.core.encoders import EncoderConfig
from repro.core.inference import InferenceRequest, predict
from repro.data.synthetic import make_task, train_val_test

# 1. a multimodal task (audio-visual digits stand-in) split across hospitals
spec = make_task("smnist")
train, val, test = train_val_test(spec, n_train=500, n_val=300, n_test=300)
clients = partition(train, n_clients=3,
                    frac_paired=0.4, frac_fragmented=0.3, frac_partial=0.3)

# 2. the federation: per-modality encoders + fusion head per hospital
fed = Federation.init(
    key=jax.random.PRNGKey(0),
    cfg=FedConfig(n_clients=3, rounds=15, lr=1e-2, batch_size=64),
    spec=spec,
    ecfg=EncoderConfig(d_hidden=48, n_layers=2, enc_type="mlp"),
    clients=clients,
    val=val,  # server-side validation set driving BlendAvg weights
)

# 3. train: each round = partial (HFL) + fragmented (VFL) + paired phases
#    + BlendAvg aggregation (Algorithm 1 in the paper)
for r, logs in enumerate(fed.fit()):
    if (r + 1) % 5 == 0:
        print(f"round {r+1:3d} losses: partial={logs['loss_partial']:.3f} "
              f"vfl={logs['loss_vfl']:.3f} paired={logs['loss_paired']:.3f}")

# 4. evaluate the blended global models
print({k: round(v, 3) for k, v in evaluate_global(fed, test).items()})

# 5. decentralized inference: any hospital serves locally, with whatever
#    modalities the sample has — no server round-trip
res = predict(fed.global_models,
              InferenceRequest(x_a=test.x_a[:4], x_b=None),
              fed.ecfg, spec.kind)
print(f"local unimodal prediction ({res.route.value}): "
      f"scores shape {res.scores.shape}, {res.bytes} wire bytes")
